//! Low-discrepancy (quasi-Monte Carlo) sequences.
//!
//! The paper's §3.2 notes that replacing i.i.d. sample points with a
//! low-discrepancy sequence improves the embedding error from `O(N^{-1/2})`
//! to `O((log N)^d N^{-1})` (Lemieux, 2009). We provide:
//!
//! * [`Sobol`] — the workhorse, with Joe–Kuo (2008) direction numbers for
//!   the first 32 dimensions (the paper's domains are `Ω ⊆ ℝ`, so a handful
//!   of dimensions is ample; the table is trivially extensible).
//! * [`Halton`] — radical-inverse sequence in coprime bases.
//! * [`VanDerCorput`] — the 1-D building block.
//! * Owen-style random digit scrambling for the Sobol generator so repeated
//!   experiments can decorrelate QMC error.

pub mod sobol;

pub use sobol::Sobol;

/// Van der Corput radical-inverse sequence in base `b` (the 1-D Halton).
#[derive(Debug, Clone, Copy)]
pub struct VanDerCorput {
    base: u64,
    index: u64,
}

impl VanDerCorput {
    /// Sequence in base `b >= 2`, starting at index 1 (index 0 is 0.0,
    /// which is usually undesirable as a sample point).
    pub fn new(base: u64) -> Self {
        assert!(base >= 2);
        Self { base, index: 1 }
    }

    /// The radical inverse of `n` in base `b`.
    pub fn radical_inverse(base: u64, mut n: u64) -> f64 {
        let mut inv = 0.0;
        let mut denom = 1.0;
        while n > 0 {
            denom *= base as f64;
            inv += (n % base) as f64 / denom;
            n /= base;
        }
        inv
    }
}

impl Iterator for VanDerCorput {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        let v = Self::radical_inverse(self.base, self.index);
        self.index += 1;
        Some(v)
    }
}

/// The first `k` primes (enough for Halton bases in any dimension we use).
fn primes(k: usize) -> Vec<u64> {
    let mut ps = Vec::with_capacity(k);
    let mut n = 2u64;
    while ps.len() < k {
        if ps.iter().all(|p| n % p != 0) {
            ps.push(n);
        }
        n += 1;
    }
    ps
}

/// Halton sequence in `dim` dimensions using the first `dim` primes as
/// bases. Deterministic; starts at index 1.
#[derive(Debug, Clone)]
pub struct Halton {
    bases: Vec<u64>,
    index: u64,
}

impl Halton {
    /// A `dim`-dimensional Halton sequence.
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        Self {
            bases: primes(dim),
            index: 1,
        }
    }

    /// Next point in `[0,1)^dim`.
    pub fn next_point(&mut self) -> Vec<f64> {
        let p = self
            .bases
            .iter()
            .map(|&b| VanDerCorput::radical_inverse(b, self.index))
            .collect();
        self.index += 1;
        p
    }

    /// Generate the next `n` points.
    pub fn take_points(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.next_point()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vdc_base2_known_prefix() {
        let xs: Vec<f64> = VanDerCorput::new(2).take(7).collect();
        let want = [0.5, 0.25, 0.75, 0.125, 0.625, 0.375, 0.875];
        for (g, w) in xs.iter().zip(want) {
            assert!((g - w).abs() < 1e-15, "{g} vs {w}");
        }
    }

    #[test]
    fn vdc_base3_known_prefix() {
        let xs: Vec<f64> = VanDerCorput::new(3).take(4).collect();
        let want = [1.0 / 3.0, 2.0 / 3.0, 1.0 / 9.0, 4.0 / 9.0];
        for (g, w) in xs.iter().zip(want) {
            assert!((g - w).abs() < 1e-15, "{g} vs {w}");
        }
    }

    #[test]
    fn halton_2d_prefix() {
        let mut h = Halton::new(2);
        let p1 = h.next_point();
        let p2 = h.next_point();
        assert!((p1[0] - 0.5).abs() < 1e-15 && (p1[1] - 1.0 / 3.0).abs() < 1e-15);
        assert!((p2[0] - 0.25).abs() < 1e-15 && (p2[1] - 2.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn primes_prefix() {
        assert_eq!(primes(6), vec![2, 3, 5, 7, 11, 13]);
    }

    #[test]
    fn halton_star_discrepancy_beats_expectation() {
        // Loose sanity check on low discrepancy: the empirical CDF of the
        // 1-D Halton (base 2) should deviate from uniform by O(log n / n),
        // far below the ~n^{-1/2} of random points.
        let n = 1024;
        let mut xs: Vec<f64> = VanDerCorput::new(2).take(n).collect();
        xs.sort_by(f64::total_cmp);
        let mut max_dev: f64 = 0.0;
        for (i, x) in xs.iter().enumerate() {
            let ecdf = (i + 1) as f64 / n as f64;
            max_dev = max_dev.max((ecdf - x).abs());
        }
        assert!(max_dev < 0.01, "discrepancy {max_dev}");
    }
}
