//! Sobol' low-discrepancy sequence generator.
//!
//! Gray-code implementation (Antonov–Saleev) with the Joe–Kuo (2008)
//! "new-joe-kuo-6" direction numbers for the first 32 dimensions. Dimension
//! 0 is the van der Corput sequence in base 2 (identity polynomial).
//!
//! Supports optional random digit scrambling (XOR with a per-dimension
//! random mask — a cheap form of Owen scrambling sufficient to decorrelate
//! repeated runs while preserving the (t, s)-sequence structure in
//! distribution).

use crate::util::rng::Rng64;

/// Joe–Kuo direction-number table entry: primitive polynomial degree `s`,
/// coefficient bits `a`, and initial direction integers `m_1..m_s`.
struct JoeKuo {
    s: u32,
    a: u32,
    m: &'static [u32],
}

/// First 31 non-trivial dimensions from the Joe–Kuo D6 table
/// (https://web.maths.unsw.edu.au/~fkuo/sobol/, new-joe-kuo-6.21201).
/// Dimension 1 of the sequence uses the degenerate polynomial (all m = 1).
const JOE_KUO: &[JoeKuo] = &[
    JoeKuo { s: 1, a: 0, m: &[1] },
    JoeKuo { s: 2, a: 1, m: &[1, 3] },
    JoeKuo { s: 3, a: 1, m: &[1, 3, 1] },
    JoeKuo { s: 3, a: 2, m: &[1, 1, 1] },
    JoeKuo { s: 4, a: 1, m: &[1, 1, 3, 3] },
    JoeKuo { s: 4, a: 4, m: &[1, 3, 5, 13] },
    JoeKuo { s: 5, a: 2, m: &[1, 1, 5, 5, 17] },
    JoeKuo { s: 5, a: 4, m: &[1, 1, 5, 5, 5] },
    JoeKuo { s: 5, a: 7, m: &[1, 1, 7, 11, 19] },
    JoeKuo { s: 5, a: 11, m: &[1, 1, 5, 1, 1] },
    JoeKuo { s: 5, a: 13, m: &[1, 1, 1, 3, 11] },
    JoeKuo { s: 5, a: 14, m: &[1, 3, 5, 5, 31] },
    JoeKuo { s: 6, a: 1, m: &[1, 3, 3, 9, 7, 49] },
    JoeKuo { s: 6, a: 13, m: &[1, 1, 1, 15, 21, 21] },
    JoeKuo { s: 6, a: 16, m: &[1, 3, 1, 13, 27, 49] },
    JoeKuo { s: 6, a: 19, m: &[1, 1, 1, 15, 7, 5] },
    JoeKuo { s: 6, a: 22, m: &[1, 3, 1, 15, 13, 25] },
    JoeKuo { s: 6, a: 25, m: &[1, 1, 5, 5, 19, 61] },
    JoeKuo { s: 7, a: 1, m: &[1, 3, 7, 11, 23, 15, 103] },
    JoeKuo { s: 7, a: 4, m: &[1, 3, 7, 13, 13, 15, 69] },
    JoeKuo { s: 7, a: 7, m: &[1, 1, 3, 13, 7, 35, 63] },
    JoeKuo { s: 7, a: 8, m: &[1, 3, 5, 9, 1, 25, 53] },
    JoeKuo { s: 7, a: 14, m: &[1, 3, 1, 13, 9, 35, 107] },
    JoeKuo { s: 7, a: 19, m: &[1, 3, 1, 5, 27, 61, 31] },
    JoeKuo { s: 7, a: 21, m: &[1, 1, 5, 11, 19, 41, 61] },
    JoeKuo { s: 7, a: 28, m: &[1, 3, 5, 3, 3, 13, 69] },
    JoeKuo { s: 7, a: 31, m: &[1, 1, 7, 13, 1, 19, 1] },
    JoeKuo { s: 7, a: 32, m: &[1, 3, 7, 5, 13, 19, 59] },
    JoeKuo { s: 7, a: 37, m: &[1, 1, 3, 9, 25, 29, 41] },
    JoeKuo { s: 7, a: 41, m: &[1, 3, 5, 13, 23, 1, 55] },
    JoeKuo { s: 7, a: 42, m: &[1, 3, 7, 3, 13, 59, 17] },
];

const BITS: u32 = 52; // fit cleanly in f64 mantissa

/// The maximum dimension supported by the built-in direction-number table.
pub const MAX_DIM: usize = 32;

/// Sobol' sequence generator over `[0,1)^dim`.
#[derive(Debug, Clone)]
pub struct Sobol {
    dim: usize,
    /// direction numbers, `v[d][j]` for bit j of dimension d
    v: Vec<[u64; BITS as usize]>,
    /// current Gray-code state per dimension
    x: Vec<u64>,
    /// per-dimension scramble masks (zero = unscrambled)
    mask: Vec<u64>,
    index: u64,
}

impl Sobol {
    /// Unscrambled Sobol' sequence of dimension `dim <= MAX_DIM`.
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1 && dim <= MAX_DIM, "dim must be in 1..={MAX_DIM}");
        let mut v = Vec::with_capacity(dim);
        // Dimension 0: van der Corput — v_j = 2^{BITS - j - 1}.
        let mut v0 = [0u64; BITS as usize];
        for (j, vj) in v0.iter_mut().enumerate() {
            *vj = 1u64 << (BITS - 1 - j as u32);
        }
        v.push(v0);
        for d in 1..dim {
            let jk = &JOE_KUO[d - 1];
            let s = jk.s as usize;
            let mut vd = [0u64; BITS as usize];
            for j in 0..s.min(BITS as usize) {
                vd[j] = (jk.m[j] as u64) << (BITS - 1 - j as u32);
            }
            for j in s..BITS as usize {
                // recurrence: v_j = v_{j-s} ^ (v_{j-s} >> s) ^ sum a_k v_{j-k}
                let mut val = vd[j - s] ^ (vd[j - s] >> s);
                for k in 1..s {
                    if (jk.a >> (s - 1 - k)) & 1 == 1 {
                        val ^= vd[j - k];
                    }
                }
                vd[j] = val;
            }
            v.push(vd);
        }
        Self {
            dim,
            v,
            x: vec![0; dim],
            mask: vec![0; dim],
            index: 0,
        }
    }

    /// Apply random digit scrambling: XOR every output with a fixed random
    /// mask per dimension. Preserves equidistribution, decorrelates runs.
    pub fn scrambled(mut self, rng: &mut dyn Rng64) -> Self {
        let keep = (1u64 << BITS) - 1;
        for m in self.mask.iter_mut() {
            *m = rng.next_u64() & keep;
        }
        self
    }

    /// Dimension of the sequence.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Next point in `[0,1)^dim` (Antonov–Saleev Gray-code update).
    pub fn next_point(&mut self) -> Vec<f64> {
        // Skip index 0 (the all-zeros point) by pre-incrementing.
        self.index += 1;
        let c = self.index.trailing_zeros() as usize;
        debug_assert!(c < BITS as usize, "sequence exhausted");
        let scale = 1.0 / (1u64 << BITS) as f64;
        let mut p = Vec::with_capacity(self.dim);
        for d in 0..self.dim {
            self.x[d] ^= self.v[d][c];
            p.push(((self.x[d] ^ self.mask[d]) as f64) * scale);
        }
        p
    }

    /// Generate `n` points.
    pub fn take_points(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.next_point()).collect()
    }

    /// Generate `n` points of a 1-D sequence as a flat vector.
    pub fn take_1d(&mut self, n: usize) -> Vec<f64> {
        assert_eq!(self.dim, 1);
        (0..n).map(|_| self.next_point()[0]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn dim1_is_van_der_corput_base2() {
        let mut s = Sobol::new(1);
        let got = s.take_1d(7);
        let want = [0.5, 0.25, 0.75, 0.125, 0.625, 0.375, 0.875];
        // Gray-code ordering permutes within blocks; check set equality of
        // the first 2^k - 1 elements instead of order.
        let mut g = got.clone();
        let mut w = want.to_vec();
        g.sort_by(f64::total_cmp);
        w.sort_by(f64::total_cmp);
        for (a, b) in g.iter().zip(&w) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn dim2_first_points() {
        // Known start of the 2-D Sobol sequence (Gray-code order):
        // (0.5, 0.5), then (0.75, 0.25)/(0.25, 0.75) pair.
        let mut s = Sobol::new(2);
        let p1 = s.next_point();
        assert!((p1[0] - 0.5).abs() < 1e-12 && (p1[1] - 0.5).abs() < 1e-12);
        let p2 = s.next_point();
        let p3 = s.next_point();
        let mut xs = [p2[0], p3[0]];
        let mut ys = [p2[1], p3[1]];
        xs.sort_by(f64::total_cmp);
        ys.sort_by(f64::total_cmp);
        assert!((xs[0] - 0.25).abs() < 1e-12 && (xs[1] - 0.75).abs() < 1e-12);
        assert!((ys[0] - 0.25).abs() < 1e-12 && (ys[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn equidistribution_1d() {
        // After 2^k - 1 points, every dyadic interval [j/16, (j+1)/16) must
        // contain a nearly equal count.
        let mut s = Sobol::new(1);
        let xs = s.take_1d(255);
        let mut bins = [0usize; 16];
        for x in xs {
            bins[(x * 16.0) as usize] += 1;
        }
        for b in bins {
            assert!((15..=16).contains(&b), "bin count {b}");
        }
    }

    #[test]
    fn equidistribution_8d_marginals() {
        let mut s = Sobol::new(8);
        let pts = s.take_points(512);
        for d in 0..8 {
            let mean: f64 = pts.iter().map(|p| p[d]).sum::<f64>() / 512.0;
            assert!((mean - 0.5).abs() < 0.01, "dim {d} mean {mean}");
        }
    }

    #[test]
    fn scrambling_changes_points_preserves_uniformity() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut a = Sobol::new(2);
        let mut b = Sobol::new(2).scrambled(&mut rng);
        let pa = a.take_points(128);
        let pb = b.take_points(128);
        assert_ne!(pa[0], pb[0]);
        let mean: f64 = pb.iter().map(|p| p[0]).sum::<f64>() / 128.0;
        assert!((mean - 0.5).abs() < 0.05, "scrambled mean {mean}");
    }

    #[test]
    fn sobol_integration_beats_mc_rate() {
        // Integrate f(x,y) = x*y over [0,1]^2 (= 1/4). QMC error at
        // n = 4096 should be far below the ~1/sqrt(n) MC scale (~0.005 for
        // this integrand's sigma).
        let mut s = Sobol::new(2);
        let n = 4096;
        let est: f64 = s
            .take_points(n)
            .iter()
            .map(|p| p[0] * p[1])
            .sum::<f64>()
            / n as f64;
        assert!((est - 0.25).abs() < 5e-4, "estimate {est}");
    }

    #[test]
    #[should_panic]
    fn dim_zero_rejected() {
        let _ = Sobol::new(0);
    }

    #[test]
    fn max_dim_constructible() {
        let mut s = Sobol::new(MAX_DIM);
        let p = s.next_point();
        assert_eq!(p.len(), MAX_DIM);
        for x in p {
            assert!((0.0..1.0).contains(&x));
        }
    }
}
