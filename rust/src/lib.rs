//! # funclsh — locality-sensitive hashing in function spaces
//!
//! A production-grade reproduction of *"Locality-sensitive hashing in
//! function spaces"* (Shand & Becker, 2020) as a three-layer Rust + JAX +
//! Pallas system.
//!
//! The paper extends LSH families on `ℝ^N` to `L^p_μ(Ω)` function spaces via
//! two embeddings:
//!
//! 1. **Orthonormal-basis approximation** (§3.1, `p = 2`): truncate the
//!    coefficient sequence of `f` in an orthonormal basis (we use Chebyshev
//!    polynomials, extracted with a DCT) to obtain `T(f) ∈ ℓ²_N`.
//! 2. **(Quasi-)Monte Carlo sampling** (§3.2, any `p > 0`): sample `f` at `N`
//!    points of `Ω` and scale by `(V/N)^{1/p}` to obtain `T(f) ∈ ℓ^p_N`.
//!
//! Any LSH family on `ℝ^N` (the p-stable hash of Datar et al., SimHash of
//! Charikar, ALSH of Shrivastava–Li) is then applied to `T(f)`. The headline
//! application is hashing the 1-D `p`-Wasserstein distance through the
//! quantile-function identity `W^p(f,g) = ‖F⁻¹ − G⁻¹‖_{L^p}` (Eq. 3).
//!
//! ## Layering
//!
//! * **L1 (Pallas, build time)** — `python/compile/kernels/`: batched DCT and
//!   fused embed→project→floor hash kernels.
//! * **L2 (JAX, build time)** — `python/compile/model.py`: the embed+hash
//!   pipelines, lowered once to HLO text by `python/compile/aot.py`.
//! * **L3 (Rust, request path)** — this crate: the [`coordinator`] serving
//!   stack (router, dynamic batcher, LSH index shards), the [`server`] TCP
//!   front-end speaking newline-delimited JSON or length-prefixed `FBIN1`
//!   binary frames (negotiated per connection), the [`runtime`] PJRT
//!   executor that runs the AOT artifacts, and a complete pure-Rust
//!   implementation of every algorithm for ground truth, baselines, and a
//!   fallback compute path.
//!
//! ## Quick start
//!
//! ```no_run
//! use funclsh::prelude::*;
//!
//! // Two functions on Ω = [0,1].
//! let f = Sine::new(1.0, 2.0 * std::f64::consts::PI, 0.3);
//! let g = Sine::new(1.0, 2.0 * std::f64::consts::PI, 1.1);
//!
//! // Monte Carlo embedding of L²([0,1]) into ℝ⁶⁴, then a bank of
//! // 2-stable (Gaussian) L²-distance hashes with r = 1.
//! let mut rng = Xoshiro256pp::seed_from_u64(7);
//! let emb = MonteCarloEmbedder::new(Interval::new(0.0, 1.0), 64, 2.0, &mut rng);
//! let bank = PStableHashBank::new(64, 1024, 2.0, 1.0, &mut rng);
//!
//! let hf = bank.hash(&emb.embed_fn(&f));
//! let hg = bank.hash(&emb.embed_fn(&g));
//! let collisions = hf.iter().zip(&hg).filter(|(a, b)| a == b).count();
//! println!("observed collision rate: {}", collisions as f64 / 1024.0);
//! ```

pub mod analysis;
pub mod bench;
pub mod chebyshev;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod embedding;
pub mod experiments;
pub mod functions;
pub mod hashing;
pub mod json;
pub mod lsh;
pub mod quadrature;
pub mod runtime;
pub mod search;
pub mod sequences;
pub mod server;
pub mod theory;
pub mod trace;
pub mod util;
pub mod wasserstein;
pub mod workload;

/// Commonly used types, re-exported for ergonomic downstream use.
pub mod prelude {
    pub use crate::chebyshev::{chebyshev_nodes, ChebyshevSeries};
    pub use crate::embedding::{
        ChebyshevEmbedder, Embedder, Interval, MonteCarloEmbedder, QmcEmbedder,
    };
    pub use crate::functions::{
        Function1D, GaussianDist, GaussianMixture, Piecewise, Polynomial, Sampled, Sine,
    };
    pub use crate::hashing::{HashBank, LazyL2Hash, PStableHashBank, SimHashBank, VectorHash};
    pub use crate::lsh::{IndexConfig, LshIndex};
    pub use crate::quadrature::{cosine_similarity_l2, inner_product_l2, lp_distance};
    pub use crate::search::{BruteForceKnn, LshKnn};
    pub use crate::theory::{
        pstable_collision_probability, simhash_collision_probability, theorem1_bounds,
    };
    pub use crate::util::rng::{Rng64, SplitMix64, Xoshiro256pp};
    pub use crate::wasserstein::{gaussian_w2, wasserstein_1d_quantile, wasserstein_empirical};
}
