//! A small argument parser (the offline vendor set has no clap):
//! positional arguments, `--flag value`, `--flag=value`, and boolean
//! `--switch` forms.

use std::collections::{HashMap, HashSet};

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// positional arguments in order
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options
    options: HashMap<String, String>,
    /// bare `--switch` flags
    switches: HashSet<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding the binary name).
    ///
    /// A `--key` followed by a token that does not start with `--` is an
    /// option; a `--key` followed by another `--…` (or nothing) is a
    /// boolean switch. `--key=value` always binds.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let tokens: Vec<String> = args.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.options
                        .insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.insert(stripped.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option parsed as `T`, or `default`.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.switches.contains(key)
    }

    /// First positional argument (the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("experiment fig1 --pairs 128 --method=mc --fast");
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positional, vec!["experiment", "fig1"]);
        assert_eq!(a.get("pairs"), Some("128"));
        assert_eq!(a.get("method"), Some("mc"));
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn parsed_with_default() {
        let a = parse("--n 32");
        assert_eq!(a.get_parsed("n", 7usize), 32);
        assert_eq!(a.get_parsed("m", 7usize), 7);
        assert_eq!(a.get_parsed::<f64>("r", 1.5), 1.5);
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("--verbose --out file.csv");
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("file.csv"));
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert_eq!(a.subcommand(), None);
    }
}
