//! Sliced Wasserstein distance — the standard route for taking the
//! paper's 1-D machinery to multivariate distributions: project both
//! point clouds onto random directions, apply the 1-D closed form
//! (Eq. 3 / order statistics) per direction, and average.
//!
//! `SW_p^p(X, Y) = E_{θ ~ U(S^{d−1})} [ W_p^p(⟨X, θ⟩, ⟨Y, θ⟩) ]`
//!
//! Combined with the Monte Carlo embedding this also yields an LSH for
//! sliced Wasserstein: concatenate the per-direction quantile embeddings
//! (each direction contributes `N/D` coordinates), which preserves
//! `SW_2` in `ℓ²` exactly as §3.2 preserves `W_2`.

use crate::util::rng::Rng64;
use crate::wasserstein::wasserstein_empirical;

/// A bank of random unit directions on `S^{d−1}`.
#[derive(Debug, Clone)]
pub struct DirectionBank {
    dirs: Vec<Vec<f64>>,
    dim: usize,
}

impl DirectionBank {
    /// `count` i.i.d. uniform directions in `d` dimensions (normalized
    /// Gaussians).
    pub fn new(dim: usize, count: usize, rng: &mut dyn Rng64) -> Self {
        assert!(dim >= 1 && count >= 1);
        let dirs = (0..count)
            .map(|_| {
                loop {
                    let v: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
                    let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
                    if norm > 1e-12 {
                        return v.into_iter().map(|x| x / norm).collect();
                    }
                }
            })
            .collect();
        Self { dirs, dim }
    }

    /// Number of directions.
    pub fn len(&self) -> usize {
        self.dirs.len()
    }

    /// Whether the bank is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        false
    }

    /// The directions.
    pub fn directions(&self) -> &[Vec<f64>] {
        &self.dirs
    }

    /// Project a point cloud (row-major `[n][d]`) onto direction `i`.
    pub fn project(&self, points: &[Vec<f64>], i: usize) -> Vec<f64> {
        points
            .iter()
            .map(|p| {
                assert_eq!(p.len(), self.dim);
                p.iter().zip(&self.dirs[i]).map(|(a, b)| a * b).sum()
            })
            .collect()
    }
}

/// Sliced `p`-Wasserstein distance between two empirical point clouds
/// (each a set of `d`-dimensional points), averaged over the direction
/// bank.
pub fn sliced_wasserstein(
    xs: &[Vec<f64>],
    ys: &[Vec<f64>],
    p: f64,
    bank: &DirectionBank,
) -> f64 {
    assert!(!xs.is_empty() && !ys.is_empty());
    let mut acc = 0.0;
    for i in 0..bank.len() {
        let px = bank.project(xs, i);
        let py = bank.project(ys, i);
        acc += wasserstein_empirical(&px, &py, p).powf(p);
    }
    (acc / bank.len() as f64).powf(1.0 / p)
}

/// The concatenated quantile embedding for sliced Wasserstein LSH: for
/// each direction, embed the projected quantile function at `m` levels
/// and scale so the ℓ² norm of the concatenation approximates `SW_2`.
pub fn sliced_embedding(
    points: &[Vec<f64>],
    bank: &DirectionBank,
    m: usize,
    rng: &mut dyn Rng64,
) -> Vec<f64> {
    assert!(m >= 1);
    let d = bank.len();
    let scale = (1.0 / (d * m) as f64).sqrt();
    let mut out = Vec::with_capacity(d * m);
    // shared random quantile levels (client-agreed, like sample points)
    let levels: Vec<f64> = (0..m)
        .map(|_| rng.uniform().clamp(1e-9, 1.0 - 1e-9))
        .collect();
    for i in 0..d {
        let mut proj = bank.project(points, i);
        proj.sort_by(f64::total_cmp);
        for &u in &levels {
            let s = crate::functions::Sampled::from_samples(proj.clone());
            use crate::functions::Distribution1D;
            out.push(s.quantile(u) * scale);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn cloud(rng: &mut dyn Rng64, n: usize, d: usize, shift: f64) -> Vec<Vec<f64>> {
        (0..n)
            .map(|_| (0..d).map(|_| rng.normal() + shift).collect())
            .collect()
    }

    #[test]
    fn identity_and_symmetry() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let bank = DirectionBank::new(3, 32, &mut rng);
        let xs = cloud(&mut rng, 20, 3, 0.0);
        let ys = cloud(&mut rng, 25, 3, 1.0);
        assert!(sliced_wasserstein(&xs, &xs, 2.0, &bank) < 1e-10);
        let a = sliced_wasserstein(&xs, &ys, 2.0, &bank);
        let b = sliced_wasserstein(&ys, &xs, 2.0, &bank);
        assert!((a - b).abs() < 1e-12);
        assert!(a > 0.0);
    }

    #[test]
    fn point_masses_closed_form() {
        // δ_x vs δ_y: SW₂² = E|θ·(x−y)|² = ‖x−y‖²/d.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let d = 4;
        let bank = DirectionBank::new(d, 20_000, &mut rng);
        let x = vec![vec![1.0, 0.0, 0.0, 0.0]];
        let y = vec![vec![0.0, 0.0, 0.0, 0.0]];
        let sw = sliced_wasserstein(&x, &y, 2.0, &bank);
        let want = (1.0f64 / d as f64).sqrt();
        assert!((sw - want).abs() < 0.01, "{sw} vs {want}");
    }

    #[test]
    fn translation_monotone() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let bank = DirectionBank::new(2, 64, &mut rng);
        let xs = cloud(&mut rng, 50, 2, 0.0);
        let near: Vec<Vec<f64>> = xs.iter().map(|p| vec![p[0] + 0.1, p[1]]).collect();
        let far: Vec<Vec<f64>> = xs.iter().map(|p| vec![p[0] + 2.0, p[1]]).collect();
        let dn = sliced_wasserstein(&xs, &near, 2.0, &bank);
        let df = sliced_wasserstein(&xs, &far, 2.0, &bank);
        assert!(df > 5.0 * dn, "near {dn} far {df}");
    }

    #[test]
    fn directions_are_unit() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let bank = DirectionBank::new(5, 100, &mut rng);
        for dir in bank.directions() {
            let norm: f64 = dir.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn sliced_embedding_preserves_sw2() {
        // ‖E(X) − E(Y)‖₂ tracks SW₂(X, Y) across pairs (monotone + close).
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let bank = DirectionBank::new(2, 32, &mut rng);
        let base = cloud(&mut rng, 64, 2, 0.0);
        let mut emb_rng = Xoshiro256pp::seed_from_u64(99);
        let e_base = sliced_embedding(&base, &bank, 32, &mut emb_rng);
        for shift in [0.25, 0.5, 1.0, 2.0] {
            let moved: Vec<Vec<f64>> =
                base.iter().map(|p| vec![p[0] + shift, p[1] + shift]).collect();
            let mut emb_rng = Xoshiro256pp::seed_from_u64(99); // same levels
            let e_moved = sliced_embedding(&moved, &bank, 32, &mut emb_rng);
            let emb_dist: f64 = e_base
                .iter()
                .zip(&e_moved)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            let sw = sliced_wasserstein(&base, &moved, 2.0, &bank);
            assert!(
                (emb_dist - sw).abs() < 0.2 * sw,
                "shift {shift}: embed {emb_dist} vs SW {sw}"
            );
        }
    }
}
