//! The Indyk–Thaper (2003) grid embedding of `W¹` into `ℓ¹` — the
//! related-work baseline (§2.3) against which the paper's continuous
//! methods are compared in experiment E7.
//!
//! A distribution supported on `[0, 1)` is summarized by a pyramid of
//! dyadic histograms; level `ℓ` has `2^ℓ` cells weighted by the cell size
//! `2^{-ℓ}`. For two distributions `f, g` the ℓ¹ distance between their
//! embeddings approximates `W¹(f, g)` within an `O(log n)` factor, and an
//! ℓ¹ LSH (1-stable hash) on the embedding gives an LSH for `W¹`.

/// Pyramid embedding of a set of weighted samples on `[0, 1)`.
#[derive(Debug, Clone)]
pub struct GridEmbedding {
    levels: usize,
}

impl GridEmbedding {
    /// An embedding with `levels` dyadic levels (level `ℓ` has `2^ℓ`
    /// cells); total output dimension `2^{levels+1} − 1`.
    pub fn new(levels: usize) -> Self {
        assert!(levels >= 1 && levels <= 20);
        Self { levels }
    }

    /// Output dimension.
    pub fn dim(&self) -> usize {
        (1usize << (self.levels + 1)) - 1
    }

    /// Embed weighted samples (positions in `[0, 1)`, masses normalized to
    /// sum to one) into `ℓ¹`.
    pub fn embed(&self, positions: &[f64], masses: &[f64]) -> Vec<f64> {
        assert_eq!(positions.len(), masses.len());
        let total: f64 = masses.iter().sum();
        assert!(total > 0.0);
        let mut out = Vec::with_capacity(self.dim());
        for level in 0..=self.levels {
            let cells = 1usize << level;
            let scale = 1.0 / cells as f64; // cell side = weight 2^{-ℓ}
            let mut hist = vec![0.0; cells];
            for (&x, &m) in positions.iter().zip(masses) {
                let c = ((x.clamp(0.0, 1.0 - 1e-12)) * cells as f64) as usize;
                hist[c] += m / total;
            }
            for h in hist {
                out.push(scale * h);
            }
        }
        out
    }
}

/// ℓ¹ distance between two embeddings — the `W¹` surrogate.
pub fn l1_distance(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng64, Xoshiro256pp};
    use crate::wasserstein::wasserstein_empirical;

    #[test]
    fn identical_inputs_zero_distance() {
        let ge = GridEmbedding::new(6);
        let pos = [0.1, 0.5, 0.9];
        let mass = [0.2, 0.3, 0.5];
        let e1 = ge.embed(&pos, &mass);
        let e2 = ge.embed(&pos, &mass);
        assert!(l1_distance(&e1, &e2) < 1e-15);
    }

    #[test]
    fn dim_matches_formula() {
        let ge = GridEmbedding::new(4);
        assert_eq!(ge.dim(), 31);
        assert_eq!(ge.embed(&[0.5], &[1.0]).len(), 31);
    }

    #[test]
    fn translation_scales_with_distance() {
        // Two point masses: the surrogate distance must grow with their
        // separation.
        let ge = GridEmbedding::new(8);
        let base = ge.embed(&[0.25], &[1.0]);
        let near = ge.embed(&[0.27], &[1.0]);
        let far = ge.embed(&[0.75], &[1.0]);
        let dn = l1_distance(&base, &near);
        let df = l1_distance(&base, &far);
        assert!(df > 3.0 * dn, "near {dn}, far {df}");
    }

    #[test]
    fn surrogate_within_log_factor_of_w1() {
        // Indyk–Thaper guarantee: W¹ ≤ ℓ¹ distance (in expectation, up to
        // constants) ≤ O(log n) W¹. Empirically check the ratio stays in a
        // modest band over random empirical measures.
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let ge = GridEmbedding::new(10);
        for _ in 0..10 {
            let xs: Vec<f64> = (0..32).map(|_| rng.uniform()).collect();
            let ys: Vec<f64> = (0..32).map(|_| rng.uniform()).collect();
            let m = vec![1.0 / 32.0; 32];
            let w1 = wasserstein_empirical(&xs, &ys, 1.0);
            let sur = l1_distance(&ge.embed(&xs, &m), &ge.embed(&ys, &m));
            let ratio = sur / w1.max(1e-9);
            assert!(
                (0.5..=30.0).contains(&ratio),
                "ratio {ratio} (W¹ {w1}, surrogate {sur})"
            );
        }
    }
}
