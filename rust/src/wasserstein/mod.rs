//! Wasserstein distances — the paper's motivating application (§2.2, §4).
//!
//! * [`wasserstein_1d_quantile`] — the continuous 1-D closed form (Eq. 3):
//!   `W^p(f,g) = ‖F⁻¹ − G⁻¹‖_{L^p([0,1])}`, evaluated by quadrature with
//!   the paper's endpoint clipping (footnote 1).
//! * [`gaussian_w2`] — the Olkin–Pukelsheim closed form for a pair of 1-D
//!   Gaussians: `W² = √((μ₁−μ₂)² + (σ₁−σ₂)²)` — the ground truth of Fig. 3.
//! * [`wasserstein_empirical`] — `O(m + n)` sorted-sample estimator for two
//!   empirical distributions with different sample counts (the "step
//!   function" estimator discussed in §2.2).
//! * [`discrete`] — the discrete LP (Eq. 2) solved exactly by min-cost
//!   flow: the baseline that validates everything else.
//! * [`indyk_thaper`] — the grid-embedding `W¹ → ℓ¹` baseline
//!   (Indyk & Thaper 2003) the related-work section compares against.

pub mod discrete;
pub mod indyk_thaper;
pub mod sliced;

pub use sliced::{sliced_wasserstein, DirectionBank};

use crate::functions::{Distribution1D, GaussianDist};
use crate::quadrature::integrate_gl;

/// The clip used when hashing/integrating quantile functions whose values
/// diverge at 0 and 1 (paper footnote 1): integrate over `[ε, 1−ε]`.
pub const QUANTILE_CLIP: f64 = 1e-3;

/// Eq. 3: `W^p(f, g) = (∫₀¹ |F⁻¹(u) − G⁻¹(u)|^p du)^{1/p}` by
/// Gauss–Legendre quadrature over the clipped interval `[clip, 1−clip]`.
///
/// With `clip = 0` this is the exact 1-D Wasserstein distance for `p ≥ 1`
/// when the quantile functions are bounded; distributions with unbounded
/// support (Gaussians!) need a positive clip exactly as the paper does.
pub fn wasserstein_1d_quantile(
    f: &dyn Distribution1D,
    g: &dyn Distribution1D,
    p: f64,
    clip: f64,
) -> f64 {
    assert!(p >= 1.0, "Eq. 3 requires p >= 1");
    assert!((0.0..0.5).contains(&clip));
    let lo = clip;
    let hi = 1.0 - clip;
    let integrand = move |u: f64| (f.quantile(u) - g.quantile(u)).abs().powf(p);
    integrate_gl(&integrand, lo, hi, 512).max(0.0).powf(1.0 / p)
}

/// Olkin–Pukelsheim closed form for 1-D Gaussians:
/// `W²(N(μ₁,σ₁²), N(μ₂,σ₂²)) = √((μ₁−μ₂)² + (σ₁−σ₂)²)`.
pub fn gaussian_w2(a: &GaussianDist, b: &GaussianDist) -> f64 {
    ((a.mu - b.mu).powi(2) + (a.sigma - b.sigma).powi(2)).sqrt()
}

/// `W^p` between two empirical distributions given raw samples, in
/// `O(m log m + n log n)` (sorting) + `O(m + n)` (merge).
///
/// Models both quantile functions as step functions (the estimator of
/// §2.2) and integrates `|F⁻¹ − G⁻¹|^p` exactly over the merged breakpoint
/// grid `{i/m} ∪ {j/n}`.
pub fn wasserstein_empirical(xs: &[f64], ys: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty() && !ys.is_empty());
    assert!(p >= 1.0);
    let mut x = xs.to_vec();
    let mut y = ys.to_vec();
    x.sort_by(f64::total_cmp);
    y.sort_by(f64::total_cmp);
    let m = x.len();
    let n = y.len();
    let mut acc = 0.0;
    let mut u = 0.0; // current position in [0, 1]
    let mut i = 0; // x-step index: F⁻¹(u) = x[i] for u ∈ (i/m, (i+1)/m]
    let mut j = 0;
    while u < 1.0 {
        let next_x = (i + 1) as f64 / m as f64;
        let next_y = (j + 1) as f64 / n as f64;
        let next = next_x.min(next_y).min(1.0);
        acc += (x[i] - y[j]).abs().powf(p) * (next - u);
        if (next - next_x).abs() < 1e-15 {
            i = (i + 1).min(m - 1);
        }
        if (next - next_y).abs() < 1e-15 {
            j = (j + 1).min(n - 1);
        }
        u = next;
    }
    acc.powf(1.0 / p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::{GaussianMixture, Sampled};
    use crate::util::rng::{Rng64, Xoshiro256pp};

    #[test]
    fn gaussian_w2_closed_form_cases() {
        let a = GaussianDist::new(0.0, 1.0);
        let b = GaussianDist::new(3.0, 1.0);
        assert!((gaussian_w2(&a, &b) - 3.0).abs() < 1e-15);
        let c = GaussianDist::new(0.0, 2.0);
        assert!((gaussian_w2(&a, &c) - 1.0).abs() < 1e-15);
        let d = GaussianDist::new(3.0, 5.0);
        assert!((gaussian_w2(&a, &d) - 5.0).abs() < 1e-15);
        assert_eq!(gaussian_w2(&a, &a), 0.0);
    }

    #[test]
    fn quantile_formula_matches_gaussian_closed_form() {
        // The quadrature version of Eq. 3 (with the paper's clip) must land
        // near the Olkin–Pukelsheim value.
        let a = GaussianDist::new(-0.4, 0.8);
        let b = GaussianDist::new(0.9, 0.3);
        let want = gaussian_w2(&a, &b);
        let got = wasserstein_1d_quantile(&a, &b, 2.0, QUANTILE_CLIP);
        assert!((got - want).abs() < 5e-3 * want, "{got} vs {want}");
    }

    #[test]
    fn quantile_formula_w1_translation() {
        // W¹ between N(0,1) and N(2,1) is exactly 2 (pure translation).
        let a = GaussianDist::new(0.0, 1.0);
        let b = GaussianDist::new(2.0, 1.0);
        let got = wasserstein_1d_quantile(&a, &b, 1.0, QUANTILE_CLIP);
        assert!((got - 2.0).abs() < 2e-2, "{got}");
    }

    #[test]
    fn quantile_formula_mixtures() {
        // Sanity on GMMs: W(f, f) = 0; translation invariance.
        let m1 = GaussianMixture::new(
            vec![GaussianDist::new(-1.0, 0.4), GaussianDist::new(1.0, 0.4)],
            vec![0.5, 0.5],
        );
        let m2 = GaussianMixture::new(
            vec![GaussianDist::new(0.0, 0.4), GaussianDist::new(2.0, 0.4)],
            vec![0.5, 0.5],
        );
        assert!(wasserstein_1d_quantile(&m1, &m1, 2.0, QUANTILE_CLIP) < 1e-9);
        let d = wasserstein_1d_quantile(&m1, &m2, 2.0, QUANTILE_CLIP);
        assert!((d - 1.0).abs() < 2e-2, "translation by 1: {d}");
    }

    #[test]
    fn empirical_equal_sizes_matches_order_statistics() {
        // m = n: W^p^p = (1/n) Σ |x_(i) − y_(i)|^p.
        let xs = [3.0, 1.0, 2.0];
        let ys = [4.0, 6.0, 5.0];
        let direct = ((4.0f64 - 1.0).powi(2) + (5.0f64 - 2.0).powi(2) + (6.0f64 - 3.0).powi(2))
            / 3.0;
        let got = wasserstein_empirical(&xs, &ys, 2.0);
        assert!((got - direct.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empirical_unequal_sizes() {
        // F⁻¹ = 0 on (0,1]; G⁻¹: 0 on (0,1/2], 1 on (1/2,1].
        // W¹ = 1/2.
        let xs = [0.0];
        let ys = [0.0, 1.0];
        let got = wasserstein_empirical(&xs, &ys, 1.0);
        assert!((got - 0.5).abs() < 1e-12, "{got}");
    }

    #[test]
    fn empirical_converges_to_gaussian_truth() {
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let a = GaussianDist::new(0.0, 1.0);
        let b = GaussianDist::new(1.5, 0.5);
        let xs: Vec<f64> = (0..20_000).map(|_| a.quantile(rng.uniform().clamp(1e-12, 1.0 - 1e-12))).collect();
        let ys: Vec<f64> = (0..30_000).map(|_| b.quantile(rng.uniform().clamp(1e-12, 1.0 - 1e-12))).collect();
        let got = wasserstein_empirical(&xs, &ys, 2.0);
        let want = gaussian_w2(&a, &b);
        assert!((got - want).abs() < 0.03, "{got} vs {want}");
    }

    #[test]
    fn empirical_symmetry_and_identity() {
        let xs = [0.5, 1.5, -2.0, 0.25];
        let ys = [1.0, 2.0, 3.0];
        let ab = wasserstein_empirical(&xs, &ys, 1.0);
        let ba = wasserstein_empirical(&ys, &xs, 1.0);
        assert!((ab - ba).abs() < 1e-12);
        assert!(wasserstein_empirical(&xs, &xs, 2.0) < 1e-12);
    }

    #[test]
    fn sampled_distribution_roundtrip() {
        // Sampled quantile fn hashed over [clip, 1-clip] integrates close
        // to the empirical estimator.
        let xs = vec![0.1, 0.4, 0.45, 0.9];
        let ys = vec![0.2, 0.3, 0.8, 0.95];
        let sf = Sampled::from_samples(xs.clone()).step();
        let sg = Sampled::from_samples(ys.clone()).step();
        let via_quantile = wasserstein_1d_quantile(&sf, &sg, 1.0, 0.0);
        let via_empirical = wasserstein_empirical(&xs, &ys, 1.0);
        assert!(
            (via_quantile - via_empirical).abs() < 5e-3,
            "{via_quantile} vs {via_empirical}"
        );
    }
}
