//! The discrete Wasserstein LP (Eq. 2 of the paper), solved *exactly*.
//!
//! `W^p(m_a, m_b)^p = min Σ f_ij d_ij^p` subject to marginal constraints —
//! a transportation problem. We solve it as min-cost flow with successive
//! shortest paths (Dijkstra + Johnson potentials), which is exact for the
//! sizes used in benchmarks (n ≤ a few hundred) and makes no assumptions
//! about the ground metric, so it doubles as the correctness oracle for
//! the fast 1-D estimators.

/// A dense transportation problem: supplies `a` (Σ = 1), demands `b`
/// (Σ = 1), cost matrix `cost[i][j]`.
#[derive(Debug, Clone)]
pub struct Transportation {
    /// supply masses (normalized internally)
    pub a: Vec<f64>,
    /// demand masses (normalized internally)
    pub b: Vec<f64>,
    /// `cost[i * b.len() + j]`, row-major
    pub cost: Vec<f64>,
}

/// Result of solving the transportation problem.
#[derive(Debug, Clone)]
pub struct TransportPlan {
    /// optimal objective `Σ f_ij c_ij`
    pub objective: f64,
    /// flow matrix, row-major `[m][n]`
    pub flow: Vec<f64>,
}

impl Transportation {
    /// Build from marginals and a cost matrix; masses are normalized to
    /// sum to one (as Eq. 2 requires).
    pub fn new(mut a: Vec<f64>, mut b: Vec<f64>, cost: Vec<f64>) -> Self {
        assert!(!a.is_empty() && !b.is_empty());
        assert_eq!(cost.len(), a.len() * b.len());
        assert!(a.iter().all(|&x| x >= 0.0) && b.iter().all(|&x| x >= 0.0));
        let sa: f64 = a.iter().sum();
        let sb: f64 = b.iter().sum();
        assert!(sa > 0.0 && sb > 0.0);
        for x in a.iter_mut() {
            *x /= sa;
        }
        for x in b.iter_mut() {
            *x /= sb;
        }
        Self { a, b, cost }
    }

    /// Solve exactly by successive shortest paths.
    ///
    /// Graph: source → supplier `i` (capacity `a_i`), supplier → consumer
    /// (∞, cost `c_ij`), consumer `j` → sink (capacity `b_j`). Costs are
    /// nonnegative after the first Dijkstra thanks to Johnson potentials.
    pub fn solve(&self) -> TransportPlan {
        let m = self.a.len();
        let n = self.b.len();
        // node ids: 0 = source, 1..=m suppliers, m+1..=m+n consumers,
        // m+n+1 = sink
        let source = 0usize;
        let sink = m + n + 1;
        let num_nodes = m + n + 2;

        // adjacency as edge list with reverse edges
        #[derive(Clone)]
        struct Edge {
            to: usize,
            cap: f64,
            cost: f64,
            /// index of the reverse edge in `graph[to]`
            rev: usize,
        }
        let mut graph: Vec<Vec<Edge>> = vec![Vec::new(); num_nodes];
        let add_edge = |graph: &mut Vec<Vec<Edge>>, u: usize, v: usize, cap: f64, cost: f64| {
            let rev_u = graph[v].len();
            let rev_v = graph[u].len();
            graph[u].push(Edge {
                to: v,
                cap,
                cost,
                rev: rev_u,
            });
            graph[v].push(Edge {
                to: u,
                cap: 0.0,
                cost: -cost,
                rev: rev_v,
            });
        };
        for (i, &ai) in self.a.iter().enumerate() {
            add_edge(&mut graph, source, 1 + i, ai, 0.0);
        }
        for (j, &bj) in self.b.iter().enumerate() {
            add_edge(&mut graph, 1 + m + j, sink, bj, 0.0);
        }
        // remember the edge index of (i, j) arcs to read flow back out
        let mut arc_index = vec![0usize; m * n];
        for i in 0..m {
            for j in 0..n {
                arc_index[i * n + j] = graph[1 + i].len();
                add_edge(&mut graph, 1 + i, 1 + m + j, f64::INFINITY, self.cost[i * n + j]);
            }
        }

        let mut potential = vec![0.0f64; num_nodes];
        let mut total_flow = 0.0;
        let target_flow = 1.0;
        let eps = 1e-12;

        while total_flow < target_flow - eps {
            // Dijkstra with reduced costs.
            let mut dist = vec![f64::INFINITY; num_nodes];
            let mut prev: Vec<Option<(usize, usize)>> = vec![None; num_nodes];
            dist[source] = 0.0;
            let mut heap = std::collections::BinaryHeap::new();
            // max-heap on negated distance
            heap.push((std::cmp::Reverse(ordered(0.0)), source));
            while let Some((std::cmp::Reverse(d), u)) = heap.pop() {
                let d = d.0;
                if d > dist[u] + eps {
                    continue;
                }
                for (ei, e) in graph[u].iter().enumerate() {
                    if e.cap <= eps {
                        continue;
                    }
                    let nd = dist[u] + e.cost + potential[u] - potential[e.to];
                    if nd + eps < dist[e.to] {
                        dist[e.to] = nd;
                        prev[e.to] = Some((u, ei));
                        heap.push((std::cmp::Reverse(ordered(nd)), e.to));
                    }
                }
            }
            if dist[sink].is_infinite() {
                break; // no augmenting path (should not happen: mass matches)
            }
            for (v, d) in dist.iter().enumerate() {
                if d.is_finite() {
                    potential[v] += d;
                }
            }
            // bottleneck along the path
            let mut push = target_flow - total_flow;
            let mut v = sink;
            while let Some((u, ei)) = prev[v] {
                push = push.min(graph[u][ei].cap);
                v = u;
            }
            // apply
            let mut v = sink;
            while let Some((u, ei)) = prev[v] {
                let rev = graph[u][ei].rev;
                graph[u][ei].cap -= push;
                graph[v][rev].cap += push;
                v = u;
            }
            total_flow += push;
        }

        // read back flows on (i, j) arcs: flow = reverse edge capacity
        let mut flow = vec![0.0; m * n];
        let mut objective = 0.0;
        for i in 0..m {
            for j in 0..n {
                let ei = arc_index[i * n + j];
                let e = &graph[1 + i][ei];
                let f = graph[e.to][e.rev].cap;
                flow[i * n + j] = f;
                objective += f * self.cost[i * n + j];
            }
        }
        TransportPlan { objective, flow }
    }
}

/// Total-order wrapper for f64 keys in the binary heap, ordered by
/// `total_cmp` so even an unexpected NaN cost cannot panic the solver.
fn ordered(x: f64) -> OrdF64 {
    OrdF64(x)
}

struct OrdF64(f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// `W^p` between two discrete distributions on point sets `xs`, `ys` on the
/// real line with masses `a`, `b` — Eq. 2 with `d_ij = |x_i − y_j|`.
pub fn discrete_wasserstein_1d(
    xs: &[f64],
    a: &[f64],
    ys: &[f64],
    b: &[f64],
    p: f64,
) -> f64 {
    assert_eq!(xs.len(), a.len());
    assert_eq!(ys.len(), b.len());
    let n = ys.len();
    let mut cost = vec![0.0; xs.len() * n];
    for (i, &x) in xs.iter().enumerate() {
        for (j, &y) in ys.iter().enumerate() {
            cost[i * n + j] = (x - y).abs().powf(p);
        }
    }
    let plan = Transportation::new(a.to_vec(), b.to_vec(), cost).solve();
    plan.objective.max(0.0).powf(1.0 / p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng64, Xoshiro256pp};
    use crate::wasserstein::wasserstein_empirical;

    #[test]
    fn identical_distributions_zero_cost() {
        let xs = [0.0, 1.0, 2.0];
        let w = [1.0, 1.0, 1.0];
        let d = discrete_wasserstein_1d(&xs, &w, &xs, &w, 1.0);
        assert!(d.abs() < 1e-12, "{d}");
    }

    #[test]
    fn point_mass_translation() {
        // δ_0 → δ_3: W^p = 3 for every p.
        for &p in &[1.0, 1.5, 2.0] {
            let d = discrete_wasserstein_1d(&[0.0], &[1.0], &[3.0], &[1.0], p);
            assert!((d - 3.0).abs() < 1e-12, "p = {p}: {d}");
        }
    }

    #[test]
    fn known_two_point_example() {
        // a: mass ½ at 0 and ½ at 1; b: mass 1 at 0.
        // Optimal W¹: move ½ from 1 to 0 → cost ½.
        let d = discrete_wasserstein_1d(&[0.0, 1.0], &[0.5, 0.5], &[0.0], &[1.0], 1.0);
        assert!((d - 0.5).abs() < 1e-12, "{d}");
    }

    #[test]
    fn lp_matches_sorted_formula_uniform_masses() {
        // Equal sample counts with uniform masses: the LP must agree with
        // the O(n log n) order-statistics formula.
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        for trial in 0..5 {
            let n = 16;
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
            let ys: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
            let w = vec![1.0 / n as f64; n];
            for &p in &[1.0, 2.0] {
                let lp = discrete_wasserstein_1d(&xs, &w, &ys, &w, p);
                let sorted = wasserstein_empirical(&xs, &ys, p);
                assert!(
                    (lp - sorted).abs() < 1e-9,
                    "trial {trial} p {p}: LP {lp} vs sorted {sorted}"
                );
            }
        }
    }

    #[test]
    fn lp_matches_merged_formula_unequal_counts() {
        let mut rng = Xoshiro256pp::seed_from_u64(43);
        let xs: Vec<f64> = (0..8).map(|_| rng.uniform_in(0.0, 1.0)).collect();
        let ys: Vec<f64> = (0..12).map(|_| rng.uniform_in(0.0, 1.0)).collect();
        let wa = vec![1.0 / 8.0; 8];
        let wb = vec![1.0 / 12.0; 12];
        let lp = discrete_wasserstein_1d(&xs, &wa, &ys, &wb, 1.0);
        let merged = wasserstein_empirical(&xs, &ys, 1.0);
        assert!((lp - merged).abs() < 1e-9, "{lp} vs {merged}");
    }

    #[test]
    fn plan_satisfies_marginals() {
        let a = vec![0.3, 0.7];
        let b = vec![0.5, 0.25, 0.25];
        let cost = vec![1.0, 2.0, 3.0, 2.5, 0.5, 1.0];
        let t = Transportation::new(a.clone(), b.clone(), cost);
        let plan = t.solve();
        for i in 0..2 {
            let row: f64 = (0..3).map(|j| plan.flow[i * 3 + j]).sum();
            assert!((row - a[i]).abs() < 1e-9, "row {i}: {row}");
        }
        for j in 0..3 {
            let col: f64 = (0..2).map(|i| plan.flow[i * 3 + j]).sum();
            assert!((col - b[j]).abs() < 1e-9, "col {j}: {col}");
        }
        assert!(plan.flow.iter().all(|&f| f >= -1e-12));
    }

    #[test]
    fn masses_get_normalized() {
        // unnormalized masses give the same distance
        let d1 = discrete_wasserstein_1d(&[0.0, 1.0], &[2.0, 2.0], &[0.5], &[7.0], 1.0);
        let d2 = discrete_wasserstein_1d(&[0.0, 1.0], &[0.5, 0.5], &[0.5], &[1.0], 1.0);
        assert!((d1 - d2).abs() < 1e-12);
    }

    #[test]
    fn non_euclidean_cost_matrix() {
        // A cost matrix with a cheap "wormhole" changes the optimum — the
        // solver must exploit it. 2x2: a = b = (½, ½).
        // cost: c00 = 10, c01 = 0, c10 = 0, c11 = 10 → optimal crossing.
        let t = Transportation::new(
            vec![0.5, 0.5],
            vec![0.5, 0.5],
            vec![10.0, 0.0, 0.0, 10.0],
        );
        let plan = t.solve();
        assert!(plan.objective.abs() < 1e-12, "{}", plan.objective);
    }
}
