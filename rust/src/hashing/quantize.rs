//! Checked quantization of hash values, and width-typed signature
//! storage.
//!
//! Every LSH family in this crate discretizes an affine form into an
//! integer bucket id: `h(x) = ⌊⟨α,x⟩/r + b⌋`. The seed code lowered that
//! `f64` with a bare `as i32`, which **saturates silently**: any value
//! beyond `i32` range collapses to `i32::MAX`/`i32::MIN` and `NaN`
//! becomes `0`, so wildly different inputs land in one bucket and a
//! poisoned dot product masquerades as a legitimate signature. This
//! module centralizes the lowering behind [`quantize_hash`], which
//! returns a typed [`HashOverflow`] instead; the `funclsh analyze`
//! rule `checked-float-cast` bans bare float→`i{8,16,32}` casts in
//! library code outside this file.
//!
//! # Signature width and the quantization-range derivation
//!
//! A hash value under the folded matrix `M` (embedding ∘ projection ∘
//! `1/r`) and offsets `b` obeys, for any input row with `‖x‖∞ ≤ c`:
//!
//! ```text
//! |⟨x, M_·j⟩ + b_j| ≤ c · Σ_i |M_ij| + |b_j|  =: B_j(c)
//! ```
//!
//! so every signature component lies in `[⌊-B_j(c)⌋, ⌊B_j(c)⌋]`. When
//! the service is configured with a norm cap `c` (rows are already
//! rejected at the wire when non-finite), `max_j B_j(c)` is a *provable*
//! bound on the hash range, and [`SigWidth::fitting`] picks the
//! narrowest of `i8`/`i16`/`i32` whose range contains it — signatures
//! are then stored at that width ([`SigVec`], width-typed
//! [`crate::coordinator::Signatures`]), cutting signature memory
//! traffic 2–4× with **unchanged** bucket semantics: values are widened
//! back to `i32` at fingerprint/probe time, so table keys and candidate
//! sets are identical to the `i32` path. Rows whose values exceed the
//! admitted range (possible only above the cap) get typed per-item
//! errors, never a silently wrapped signature.

/// Typed error of [`quantize_hash`] and the checked narrowing paths: a
/// hash value left the representable signature range (or was not a
/// finite number at all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashOverflow {
    /// the width whose range was exceeded
    pub width: SigWidth,
}

impl std::fmt::Display for HashOverflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hash overflow: value outside the {} signature range (or not finite)",
            self.width.name()
        )
    }
}

impl std::error::Error for HashOverflow {}

/// Floor-quantize an affine hash value to `i32`, rejecting overflow and
/// `NaN` instead of saturating.
///
/// This is the **only** place in library code allowed to lower a float
/// to a signature integer (enforced by the `checked-float-cast` analyze
/// rule): `⌊v⌋` is returned exactly when it lies in `i32` range, and
/// everything else — `±∞`, `NaN`, `|v|` beyond ~2³¹ — is a typed
/// [`HashOverflow`].
#[inline]
pub fn quantize_hash(v: f64) -> Result<i32, HashOverflow> {
    let f = v.floor();
    // NaN fails both comparisons; the bounds are exact f64 values, and
    // a floor within them converts exactly
    if f >= i32::MIN as f64 && f <= i32::MAX as f64 {
        Ok(f as i32)
    } else {
        Err(HashOverflow {
            width: SigWidth::I32,
        })
    }
}

/// Storage width of signature components.
///
/// `I32` is the seed layout; `I16`/`I8` store the same bucket ids
/// narrowed (see the module docs for when that is provably lossless).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SigWidth {
    /// 1-byte components in `[-128, 127]`
    I8,
    /// 2-byte components in `[-32768, 32767]`
    I16,
    /// 4-byte components (the seed layout; always admissible)
    I32,
}

impl SigWidth {
    /// Bytes per signature component.
    pub fn bytes(self) -> usize {
        match self {
            SigWidth::I8 => 1,
            SigWidth::I16 => 2,
            SigWidth::I32 => 4,
        }
    }

    /// Largest representable component.
    pub fn max_val(self) -> i32 {
        match self {
            SigWidth::I8 => i8::MAX as i32,
            SigWidth::I16 => i16::MAX as i32,
            SigWidth::I32 => i32::MAX,
        }
    }

    /// Smallest representable component.
    pub fn min_val(self) -> i32 {
        match self {
            SigWidth::I8 => i8::MIN as i32,
            SigWidth::I16 => i16::MIN as i32,
            SigWidth::I32 => i32::MIN,
        }
    }

    /// Whether `v` is representable at this width.
    pub fn admits(self, v: i32) -> bool {
        v >= self.min_val() && v <= self.max_val()
    }

    /// The narrowest width whose range provably contains every hash
    /// value with magnitude `≤ bound` (pre-floor, so one extra unit of
    /// slack is reserved on each side). Non-finite or huge bounds fall
    /// back to `I32`.
    pub fn fitting(bound: f64) -> SigWidth {
        if !bound.is_finite() || bound < 0.0 {
            return SigWidth::I32;
        }
        // floor(v) for |v| ≤ bound lies in [-bound-1, bound]; require
        // bound + 2 ≤ max so both ends clear the narrow range with a
        // unit to spare
        let need = bound + 2.0;
        if need <= SigWidth::I8.max_val() as f64 {
            SigWidth::I8
        } else if need <= SigWidth::I16.max_val() as f64 {
            SigWidth::I16
        } else {
            SigWidth::I32
        }
    }

    /// Snapshot tag byte (`EMBS2` store block): the width in bytes.
    pub fn tag(self) -> u8 {
        match self {
            SigWidth::I8 => 1,
            SigWidth::I16 => 2,
            SigWidth::I32 => 4,
        }
    }

    /// Decode a snapshot tag byte.
    pub fn from_tag(t: u8) -> Option<SigWidth> {
        match t {
            1 => Some(SigWidth::I8),
            2 => Some(SigWidth::I16),
            4 => Some(SigWidth::I32),
            _ => None,
        }
    }

    /// Stable human/JSON spelling (`i8` / `i16` / `i32`).
    pub fn name(self) -> &'static str {
        match self {
            SigWidth::I8 => "i8",
            SigWidth::I16 => "i16",
            SigWidth::I32 => "i32",
        }
    }
}

/// A borrowed signature row at its storage width.
///
/// Consumers that need bucket ids widen through [`SigRef::get`] /
/// [`SigRef::to_i32_vec`]; widening is total, so probe keys and
/// fingerprints computed from a narrowed row are identical to the `i32`
/// path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SigRef<'a> {
    /// 1-byte components
    I8(&'a [i8]),
    /// 2-byte components
    I16(&'a [i16]),
    /// 4-byte components
    I32(&'a [i32]),
}

impl SigRef<'_> {
    /// Number of components.
    pub fn len(&self) -> usize {
        match self {
            SigRef::I8(s) => s.len(),
            SigRef::I16(s) => s.len(),
            SigRef::I32(s) => s.len(),
        }
    }

    /// True when the row has no components.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage width of the row.
    pub fn width(&self) -> SigWidth {
        match self {
            SigRef::I8(_) => SigWidth::I8,
            SigRef::I16(_) => SigWidth::I16,
            SigRef::I32(_) => SigWidth::I32,
        }
    }

    /// Component `j`, widened to `i32`.
    pub fn get(&self, j: usize) -> i32 {
        match self {
            SigRef::I8(s) => s[j] as i32,
            SigRef::I16(s) => s[j] as i32,
            SigRef::I32(s) => s[j],
        }
    }

    /// Iterate the components widened to `i32`.
    pub fn iter_i32(&self) -> impl Iterator<Item = i32> + '_ {
        (0..self.len()).map(move |j| self.get(j))
    }

    /// Copy out as an owned `i32` signature.
    pub fn to_i32_vec(&self) -> Vec<i32> {
        self.iter_i32().collect()
    }

    /// Value-equality against an `i32` signature.
    pub fn eq_i32(&self, want: &[i32]) -> bool {
        self.len() == want.len() && self.iter_i32().zip(want).all(|(a, &b)| a == b)
    }
}

/// An owned signature at a fixed storage width — what the entry store
/// keeps per corpus id (2–4× smaller than the seed `Vec<i32>` when the
/// configured range admits a narrow width).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SigVec {
    /// 1-byte components
    I8(Box<[i8]>),
    /// 2-byte components
    I16(Box<[i16]>),
    /// 4-byte components (seed layout)
    I32(Box<[i32]>),
}

impl SigVec {
    /// Narrow an `i32` signature to `width`, failing with a typed error
    /// on the first component outside the width's range.
    pub fn from_i32(sig: &[i32], width: SigWidth) -> Result<SigVec, HashOverflow> {
        if sig.iter().any(|&v| !width.admits(v)) {
            return Err(HashOverflow { width });
        }
        Ok(match width {
            SigWidth::I8 => SigVec::I8(sig.iter().map(|&v| v as i8).collect()),
            SigWidth::I16 => SigVec::I16(sig.iter().map(|&v| v as i16).collect()),
            SigWidth::I32 => SigVec::I32(sig.into()),
        })
    }

    /// Copy a borrowed row at its own width.
    pub fn from_ref(r: SigRef<'_>) -> SigVec {
        match r {
            SigRef::I8(s) => SigVec::I8(s.into()),
            SigRef::I16(s) => SigVec::I16(s.into()),
            SigRef::I32(s) => SigVec::I32(s.into()),
        }
    }

    /// Borrow at the storage width.
    pub fn view(&self) -> SigRef<'_> {
        match self {
            SigVec::I8(s) => SigRef::I8(s),
            SigVec::I16(s) => SigRef::I16(s),
            SigVec::I32(s) => SigRef::I32(s),
        }
    }

    /// Number of components.
    pub fn len(&self) -> usize {
        self.view().len()
    }

    /// True when the signature has no components.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage width.
    pub fn width(&self) -> SigWidth {
        self.view().width()
    }

    /// Widen to the seed `i32` layout.
    pub fn to_i32_vec(&self) -> Vec<i32> {
        self.view().to_i32_vec()
    }

    /// Re-encode at `width` (widening is total; narrowing is checked).
    pub fn requantize(&self, width: SigWidth) -> Result<SigVec, HashOverflow> {
        if self.width() == width {
            return Ok(self.clone());
        }
        SigVec::from_i32(&self.to_i32_vec(), width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_hash_is_exact_in_range() {
        assert_eq!(quantize_hash(0.0), Ok(0));
        assert_eq!(quantize_hash(-0.25), Ok(-1));
        assert_eq!(quantize_hash(3.999), Ok(3));
        assert_eq!(quantize_hash(i32::MAX as f64), Ok(i32::MAX));
        assert_eq!(quantize_hash(i32::MIN as f64), Ok(i32::MIN));
        // the floor of a value just under MIN+1 is still MIN
        assert_eq!(quantize_hash(i32::MIN as f64 + 0.5), Ok(i32::MIN));
    }

    #[test]
    fn quantize_hash_rejects_overflow_and_nan() {
        // the seed cast saturated all of these to MAX/MIN/0 silently
        assert!(quantize_hash(i32::MAX as f64 + 1.0).is_err());
        assert!(quantize_hash(i32::MIN as f64 - 1.0).is_err());
        assert!(quantize_hash(1e300).is_err());
        assert!(quantize_hash(-1e300).is_err());
        assert!(quantize_hash(f64::INFINITY).is_err());
        assert!(quantize_hash(f64::NEG_INFINITY).is_err());
        assert!(quantize_hash(f64::NAN).is_err());
        let e = quantize_hash(f64::NAN).unwrap_err();
        assert!(e.to_string().contains("hash overflow"), "{e}");
    }

    #[test]
    fn width_fitting_picks_narrowest_provable() {
        assert_eq!(SigWidth::fitting(0.0), SigWidth::I8);
        assert_eq!(SigWidth::fitting(100.0), SigWidth::I8);
        assert_eq!(SigWidth::fitting(125.0), SigWidth::I8);
        assert_eq!(SigWidth::fitting(126.0), SigWidth::I16);
        assert_eq!(SigWidth::fitting(30_000.0), SigWidth::I16);
        assert_eq!(SigWidth::fitting(32_766.0), SigWidth::I32);
        assert_eq!(SigWidth::fitting(1e9), SigWidth::I32);
        assert_eq!(SigWidth::fitting(f64::INFINITY), SigWidth::I32);
        assert_eq!(SigWidth::fitting(f64::NAN), SigWidth::I32);
        assert_eq!(SigWidth::fitting(-1.0), SigWidth::I32);
    }

    #[test]
    fn width_admits_exact_edges() {
        for w in [SigWidth::I8, SigWidth::I16, SigWidth::I32] {
            assert!(w.admits(w.max_val()));
            assert!(w.admits(w.min_val()));
            assert!(w.admits(0));
            if w != SigWidth::I32 {
                assert!(!w.admits(w.max_val() + 1));
                assert!(!w.admits(w.min_val() - 1));
            }
            assert_eq!(SigWidth::from_tag(w.tag()), Some(w));
        }
        assert_eq!(SigWidth::from_tag(0), None);
        assert_eq!(SigWidth::from_tag(3), None);
        assert_eq!(SigWidth::from_tag(8), None);
    }

    #[test]
    fn sigvec_roundtrips_at_every_width() {
        let sig = vec![-128, -1, 0, 1, 127];
        for w in [SigWidth::I8, SigWidth::I16, SigWidth::I32] {
            let v = SigVec::from_i32(&sig, w).unwrap();
            assert_eq!(v.width(), w);
            assert_eq!(v.len(), sig.len());
            assert_eq!(v.to_i32_vec(), sig);
            assert!(v.view().eq_i32(&sig));
            assert_eq!(v.view().iter_i32().collect::<Vec<_>>(), sig);
            // requantize: widen then narrow back
            let wide = v.requantize(SigWidth::I32).unwrap();
            assert_eq!(wide.requantize(w).unwrap(), v);
        }
    }

    #[test]
    fn sigvec_narrowing_is_checked_at_the_edge() {
        assert!(SigVec::from_i32(&[127], SigWidth::I8).is_ok());
        assert!(SigVec::from_i32(&[128], SigWidth::I8).is_err());
        assert!(SigVec::from_i32(&[-128], SigWidth::I8).is_ok());
        assert!(SigVec::from_i32(&[-129], SigWidth::I8).is_err());
        assert!(SigVec::from_i32(&[32767], SigWidth::I16).is_ok());
        assert!(SigVec::from_i32(&[32768], SigWidth::I16).is_err());
        assert!(SigVec::from_i32(&[-32768], SigWidth::I16).is_ok());
        assert!(SigVec::from_i32(&[-32769], SigWidth::I16).is_err());
        let e = SigVec::from_i32(&[1 << 20], SigWidth::I8).unwrap_err();
        assert_eq!(e.width, SigWidth::I8);
    }
}
