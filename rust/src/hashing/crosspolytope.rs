//! Cross-polytope LSH (Andoni, Indyk, Laarhoven, Razenshteyn, Schmidt —
//! NeurIPS 2015): the asymptotically optimal angular-distance hash, a
//! drop-in upgrade over SimHash for the paper's cosine-similarity
//! pipeline (each hash yields one of `2N` buckets instead of 2, so far
//! fewer hashes are needed per table).
//!
//! `h(x) = argmax_i |(Rx)_i|` with the sign of that coordinate, where `R`
//! is a pseudo-random rotation implemented as three rounds of
//! `H · D_r` (fast Hadamard transform × random ±1 diagonal) — `O(N log N)`
//! per hash instead of the `O(N²)` dense rotation.

use crate::util::rng::Rng64;

/// One cross-polytope hash: a keyed pseudo-rotation + argmax bucket.
#[derive(Debug, Clone)]
pub struct CrossPolytopeHash {
    /// three ±1 diagonals (one per HD round)
    diagonals: [Vec<f64>; 3],
    /// padded (power-of-two) dimension
    dim_padded: usize,
    /// input dimension
    dim: usize,
}

impl CrossPolytopeHash {
    /// A hash over input dimension `dim` (internally padded to the next
    /// power of two for the Hadamard transform).
    pub fn new(dim: usize, rng: &mut dyn Rng64) -> Self {
        assert!(dim > 0);
        let dim_padded = dim.next_power_of_two();
        let make_diag = |rng: &mut dyn Rng64| -> Vec<f64> {
            (0..dim_padded)
                .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
                .collect()
        };
        let diagonals = [make_diag(rng), make_diag(rng), make_diag(rng)];
        Self {
            diagonals,
            dim_padded,
            dim,
        }
    }

    /// Apply the pseudo-rotation `H D₃ H D₂ H D₁` to `x` into `buf`
    /// (zero-padded). Buffer is caller-provided so banks can hash without
    /// per-call allocation (measured neutral at dim 64 — the FWHT
    /// butterflies dominate — but it keeps the hot loop allocation-free
    /// for larger dims; see EXPERIMENTS.md §Perf).
    fn rotate_into(&self, x: &[f64], buf: &mut Vec<f64>) {
        buf.clear();
        buf.resize(self.dim_padded, 0.0);
        buf[..x.len()].copy_from_slice(x);
        for d in &self.diagonals {
            for (vi, di) in buf.iter_mut().zip(d) {
                *vi *= di;
            }
            fwht(buf);
        }
    }

    /// Bucket id in `0..2·dim_padded`: `2i` for the max coordinate `i`
    /// when positive, `2i + 1` when negative.
    pub fn hash_one(&self, x: &[f64]) -> i32 {
        let mut buf = Vec::new();
        self.hash_one_with(x, &mut buf)
    }

    /// Allocation-free variant of [`CrossPolytopeHash::hash_one`].
    pub fn hash_one_with(&self, x: &[f64], buf: &mut Vec<f64>) -> i32 {
        assert_eq!(x.len(), self.dim);
        self.rotate_into(x, buf);
        let v: &[f64] = buf;
        let mut best = 0usize;
        let mut best_abs = f64::NEG_INFINITY;
        for (i, &vi) in v.iter().enumerate() {
            if vi.abs() > best_abs {
                best_abs = vi.abs();
                best = i;
            }
        }
        (2 * best) as i32 + if v[best] < 0.0 { 1 } else { 0 }
    }
}

/// In-place fast Walsh–Hadamard transform, normalized by `1/√n` so the
/// rotation is an isometry. `v.len()` must be a power of two.
pub fn fwht(v: &mut [f64]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(h * 2) {
            for i in start..start + h {
                let (a, b) = (v[i], v[i + h]);
                v[i] = a + b;
                v[i + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f64).sqrt();
    for vi in v.iter_mut() {
        *vi *= scale;
    }
}

/// A bank of independent cross-polytope hashes, matching the
/// [`super::HashBank`] interface.
#[derive(Debug, Clone)]
pub struct CrossPolytopeBank {
    hashes: Vec<CrossPolytopeHash>,
    dim: usize,
}

impl CrossPolytopeBank {
    /// A bank of `k` independent hashes over dimension `dim`.
    pub fn new(dim: usize, k: usize, rng: &mut dyn Rng64) -> Self {
        let hashes = (0..k).map(|_| CrossPolytopeHash::new(dim, rng)).collect();
        Self { hashes, dim }
    }
}

impl super::HashBank for CrossPolytopeBank {
    fn num_hashes(&self) -> usize {
        self.hashes.len()
    }

    fn input_dim(&self) -> Option<usize> {
        Some(self.dim)
    }

    fn hash(&self, v: &[f64]) -> Vec<i32> {
        let mut buf = Vec::new();
        self.hashes
            .iter()
            .map(|h| h.hash_one_with(v, &mut buf))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::HashBank;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn fwht_is_isometry() {
        let mut v = vec![1.0, -2.0, 3.0, 0.5, 0.0, 1.5, -1.0, 2.0];
        let before: f64 = v.iter().map(|x| x * x).sum();
        fwht(&mut v);
        let after: f64 = v.iter().map(|x| x * x).sum();
        assert!((before - after).abs() < 1e-12);
    }

    #[test]
    fn fwht_matches_hadamard_matrix_2x2() {
        let mut v = vec![3.0, 1.0];
        fwht(&mut v);
        let s = 1.0 / 2.0f64.sqrt();
        assert!((v[0] - 4.0 * s).abs() < 1e-12);
        assert!((v[1] - 2.0 * s).abs() < 1e-12);
    }

    #[test]
    fn scale_invariance_and_determinism() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let h = CrossPolytopeHash::new(10, &mut rng);
        let x: Vec<f64> = (0..10).map(|i| (i as f64).sin()).collect();
        assert_eq!(h.hash_one(&x), h.hash_one(&x));
        let scaled: Vec<f64> = x.iter().map(|v| v * 7.0).collect();
        assert_eq!(h.hash_one(&x), h.hash_one(&scaled));
    }

    #[test]
    fn antipodal_points_never_collide() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let bank = CrossPolytopeBank::new(8, 64, &mut rng);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.7).cos()).collect();
        let neg: Vec<f64> = x.iter().map(|v| -v).collect();
        let hx = bank.hash(&x);
        let hn = bank.hash(&neg);
        // -x flips the argmax sign bit: zero collisions
        assert!(hx.iter().zip(&hn).all(|(a, b)| a != b));
    }

    #[test]
    fn collision_rate_monotone_in_angle() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let dim = 16;
        let bank = CrossPolytopeBank::new(dim, 4000, &mut rng);
        let x: Vec<f64> = (0..dim).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        let rate = |cos_theta: f64| {
            let sin = (1.0 - cos_theta * cos_theta).sqrt();
            let mut y = vec![0.0; dim];
            y[0] = cos_theta;
            y[1] = sin;
            let hx = bank.hash(&x);
            let hy = bank.hash(&y);
            hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64 / hx.len() as f64
        };
        let r_close = rate(0.95);
        let r_mid = rate(0.6);
        let r_far = rate(0.0);
        assert!(
            r_close > r_mid && r_mid > r_far,
            "{r_close} > {r_mid} > {r_far} violated"
        );
    }

    #[test]
    fn more_selective_than_simhash_at_same_k() {
        // At 90° (cossim 0) SimHash collides half the time; cross-polytope
        // collides far less (1/(2N)-ish) — the selectivity win.
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let dim = 16;
        let bank = CrossPolytopeBank::new(dim, 4000, &mut rng);
        let x: Vec<f64> = (0..dim).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        let mut y = vec![0.0; dim];
        y[1] = 1.0;
        let hx = bank.hash(&x);
        let hy = bank.hash(&y);
        let rate =
            hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64 / hx.len() as f64;
        assert!(rate < 0.15, "orthogonal collision rate {rate} (simhash would be 0.5)");
    }

    #[test]
    fn bucket_ids_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let dim = 10; // pads to 16
        let bank = CrossPolytopeBank::new(dim, 100, &mut rng);
        let x: Vec<f64> = (0..dim).map(|i| (i as f64 * 1.3).sin()).collect();
        for b in bank.hash(&x) {
            assert!((0..32).contains(&b), "bucket {b}");
        }
    }
}
