//! Asymmetric LSH for Maximum Inner Product Search (MIPS) — the extension
//! the paper's conclusion singles out (Shrivastava & Li 2014; 2015), plus
//! the KL-divergence-as-MIPS reduction sketched there.
//!
//! MIPS is not directly LSH-able (inner product violates the triangle-ish
//! requirements), but becomes so after *asymmetric* preprocessing:
//!
//! * **L2-ALSH** (2014): scale data to norm ≤ U < 1, append the norm powers
//!   `‖x‖², ‖x‖⁴, …, ‖x‖^{2^m}` to data points and constants ½ to queries;
//!   then argmax⟨q,x⟩ = argmin‖Q(q) − P(x)‖₂ up to vanishing error, so the
//!   2-stable hash applies.
//! * **Sign-ALSH** (2015, improved): same idea with SimHash on
//!   `P(x) = [x; ½ − ‖x‖²; …]`, `Q(q) = [q; ½; …]`.

use super::{HashBank, PStableHashBank, SimHashBank};
use crate::util::rng::Rng64;

/// The asymmetric transform pair of L2-ALSH (Shrivastava & Li 2014).
#[derive(Debug, Clone)]
pub struct L2Alsh {
    /// number of norm-augmentation terms `m`
    pub m: usize,
    /// scaling bound `U < 1`
    pub u: f64,
    /// max data norm observed at build time (data are scaled by `u / max`)
    scale: f64,
    bank: PStableHashBank,
    dim: usize,
}

impl L2Alsh {
    /// Build an L2-ALSH over data dimension `dim` with `k` hashes.
    ///
    /// `max_norm` is the largest ‖x‖₂ in the dataset (used to scale all
    /// data into the U-ball). Standard parameters `m = 3`, `u = 0.83`,
    /// `r = 2.5` follow the paper's recommendation.
    pub fn new(dim: usize, k: usize, max_norm: f64, rng: &mut dyn Rng64) -> Self {
        let m = 3;
        let u = 0.83;
        assert!(max_norm > 0.0);
        let bank = PStableHashBank::new(dim + m, k, 2.0, 2.5, rng);
        Self {
            m,
            u,
            scale: u / max_norm,
            bank,
            dim,
        }
    }

    /// Preprocess a *data* point: `P(x) = [Sx; ‖Sx‖²; …; ‖Sx‖^{2^m}]`.
    pub fn preprocess_data(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.dim);
        let mut out: Vec<f64> = x.iter().map(|v| v * self.scale).collect();
        let mut norm_sq: f64 = out.iter().map(|v| v * v).sum();
        for _ in 0..self.m {
            out.push(norm_sq);
            norm_sq = norm_sq * norm_sq;
        }
        out
    }

    /// Preprocess a *query* point: `Q(q) = [q/‖q‖; ½; …; ½]`.
    pub fn preprocess_query(&self, q: &[f64]) -> Vec<f64> {
        assert_eq!(q.len(), self.dim);
        let norm: f64 = q.iter().map(|v| v * v).sum::<f64>().sqrt();
        let inv = if norm > 0.0 { 1.0 / norm } else { 0.0 };
        let mut out: Vec<f64> = q.iter().map(|v| v * inv).collect();
        out.extend(std::iter::repeat_n(0.5, self.m));
        out
    }

    /// Hash a preprocessed vector.
    pub fn hash(&self, augmented: &[f64]) -> Vec<i32> {
        self.bank.hash(augmented)
    }

    /// Convenience: hash a raw data point.
    pub fn hash_data(&self, x: &[f64]) -> Vec<i32> {
        self.hash(&self.preprocess_data(x))
    }

    /// Convenience: hash a raw query point.
    pub fn hash_query(&self, q: &[f64]) -> Vec<i32> {
        self.hash(&self.preprocess_query(q))
    }
}

/// Sign-ALSH (Shrivastava & Li 2015): the improved MIPS hash using SimHash
/// over `P(x) = [Sx; ½ − ‖Sx‖²; …]`, `Q(q) = [q̂; 0; …]`.
#[derive(Debug, Clone)]
pub struct SignAlsh {
    /// number of augmentation terms `m`
    pub m: usize,
    /// scaling bound `U`
    pub u: f64,
    scale: f64,
    bank: SimHashBank,
    dim: usize,
}

impl SignAlsh {
    /// Build a Sign-ALSH over data dimension `dim` with `k` sign hashes.
    /// Recommended parameters `m = 2`, `U = 0.75` (2015 paper).
    pub fn new(dim: usize, k: usize, max_norm: f64, rng: &mut dyn Rng64) -> Self {
        let m = 2;
        let u = 0.75;
        assert!(max_norm > 0.0);
        let bank = SimHashBank::new(dim + m, k, rng);
        Self {
            m,
            u,
            scale: u / max_norm,
            bank,
            dim,
        }
    }

    /// `P(x) = [Sx; ½ − ‖Sx‖²; ½ − ‖Sx‖⁴; …]`.
    pub fn preprocess_data(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.dim);
        let mut out: Vec<f64> = x.iter().map(|v| v * self.scale).collect();
        let mut norm_sq: f64 = out.iter().map(|v| v * v).sum();
        for _ in 0..self.m {
            out.push(0.5 - norm_sq);
            norm_sq = norm_sq * norm_sq;
        }
        out
    }

    /// `Q(q) = [q̂; 0; …; 0]`.
    pub fn preprocess_query(&self, q: &[f64]) -> Vec<f64> {
        assert_eq!(q.len(), self.dim);
        let norm: f64 = q.iter().map(|v| v * v).sum::<f64>().sqrt();
        let inv = if norm > 0.0 { 1.0 / norm } else { 0.0 };
        let mut out: Vec<f64> = q.iter().map(|v| v * inv).collect();
        out.extend(std::iter::repeat_n(0.0, self.m));
        out
    }

    /// Hash a raw data point.
    pub fn hash_data(&self, x: &[f64]) -> Vec<i32> {
        self.bank.hash(&self.preprocess_data(x))
    }

    /// Hash a raw query point.
    pub fn hash_query(&self, q: &[f64]) -> Vec<i32> {
        self.bank.hash(&self.preprocess_query(q))
    }
}

/// The KL-divergence → MIPS reduction from the paper's conclusion:
///
/// `D_KL(p ‖ q) ∝ 1 − ⟨p, log q⟩ / ⟨p, log p⟩` for fixed `p`, so finding
/// the `q` minimizing KL divergence from a query `p` is a maximum inner
/// product search between the embedded density `p` and embedded
/// log-densities `log q`. Given vectors of density samples on a shared
/// grid, this helper produces the MIPS pair.
pub fn kl_as_mips(p_samples: &[f64], log_q_samples: &[f64]) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(p_samples.len(), log_q_samples.len());
    (p_samples.to_vec(), log_q_samples.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    /// Empirical collision rate between a query and a data point.
    fn collision_rate(hq: &[i32], hd: &[i32]) -> f64 {
        hq.iter().zip(hd).filter(|(a, b)| a == b).count() as f64 / hq.len() as f64
    }

    #[test]
    fn l2_alsh_prefers_larger_inner_product() {
        // Data points with equal direction but different norms: the one
        // with the larger inner product with q must collide more often.
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let dim = 8;
        let alsh = L2Alsh::new(dim, 20_000, 2.0, &mut rng);
        let q: Vec<f64> = (0..dim).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        let big: Vec<f64> = (0..dim).map(|i| if i == 0 { 2.0 } else { 0.0 }).collect();
        let small: Vec<f64> = (0..dim).map(|i| if i == 0 { 0.4 } else { 0.0 }).collect();
        let hq = alsh.hash_query(&q);
        let r_big = collision_rate(&hq, &alsh.hash_data(&big));
        let r_small = collision_rate(&hq, &alsh.hash_data(&small));
        assert!(
            r_big > r_small + 0.02,
            "big ip rate {r_big} vs small ip rate {r_small}"
        );
    }

    #[test]
    fn sign_alsh_prefers_larger_inner_product() {
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let dim = 8;
        let alsh = SignAlsh::new(dim, 20_000, 2.0, &mut rng);
        let q: Vec<f64> = (0..dim).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect();
        let big: Vec<f64> = (0..dim).map(|i| if i == 0 { 2.0 } else { 0.0 }).collect();
        let neg: Vec<f64> = (0..dim).map(|i| if i == 0 { -2.0 } else { 0.0 }).collect();
        let hq = alsh.hash_query(&q);
        let r_big = collision_rate(&hq, &alsh.hash_data(&big));
        let r_neg = collision_rate(&hq, &alsh.hash_data(&neg));
        assert!(r_big > r_neg + 0.2, "aligned {r_big} vs opposed {r_neg}");
    }

    #[test]
    fn preprocess_shapes() {
        let mut rng = Xoshiro256pp::seed_from_u64(25);
        let alsh = L2Alsh::new(4, 8, 1.0, &mut rng);
        assert_eq!(alsh.preprocess_data(&[1.0, 0.0, 0.0, 0.0]).len(), 7);
        assert_eq!(alsh.preprocess_query(&[1.0, 0.0, 0.0, 0.0]).len(), 7);
        let s = SignAlsh::new(4, 8, 1.0, &mut rng);
        assert_eq!(s.preprocess_data(&[1.0, 0.0, 0.0, 0.0]).len(), 6);
    }

    #[test]
    fn data_scaled_into_u_ball() {
        let mut rng = Xoshiro256pp::seed_from_u64(27);
        let alsh = L2Alsh::new(2, 4, 10.0, &mut rng);
        let p = alsh.preprocess_data(&[10.0, 0.0]);
        let norm_sq: f64 = p[..2].iter().map(|v| v * v).sum();
        assert!((norm_sq.sqrt() - 0.83).abs() < 1e-12);
    }

    #[test]
    fn kl_mips_pair_shapes() {
        let (a, b) = kl_as_mips(&[0.1, 0.9], &[-2.3, -0.1]);
        assert_eq!(a.len(), b.len());
    }
}
