//! LSH families on `ℝ^N` — the hash functions the embeddings feed.
//!
//! * [`PStableHashBank`] — the `ℓ^p`-distance hash of Datar et al. (2004)
//!   for any `p ∈ (0, 2]`: `h(x) = ⌊(α·x)/r + b⌋` with `α` i.i.d. p-stable.
//! * [`SimHashBank`] — Charikar's (2002) sign-random-projection hash for
//!   cosine similarity.
//! * [`LazyL2Hash`] — Algorithm 1 of the paper: the 2-stable hash with a
//!   *virtually infinite* coefficient vector. Coefficients `α_i` are drawn
//!   from a keyed counter-based stream, so inputs of any dimension `N_f`
//!   hash consistently without storing or bounding `α` (the paper's lazy
//!   extension), and coefficient `i` is identical no matter which input
//!   lengths were seen before.
//! * [`alsh`] — the asymmetric LSH constructions for maximum inner product
//!   search (Shrivastava & Li 2014, 2015) the paper's conclusion points to,
//!   plus the KL-divergence-as-MIPS reduction.

pub mod alsh;
pub mod crosspolytope;
pub mod quantize;

pub use crosspolytope::{CrossPolytopeBank, CrossPolytopeHash};
pub use quantize::{quantize_hash, HashOverflow, SigRef, SigVec, SigWidth};

use crate::util::rng::{Rng64, SplitMix64};
use crate::util::sync;

/// A bank of `K` hash functions mapping `ℝ^N → ℤ^K`.
///
/// Banks are the unit the LSH index consumes: `K = k·L` hashes are split
/// into `L` tables of `k` concatenated hashes each.
pub trait HashBank: Send + Sync {
    /// Number of hash functions in the bank.
    fn num_hashes(&self) -> usize;

    /// Input dimensionality (`None` if the bank accepts any length, like
    /// [`LazyL2Hash`]).
    fn input_dim(&self) -> Option<usize>;

    /// Hash a vector with every function in the bank.
    fn hash(&self, v: &[f64]) -> Vec<i32>;

    /// Hash a vector into a caller-provided buffer of length
    /// [`HashBank::num_hashes`] — the allocation-free form the batched
    /// request path uses. The default delegates to [`HashBank::hash`];
    /// the in-tree banks override it to write `out` directly.
    fn hash_into(&self, v: &[f64], out: &mut [i32]) {
        out.copy_from_slice(&self.hash(v));
    }

    /// Checked form of [`HashBank::hash_into`]: hash values that fall
    /// outside the `i32` range (or are not finite) return
    /// [`HashOverflow`] instead of silently saturating. The default
    /// delegates to `hash_into` and always succeeds — correct for banks
    /// whose outputs are range-bounded by construction (e.g.
    /// [`SimHashBank`], which emits only `0`/`1`); the floor-hash banks
    /// override it with a [`quantize_hash`]-checked loop.
    fn try_hash_into(&self, v: &[f64], out: &mut [i32]) -> Result<(), HashOverflow> {
        self.hash_into(v, out);
        Ok(())
    }
}

/// A single vector hash function `ℝ^N → ℤ`.
pub trait VectorHash: Send + Sync {
    /// Hash one vector.
    fn hash_one(&self, v: &[f64]) -> i32;
}

/// The p-stable `ℓ^p`-distance hash bank (Datar et al. 2004):
/// `h_j(x) = ⌊(α_j · x) / r + b_j⌋`, `α_j` i.i.d. p-stable,
/// `b_j ~ U[0, 1)`.
///
/// Collision probability decreases monotonically in `‖x − y‖_p`; see
/// [`crate::theory::pstable_collision_probability`].
#[derive(Debug, Clone)]
pub struct PStableHashBank {
    /// projection matrix, row-major `[K][N]`
    proj: Vec<f64>,
    /// offsets `b_j ∈ [0, 1)` (pre-scaled convention: the hash computes
    /// `⌊ proj·x / r + b ⌋` with `b` in *bucket* units)
    offsets: Vec<f64>,
    dim: usize,
    k: usize,
    r: f64,
    p: f64,
}

impl PStableHashBank {
    /// A bank of `k` hashes over dimension `dim` with bucket width `r` and
    /// stability index `p` (2 = Gaussian/L², 1 = Cauchy/L¹).
    pub fn new(dim: usize, k: usize, p: f64, r: f64, rng: &mut dyn Rng64) -> Self {
        assert!(dim > 0 && k > 0 && r > 0.0);
        assert!(p > 0.0 && p <= 2.0);
        let mut proj = Vec::with_capacity(k * dim);
        for _ in 0..k * dim {
            proj.push(rng.stable(p));
        }
        let offsets = (0..k).map(|_| rng.uniform()).collect();
        Self {
            proj,
            offsets,
            dim,
            k,
            r,
            p,
        }
    }

    /// Bucket width `r`.
    pub fn r(&self) -> f64 {
        self.r
    }

    /// Stability index `p`.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// The projection row of hash `j` (for AOT export: the L2 pipeline
    /// bakes this matrix into the HLO-executed computation).
    pub fn projection_row(&self, j: usize) -> &[f64] {
        &self.proj[j * self.dim..(j + 1) * self.dim]
    }

    /// The offsets `b_j` (bucket units).
    pub fn offsets(&self) -> &[f64] {
        &self.offsets
    }
}

impl HashBank for PStableHashBank {
    fn num_hashes(&self) -> usize {
        self.k
    }

    fn input_dim(&self) -> Option<usize> {
        Some(self.dim)
    }

    fn hash(&self, v: &[f64]) -> Vec<i32> {
        let mut out = vec![0i32; self.k];
        self.hash_into(v, &mut out);
        out
    }

    fn hash_into(&self, v: &[f64], out: &mut [i32]) {
        self.try_hash_into(v, out)
            .expect("hash value overflows the signature range (use try_hash_into)");
    }

    fn try_hash_into(&self, v: &[f64], out: &mut [i32]) -> Result<(), HashOverflow> {
        assert_eq!(v.len(), self.dim, "input dimension mismatch");
        assert_eq!(out.len(), self.k, "output length mismatch");
        for (j, o) in out.iter_mut().enumerate() {
            let row = &self.proj[j * self.dim..(j + 1) * self.dim];
            let dot: f64 = row.iter().zip(v).map(|(a, x)| a * x).sum();
            *o = quantize_hash(dot / self.r + self.offsets[j])?;
        }
        Ok(())
    }
}

/// SimHash (Charikar 2002): `h_j(x) = sign(α_j · x)` with Gaussian `α_j`.
/// Collision probability `1 − θ(x, y)/π` where `θ` is the angle between
/// the vectors (Eq. 7 of the paper).
#[derive(Debug, Clone)]
pub struct SimHashBank {
    proj: Vec<f64>,
    dim: usize,
    k: usize,
}

impl SimHashBank {
    /// A bank of `k` sign hashes over dimension `dim`.
    pub fn new(dim: usize, k: usize, rng: &mut dyn Rng64) -> Self {
        assert!(dim > 0 && k > 0);
        let proj = (0..k * dim).map(|_| rng.normal()).collect();
        Self { proj, dim, k }
    }

    /// Pack the sign bits into `u64` words (bit `j % 64` of word `j / 64`),
    /// for Hamming-style storage.
    pub fn hash_packed(&self, v: &[f64]) -> Vec<u64> {
        let bits = self.hash(v);
        let mut words = vec![0u64; self.k.div_ceil(64)];
        for (j, &b) in bits.iter().enumerate() {
            if b == 1 {
                words[j / 64] |= 1 << (j % 64);
            }
        }
        words
    }
}

impl HashBank for SimHashBank {
    fn num_hashes(&self) -> usize {
        self.k
    }

    fn input_dim(&self) -> Option<usize> {
        Some(self.dim)
    }

    fn hash(&self, v: &[f64]) -> Vec<i32> {
        let mut out = vec![0i32; self.k];
        self.hash_into(v, &mut out);
        out
    }

    fn hash_into(&self, v: &[f64], out: &mut [i32]) {
        assert_eq!(v.len(), self.dim, "input dimension mismatch");
        assert_eq!(out.len(), self.k, "output length mismatch");
        for (j, o) in out.iter_mut().enumerate() {
            let row = &self.proj[j * self.dim..(j + 1) * self.dim];
            let dot: f64 = row.iter().zip(v).map(|(a, x)| a * x).sum();
            *o = if dot >= 0.0 { 1 } else { 0 };
        }
    }
}

/// Algorithm 1 of the paper: the 2-stable hash over coefficient vectors of
/// *unbounded, input-dependent* length `N_f`.
///
/// Instead of materializing `α ∈ ℝ^∞`, coefficient `α_i` of hash `j` is
/// `Φ⁻¹`-free Gaussian generated from a counter-based keyed stream
/// (SplitMix64 keyed by `(seed, j, i)` + polar transform on two lazily
/// drawn uniforms). This realizes the paper's "append new randomly
/// generated coefficients to α when we encounter a new largest value of
/// N_f" — with the stronger property that no mutable state is needed at
/// all, so concurrent hashers on different shards agree bit-for-bit.
#[derive(Debug)]
pub struct LazyL2Hash {
    seed: u64,
    k: usize,
    r: f64,
    offsets: Vec<f64>,
    /// memoized coefficient prefixes, `cache[j][i] == alpha(j, i)`.
    ///
    /// The cache is *pure memoization* of the counter-based stream — the
    /// hash output is identical with or without it — but it removes the
    /// ln/cos/sqrt per coefficient from the hot path (measured ~29×,
    /// EXPERIMENTS.md §Perf). RwLock: concurrent hashers share warm rows.
    cache: std::sync::RwLock<Vec<Vec<f64>>>,
}

impl Clone for LazyL2Hash {
    fn clone(&self) -> Self {
        Self {
            seed: self.seed,
            k: self.k,
            r: self.r,
            offsets: self.offsets.clone(),
            cache: std::sync::RwLock::new(sync::read(&self.cache).clone()),
        }
    }
}

impl LazyL2Hash {
    /// A bank of `k` lazy 2-stable hashes with bucket width `r`.
    pub fn new(seed: u64, k: usize, r: f64) -> Self {
        assert!(k > 0 && r > 0.0);
        let mut sm = SplitMix64::new(seed ^ 0xB0FF5EED);
        let offsets = (0..k).map(|_| sm.uniform()).collect();
        Self {
            seed,
            k,
            r,
            offsets,
            cache: std::sync::RwLock::new(vec![Vec::new(); k]),
        }
    }

    /// Ensure the cached coefficient prefix of every hash covers `len`
    /// entries ("append new randomly generated coefficients to α when we
    /// encounter a new largest value of N_f" — Algorithm 1, memoized).
    fn ensure_cached(&self, len: usize) {
        {
            let cache = sync::read(&self.cache);
            if cache.iter().all(|row| row.len() >= len) {
                return;
            }
        }
        let mut cache = sync::write(&self.cache);
        for (j, row) in cache.iter_mut().enumerate() {
            while row.len() < len {
                row.push(self.alpha(j, row.len()));
            }
        }
    }

    /// The `i`-th Gaussian coefficient of hash function `j` — pure function
    /// of `(seed, j, i)`.
    pub fn alpha(&self, j: usize, i: usize) -> f64 {
        // Derive two independent uniforms from the counter stream and apply
        // Box–Muller (always taking the cosine branch).
        let key = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((j as u64) << 32 | i as u64);
        let u1 = (SplitMix64::nth(key, 1) >> 11) as f64 / 9007199254740992.0;
        let u2 = (SplitMix64::nth(key, 2) >> 11) as f64 / 9007199254740992.0;
        let u1 = u1.max(1e-300); // avoid ln(0)
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bucket width `r`.
    pub fn r(&self) -> f64 {
        self.r
    }
}

impl HashBank for LazyL2Hash {
    fn num_hashes(&self) -> usize {
        self.k
    }

    fn input_dim(&self) -> Option<usize> {
        None // any length: that is the point
    }

    fn hash(&self, v: &[f64]) -> Vec<i32> {
        let mut out = vec![0i32; self.k];
        self.hash_into(v, &mut out);
        out
    }

    fn hash_into(&self, v: &[f64], out: &mut [i32]) {
        self.try_hash_into(v, out)
            .expect("hash value overflows the signature range (use try_hash_into)");
    }

    fn try_hash_into(&self, v: &[f64], out: &mut [i32]) -> Result<(), HashOverflow> {
        assert_eq!(out.len(), self.k, "output length mismatch");
        self.ensure_cached(v.len());
        let cache = sync::read(&self.cache);
        for (j, o) in out.iter_mut().enumerate() {
            let dot: f64 = v.iter().zip(&cache[j]).map(|(&x, &a)| a * x).sum();
            *o = quantize_hash(dot / self.r + self.offsets[j])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::{pstable_collision_probability, simhash_collision_probability};
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn pstable_translation_moves_buckets() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let bank = PStableHashBank::new(4, 16, 2.0, 1.0, &mut rng);
        let x = [0.1, 0.2, 0.3, 0.4];
        let h1 = bank.hash(&x);
        let h2 = bank.hash(&x); // determinism
        assert_eq!(h1, h2);
        let far = [10.1, -10.2, 10.3, -10.4];
        assert_ne!(bank.hash(&far), h1);
    }

    #[test]
    fn pstable_collision_rate_matches_theory_l2() {
        // Empirical collision fraction across a large bank must track the
        // closed-form probability for p = 2.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let dim = 16;
        let k = 20_000;
        let r = 1.0;
        let bank = PStableHashBank::new(dim, k, 2.0, r, &mut rng);
        for &c in &[0.25, 0.5, 1.0, 2.0] {
            let x = vec![0.0; dim];
            let mut y = vec![0.0; dim];
            y[0] = c; // ‖x − y‖₂ = c
            let hx = bank.hash(&x);
            let hy = bank.hash(&y);
            let obs = hx
                .iter()
                .zip(&hy)
                .filter(|(a, b)| a == b)
                .count() as f64
                / k as f64;
            let want = pstable_collision_probability(c, r, 2.0);
            assert!(
                (obs - want).abs() < 0.015,
                "c = {c}: observed {obs}, theory {want}"
            );
        }
    }

    #[test]
    fn pstable_collision_rate_matches_theory_l1() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let dim = 8;
        let k = 20_000;
        let r = 2.0;
        let bank = PStableHashBank::new(dim, k, 1.0, r, &mut rng);
        let x = vec![0.0; dim];
        let mut y = vec![0.0; dim];
        y[0] = 1.0; // ‖x − y‖₁ = 1
        let obs = bank
            .hash(&x)
            .iter()
            .zip(&bank.hash(&y))
            .filter(|(a, b)| a == b)
            .count() as f64
            / k as f64;
        let want = pstable_collision_probability(1.0, r, 1.0);
        assert!((obs - want).abs() < 0.015, "observed {obs}, theory {want}");
    }

    #[test]
    fn simhash_collision_rate_matches_theory() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let dim = 8;
        let k = 20_000;
        let bank = SimHashBank::new(dim, k, &mut rng);
        // vectors at a known angle: cos θ = 0.6
        let x = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let y = [0.6, 0.8, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let obs = bank
            .hash(&x)
            .iter()
            .zip(&bank.hash(&y))
            .filter(|(a, b)| a == b)
            .count() as f64
            / k as f64;
        let want = simhash_collision_probability(0.6);
        assert!((obs - want).abs() < 0.01, "observed {obs}, theory {want}");
    }

    #[test]
    fn simhash_packed_agrees_with_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let bank = SimHashBank::new(4, 100, &mut rng);
        let v = [0.3, -0.7, 0.2, 0.9];
        let bits = bank.hash(&v);
        let packed = bank.hash_packed(&v);
        for (j, &b) in bits.iter().enumerate() {
            let bit = (packed[j / 64] >> (j % 64)) & 1;
            assert_eq!(bit as i32, b);
        }
    }

    #[test]
    fn lazy_hash_prefix_consistency() {
        // Hashing a zero-padded vector must equal hashing the short vector:
        // the sparsity observation of Remark 2.
        let h = LazyL2Hash::new(42, 8, 1.0);
        let short = [0.5, -0.25, 0.125];
        let mut padded = short.to_vec();
        padded.extend_from_slice(&[0.0; 10]);
        assert_eq!(h.hash(&short), h.hash(&padded));
    }

    #[test]
    fn lazy_hash_alpha_is_gaussian() {
        let h = LazyL2Hash::new(7, 1, 1.0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|i| h.alpha(0, i)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lazy_hash_matches_theory() {
        // The lazy bank is a valid 2-stable LSH: collision rates follow Eq. 8.
        let k = 20_000;
        let h = LazyL2Hash::new(11, k, 1.0);
        let x = [0.0, 0.0, 0.0, 0.0];
        let y = [0.5, 0.0, 0.0, 0.0];
        let obs = h
            .hash(&x)
            .iter()
            .zip(&h.hash(&y))
            .filter(|(a, b)| a == b)
            .count() as f64
            / k as f64;
        let want = pstable_collision_probability(0.5, 1.0, 2.0);
        assert!((obs - want).abs() < 0.015, "observed {obs}, theory {want}");
    }

    #[test]
    fn lazy_hash_different_seeds_differ() {
        let a = LazyL2Hash::new(1, 4, 1.0);
        let b = LazyL2Hash::new(2, 4, 1.0);
        let v = [1.0, 2.0, 3.0];
        assert_ne!(a.hash(&v), b.hash(&v));
    }

    // ----- overflow regression tests (the former silent-saturation bug) ----

    #[test]
    fn pstable_huge_norm_row_is_a_typed_error() {
        // A row with astronomically large norm drives |dot/r + b| past
        // i32::MAX. The old code saturated every such hash to i32::MAX,
        // collapsing all huge inputs into one bucket; now it is a typed
        // per-call error.
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let bank = PStableHashBank::new(4, 8, 2.0, 1.0, &mut rng);
        let huge = [1e300, -1e300, 1e300, -1e300];
        let mut out = vec![0i32; 8];
        let err = bank
            .try_hash_into(&huge, &mut out)
            .expect_err("huge-norm row must not hash");
        assert_eq!(err.width, SigWidth::I32);
    }

    #[test]
    fn pstable_nan_dot_is_a_typed_error() {
        // NaN anywhere in the dot product used to floor-cast to 0 —
        // indistinguishable from a legitimate bucket. Now: HashOverflow.
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let bank = PStableHashBank::new(4, 8, 2.0, 1.0, &mut rng);
        let bad = [f64::NAN, 0.0, 0.0, 0.0];
        let mut out = vec![0i32; 8];
        assert!(bank.try_hash_into(&bad, &mut out).is_err());
        // Infinities cancel to NaN in the sum as well.
        let inf = [f64::INFINITY, f64::NEG_INFINITY, 0.0, 0.0];
        assert!(bank.try_hash_into(&inf, &mut out).is_err());
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn pstable_infallible_hash_panics_on_overflow() {
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let bank = PStableHashBank::new(2, 4, 2.0, 1.0, &mut rng);
        bank.hash(&[1e300, 1e300]);
    }

    #[test]
    fn lazy_hash_overflow_is_a_typed_error() {
        let h = LazyL2Hash::new(9, 8, 1.0);
        let mut out = vec![0i32; 8];
        assert!(h.try_hash_into(&[f64::NAN, 1.0], &mut out).is_err());
        assert!(h.try_hash_into(&[1e300, -1e300, 1e300], &mut out).is_err());
        // Sane inputs still succeed and agree with the infallible path.
        let v = [0.5, -0.25, 0.125];
        h.try_hash_into(&v, &mut out).unwrap();
        assert_eq!(out, h.hash(&v));
    }
}
