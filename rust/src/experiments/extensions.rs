//! Extension experiments E4–E9 (DESIGN.md §3): Theorem 1 bound tightness,
//! MC-vs-QMC convergence, end-to-end k-NN recall/speedup, the W¹ hash with
//! its LP and Indyk–Thaper baselines, ALSH/MIPS, and adaptive-N_f ablation.

use crate::chebyshev::ChebyshevSeries;
use crate::embedding::{l2_dist, Embedder, Interval, MonteCarloEmbedder, QmcEmbedder, QmcSequence};
use crate::functions::{Distribution1D, Sine};
use crate::hashing::alsh::SignAlsh;
use crate::hashing::{HashBank, LazyL2Hash, PStableHashBank};
use crate::lsh::{IndexConfig, LshIndex};
use crate::quadrature::lp_distance;
use crate::search::{recall_at_k, BruteForceKnn, LshKnn};
use crate::theory::{
    cauchy_collision_probability, pstable_collision_probability, theorem1_bounds,
};
use crate::util::rng::{Rng64, Xoshiro256pp};
use crate::wasserstein::indyk_thaper::{l1_distance, GridEmbedding};
use crate::wasserstein::{discrete::discrete_wasserstein_1d, wasserstein_empirical, QUANTILE_CLIP};
use crate::workload::{gaussian_pair, gmm_corpus, sine_pair};
use crate::experiments::collision_rate;

// ---------------------------------------------------------------------
// E4: Theorem 1 bound tightness
// ---------------------------------------------------------------------

/// One row of the Theorem 1 experiment: a truncation level and the
/// resulting embedding error / collision probabilities.
#[derive(Debug, Clone, Copy)]
pub struct Thm1Row {
    /// number of retained basis coefficients `N_f`
    pub n_f: usize,
    /// the embedding error bound ε = ‖ε_f‖ + ‖ε_g‖
    pub eps: f64,
    /// observed collision frequency at this truncation
    pub observed: f64,
    /// ideal collision probability P (ε = 0)
    pub p_ideal: f64,
    /// Theorem 1 lower bound
    pub lower: f64,
    /// Theorem 1 upper bound
    pub upper: f64,
}

/// E4: truncate the Chebyshev coefficient embedding of a fixed sine pair
/// at increasing `N_f` and verify the observed collision probability sits
/// inside the Theorem 1 band (which tightens as ε → 0).
pub fn thm1_bounds_experiment(hashes: usize, seed: u64) -> Vec<Thm1Row> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let f = Sine::paper(0.7);
    let g = Sine::paper(2.9);
    let r = 1.0;
    // full-resolution embedding = ground truth coefficients
    let full = 256usize;
    let emb = crate::embedding::ChebyshevEmbedder::new(Interval::unit(), full);
    let tf = emb.embed_fn(&f);
    let tg = emb.embed_fn(&g);
    let c_true = lp_distance(&f, &g, 0.0, 1.0, 2.0);
    let norm_sq = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>();
    let nf2 = norm_sq(&tf);
    let ng2 = norm_sq(&tg);
    let bank = LazyL2Hash::new(rng.next_u64(), hashes, r);

    let mut rows = Vec::new();
    for &n_f in &[4usize, 8, 12, 16, 24, 32, 64] {
        let tfk = &tf[..n_f];
        let tgk = &tg[..n_f];
        // ‖ε_f‖² = ‖f‖² − ‖f̂‖² (the computable-error identity of §3.1)
        let ef = (nf2 - norm_sq(tfk)).max(0.0).sqrt();
        let eg = (ng2 - norm_sq(tgk)).max(0.0).sqrt();
        let eps = ef + eg;
        let observed = collision_rate(&bank.hash(tfk), &bank.hash(tgk));
        let (lower, upper) = theorem1_bounds(c_true, r, 2.0, eps);
        rows.push(Thm1Row {
            n_f,
            eps,
            observed,
            p_ideal: pstable_collision_probability(c_true, r, 2.0),
            lower,
            upper,
        });
    }
    rows
}

// ---------------------------------------------------------------------
// E5: MC vs QMC convergence
// ---------------------------------------------------------------------

/// One row of the convergence sweep.
#[derive(Debug, Clone, Copy)]
pub struct ConvergenceRow {
    /// embedding dimension N
    pub n: usize,
    /// mean |‖T(f)−T(g)‖ − ‖f−g‖| for i.i.d. Monte Carlo
    pub mc_err: f64,
    /// same for Sobol QMC
    pub qmc_err: f64,
    /// same for Halton QMC
    pub halton_err: f64,
}

/// E5: embedding error as a function of N — MC should decay ~N^{-1/2},
/// QMC ~N^{-1} (§3.2 error analysis).
pub fn qmc_convergence(pairs: usize, seed: u64) -> Vec<ConvergenceRow> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let omega = Interval::unit();
    let mut rows = Vec::new();
    for &n in &[16usize, 32, 64, 128, 256, 512] {
        let mut mc_err = 0.0;
        let mut qmc_err = 0.0;
        let mut halton_err = 0.0;
        for _ in 0..pairs {
            let (f, g) = sine_pair(&mut rng);
            let truth = (1.0 - (f.phase - g.phase).cos()).max(0.0).sqrt();
            let mc = MonteCarloEmbedder::new(omega, n, 2.0, &mut rng);
            mc_err += (l2_dist(&mc.embed_fn(&f), &mc.embed_fn(&g)) - truth).abs();
            let qe = QmcEmbedder::new(omega, n, 2.0, QmcSequence::Sobol);
            qmc_err += (l2_dist(&qe.embed_fn(&f), &qe.embed_fn(&g)) - truth).abs();
            let he = QmcEmbedder::new(omega, n, 2.0, QmcSequence::Halton);
            halton_err += (l2_dist(&he.embed_fn(&f), &he.embed_fn(&g)) - truth).abs();
        }
        rows.push(ConvergenceRow {
            n,
            mc_err: mc_err / pairs as f64,
            qmc_err: qmc_err / pairs as f64,
            halton_err: halton_err / pairs as f64,
        });
    }
    rows
}

// ---------------------------------------------------------------------
// E6: end-to-end k-NN recall vs speedup
// ---------------------------------------------------------------------

/// Result of the end-to-end k-NN experiment.
#[derive(Debug, Clone, Copy)]
pub struct KnnResult {
    /// corpus size
    pub corpus: usize,
    /// multi-probe depth used
    pub probe_depth: usize,
    /// mean recall@k against exact search
    pub recall: f64,
    /// mean exact-distance evaluations per LSH query
    pub mean_evals: f64,
    /// corpus size / mean_evals — the work reduction factor
    pub speedup: f64,
}

/// E6: index a corpus of GMM quantile functions (W²-style embedding),
/// query held-out distributions, and measure recall@k and the reduction
/// in exact distance evaluations vs brute force.
pub fn knn_experiment(
    corpus_size: usize,
    queries: usize,
    k: usize,
    probe_depth: usize,
    seed: u64,
) -> KnnResult {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let omega = Interval::new(QUANTILE_CLIP, 1.0 - QUANTILE_CLIP);
    let dim = 64;
    let emb = MonteCarloEmbedder::new(omega, dim, 2.0, &mut rng);
    // k=6/l=8 with a narrow bucket keeps the amplified S-curve steep
    // enough that far GMMs (W² ~ 1) rarely become candidates while near
    // ones almost always do (tuned in examples/wasserstein_knn.rs:
    // recall ≈ 0.96 at ~17x pruning on 5k corpora).
    let cfg = IndexConfig::new(6, 8);
    let bank = PStableHashBank::new(dim, cfg.total_hashes(), 2.0, 0.5, &mut rng);

    let corpus = gmm_corpus(corpus_size, &mut rng);
    let vecs: Vec<Vec<f64>> = corpus
        .iter()
        .map(|d| {
            let q = d.quantile_fn();
            emb.embed_fn(&q)
        })
        .collect();
    let mut index = LshIndex::new(cfg);
    for (i, v) in vecs.iter().enumerate() {
        index.insert(i as u64, &bank.hash(v));
    }

    let ids: Vec<u64> = (0..corpus_size as u64).collect();
    let mut recall_acc = 0.0;
    let mut evals_acc = 0.0;
    for _ in 0..queries {
        let qd = crate::workload::random_gmm(1 + rng.uniform_usize(4), &mut rng);
        let qv = emb.embed_fn(&qd.quantile_fn());
        let (exact, _) =
            BruteForceKnn::new(&ids, |id| l2_dist(&qv, &vecs[id as usize])).query(k);
        let engine = LshKnn::new(&index).with_probe_depth(probe_depth);
        let (approx, stats) =
            engine.query(&bank.hash(&qv), k, |id| l2_dist(&qv, &vecs[id as usize]));
        recall_acc += recall_at_k(&exact, &approx, k);
        evals_acc += stats.distance_evals as f64;
    }
    let mean_evals = evals_acc / queries as f64;
    KnnResult {
        corpus: corpus_size,
        probe_depth,
        recall: recall_acc / queries as f64,
        mean_evals,
        speedup: corpus_size as f64 / mean_evals.max(1.0),
    }
}

// ---------------------------------------------------------------------
// E7: W¹ via the Cauchy (1-stable) hash + baselines
// ---------------------------------------------------------------------

/// Result rows for the W¹ experiment.
#[derive(Debug, Clone, Copy)]
pub struct W1Row {
    /// true W¹ distance (quantile quadrature)
    pub w1: f64,
    /// observed collision rate of the 1-stable hash on embedded quantiles
    pub observed: f64,
    /// theoretical Cauchy collision probability at `w1`
    pub theoretical: f64,
    /// discrete LP estimate of W¹ from 64-point discretizations
    pub w1_lp: f64,
    /// Indyk–Thaper ℓ¹ surrogate distance
    pub w1_it: f64,
}

/// E7: hash `W¹` through Eq. 3 with the p = 1 (Cauchy) hash; cross-check
/// the true distance against the discrete LP (Eq. 2) and the
/// Indyk–Thaper grid embedding on the same data.
pub fn w1_experiment(pairs: usize, hashes: usize, seed: u64) -> Vec<W1Row> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let omega = Interval::new(QUANTILE_CLIP, 1.0 - QUANTILE_CLIP);
    let dim = 64;
    let r = 4.0;
    let emb = MonteCarloEmbedder::new(omega, dim, 1.0, &mut rng);
    let bank = PStableHashBank::new(dim, hashes, 1.0, r, &mut rng);
    let grid = GridEmbedding::new(8);

    let mut rows = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let (a, b) = gaussian_pair(&mut rng);
        // ground truth: W¹ = ∫ |F⁻¹ − G⁻¹| via sorted-sample formula on a
        // dense common grid (exact for these step approximations)
        let grid_u: Vec<f64> = (0..2048)
            .map(|i| QUANTILE_CLIP + (1.0 - 2.0 * QUANTILE_CLIP) * (i as f64 + 0.5) / 2048.0)
            .collect();
        let xa: Vec<f64> = grid_u.iter().map(|&u| a.quantile(u)).collect();
        let xb: Vec<f64> = grid_u.iter().map(|&u| b.quantile(u)).collect();
        let w1 = wasserstein_empirical(&xa, &xb, 1.0);

        let qa = a.quantile_fn();
        let qb = b.quantile_fn();
        let ta = emb.embed_fn(&qa);
        let tb = emb.embed_fn(&qb);
        let observed = collision_rate(&bank.hash(&ta), &bank.hash(&tb));

        // discrete LP on 64-point sample discretizations
        let pts: Vec<f64> = (0..64)
            .map(|i| QUANTILE_CLIP + (1.0 - 2.0 * QUANTILE_CLIP) * (i as f64 + 0.5) / 64.0)
            .collect();
        let da: Vec<f64> = pts.iter().map(|&u| a.quantile(u)).collect();
        let db: Vec<f64> = pts.iter().map(|&u| b.quantile(u)).collect();
        let mass = vec![1.0 / 64.0; 64];
        let w1_lp = discrete_wasserstein_1d(&da, &mass, &db, &mass, 1.0);

        // Indyk–Thaper surrogate on positions rescaled to [0,1)
        let rescale = |x: f64| ((x + 4.0) / 8.0).clamp(0.0, 1.0 - 1e-9);
        let pa: Vec<f64> = da.iter().map(|&x| rescale(x)).collect();
        let pb: Vec<f64> = db.iter().map(|&x| rescale(x)).collect();
        let w1_it = l1_distance(&grid.embed(&pa, &mass), &grid.embed(&pb, &mass)) * 8.0;

        rows.push(W1Row {
            w1,
            observed,
            theoretical: cauchy_collision_probability(w1, r),
            w1_lp,
            w1_it,
        });
    }
    rows
}

// ---------------------------------------------------------------------
// E8: ALSH / MIPS
// ---------------------------------------------------------------------

/// Result of the MIPS retrieval experiment.
#[derive(Debug, Clone, Copy)]
pub struct MipsResult {
    /// corpus size
    pub corpus: usize,
    /// recall@1 of the true max-inner-product item via hashed buckets
    pub recall_at_1: f64,
    /// mean rank of the true best item in the hash-collision ordering
    pub mean_rank: f64,
}

/// E8: Sign-ALSH over a random vector corpus; for each query, rank corpus
/// items by hash-collision count and check where the true
/// max-inner-product item lands.
pub fn mips_experiment(corpus_size: usize, queries: usize, hashes: usize, seed: u64) -> MipsResult {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dim = 16;
    // random corpus with varied norms (norm matters for MIPS)
    let corpus: Vec<Vec<f64>> = (0..corpus_size)
        .map(|_| {
            let scale = rng.uniform_in(0.2, 2.0);
            (0..dim).map(|_| scale * rng.normal()).collect()
        })
        .collect();
    let max_norm = corpus
        .iter()
        .map(|v| v.iter().map(|x| x * x).sum::<f64>().sqrt())
        .fold(0.0f64, f64::max);
    let alsh = SignAlsh::new(dim, hashes, max_norm, &mut rng);
    let hashed: Vec<Vec<i32>> = corpus.iter().map(|v| alsh.hash_data(v)).collect();

    let mut hits = 0usize;
    let mut rank_acc = 0.0;
    for _ in 0..queries {
        let q: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let hq = alsh.hash_query(&q);
        // true best by inner product
        let best = (0..corpus_size)
            .max_by(|&i, &j| {
                let ip = |v: &Vec<f64>| v.iter().zip(&q).map(|(a, b)| a * b).sum::<f64>();
                ip(&corpus[i]).total_cmp(&ip(&corpus[j]))
            })
            .unwrap();
        // rank corpus by collision count (descending)
        let mut order: Vec<usize> = (0..corpus_size).collect();
        let coll: Vec<f64> = hashed.iter().map(|h| collision_rate(&hq, h)).collect();
        order.sort_by(|&i, &j| coll[j].total_cmp(&coll[i]));
        let rank = order.iter().position(|&i| i == best).unwrap();
        if rank == 0 {
            hits += 1;
        }
        rank_acc += rank as f64;
    }
    MipsResult {
        corpus: corpus_size,
        recall_at_1: hits as f64 / queries as f64,
        mean_rank: rank_acc / queries as f64,
    }
}

// ---------------------------------------------------------------------
// E9: adaptive N_f ablation
// ---------------------------------------------------------------------

/// Result of the adaptive-degree ablation.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveRow {
    /// oscillation parameter of the workload (higher = harder function)
    pub omega_scale: f64,
    /// mean adaptive N_f chosen by the chebfun heuristic
    pub mean_nf: f64,
    /// collision-probability RMSE with adaptive truncation (lazy hash)
    pub rmse_adaptive: f64,
    /// collision-probability RMSE with fixed N_f = 64
    pub rmse_fixed: f64,
}

/// E9: compare the paper's fixed `N_f = 64` against the chebfun-style
/// adaptive choice on workloads of increasing frequency. Uses the lazy
/// Algorithm 1 hash, which accepts variable-length coefficient vectors.
pub fn adaptive_nf_experiment(pairs: usize, hashes: usize, seed: u64) -> Vec<AdaptiveRow> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let r = 1.0;
    let bank = LazyL2Hash::new(rng.next_u64(), hashes, r);
    let full_emb = crate::embedding::ChebyshevEmbedder::new(Interval::unit(), 256);
    let mut rows = Vec::new();
    for &scale in &[1.0f64, 2.0, 4.0] {
        let mut nf_acc = 0.0;
        let mut obs_a = Vec::new();
        let mut obs_f = Vec::new();
        let mut theo = Vec::new();
        for _ in 0..pairs {
            let d1 = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
            let d2 = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
            let f = Sine::new(1.0, scale * 2.0 * std::f64::consts::PI, d1);
            let g = Sine::new(1.0, scale * 2.0 * std::f64::consts::PI, d2);
            let c = (1.0 - (d1 - d2).cos()).max(0.0).sqrt();

            // adaptive N_f from the coefficient plateau of a chebfun fit
            let fit = ChebyshevSeries::fit_adaptive(&f, 0.0, 1.0, 1e-10, 256);
            let n_f = fit.len().clamp(4, 256);
            nf_acc += n_f as f64;

            let tf = full_emb.embed_fn(&f);
            let tg = full_emb.embed_fn(&g);
            obs_a.push(collision_rate(&bank.hash(&tf[..n_f]), &bank.hash(&tg[..n_f])));
            obs_f.push(collision_rate(&bank.hash(&tf[..64]), &bank.hash(&tg[..64])));
            theo.push(pstable_collision_probability(c, r, 2.0));
        }
        rows.push(AdaptiveRow {
            omega_scale: scale,
            mean_nf: nf_acc / pairs as f64,
            rmse_adaptive: crate::util::stats::rmse(&obs_a, &theo),
            rmse_fixed: crate::util::stats::rmse(&obs_f, &theo),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thm1_band_contains_observation_and_tightens() {
        let rows = thm1_bounds_experiment(2048, 11);
        assert_eq!(rows.len(), 7);
        // bands must be nested/tightening as N_f grows
        assert!(rows.last().unwrap().upper - rows.last().unwrap().lower
            < rows[0].upper - rows[0].lower);
        // At high N_f the coefficient tail of the √sin-weighted embedding
        // decays algebraically (~ N_f^{-3/2}), so eps is small but not
        // machine-zero; the observation must sit inside the (slightly
        // slackened for sampling noise) band.
        let last = rows.last().unwrap();
        assert!(last.eps < 0.2, "eps {}", last.eps);
        assert!(
            last.observed >= last.lower - 0.05 && last.observed <= last.upper + 0.05,
            "{last:?}"
        );
    }

    #[test]
    fn qmc_beats_mc_at_large_n() {
        let rows = qmc_convergence(12, 13);
        let last = rows.last().unwrap();
        assert!(
            last.qmc_err < last.mc_err,
            "qmc {} vs mc {}",
            last.qmc_err,
            last.mc_err
        );
        // MC error should shrink with N overall
        assert!(rows.last().unwrap().mc_err < rows[0].mc_err * 1.5);
    }

    #[test]
    fn knn_has_useful_recall_and_speedup() {
        let res = knn_experiment(500, 20, 10, 1, 17);
        assert!(res.recall > 0.45, "recall {}", res.recall);
        assert!(res.speedup > 1.5, "speedup {}", res.speedup);
    }

    #[test]
    fn w1_rows_consistent() {
        let rows = w1_experiment(12, 512, 19);
        for row in &rows {
            // LP on 64-pt discretization ≈ dense ground truth
            assert!(
                (row.w1_lp - row.w1).abs() < 0.15 * row.w1.max(0.05),
                "{row:?}"
            );
            // observed collision rate ≈ Cauchy theory
            assert!((row.observed - row.theoretical).abs() < 0.12, "{row:?}");
            // IT surrogate correlates (within its log-factor guarantee)
            assert!(row.w1_it > 0.0);
        }
    }

    #[test]
    fn mips_finds_best_items() {
        let res = mips_experiment(100, 20, 1024, 23);
        // the true best item should rank far above median on average
        assert!(res.mean_rank < 25.0, "mean rank {}", res.mean_rank);
        assert!(res.recall_at_1 > 0.2, "recall@1 {}", res.recall_at_1);
    }

    #[test]
    fn adaptive_nf_grows_with_frequency() {
        let rows = adaptive_nf_experiment(10, 256, 29);
        assert!(rows[2].mean_nf > rows[0].mean_nf);
        // both truncations should track theory reasonably
        for r in &rows {
            assert!(r.rmse_adaptive < 0.12, "{r:?}");
            assert!(r.rmse_fixed < 0.12, "{r:?}");
        }
    }
}
