//! E10/E11: generality experiments beyond the paper's own figures.
//!
//! * **E10** — the §3.1 method with different orthonormal bases
//!   (Chebyshev-weighted cosine, Legendre, Fourier): collision-rate
//!   agreement and embedding error per basis, demonstrating the paper's
//!   "any orthonormal basis" claim.
//! * **E11** — §3.2 over `Ω = [0,1]²`: MC vs Sobol vs Halton embedding
//!   error, exhibiting the dimension-dependent `(log N)^d N^{-1}` QMC
//!   rate (Lemieux 2009) the paper cites.

use crate::embedding::{
    l2_dist, ChebyshevEmbedder, Embedder, FourierEmbedder, Interval, LegendreEmbedder,
    MonteCarloEmbedder2D, Rectangle,
};
use crate::embedding::multidim::Sampling2D;
use crate::experiments::collision_rate;
use crate::hashing::{HashBank, PStableHashBank};
use crate::theory::gaussian_collision_probability;
use crate::util::rng::{Rng64, Xoshiro256pp};
use crate::util::stats::rmse;
use crate::workload::sine_pair;
use std::f64::consts::PI;

/// One row of the basis-comparison experiment (E10).
#[derive(Debug, Clone)]
pub struct BasisRow {
    /// basis label
    pub basis: &'static str,
    /// mean |‖T(f)−T(g)‖ − ‖f−g‖| over the workload
    pub embed_err: f64,
    /// collision-probability RMSE vs Eq. 8
    pub collision_rmse: f64,
}

/// E10: compare orthonormal bases at the paper's N = 64 (Fourier uses 65,
/// the nearest odd dimension).
pub fn basis_comparison(pairs: usize, hashes: usize, seed: u64) -> Vec<BasisRow> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let r = 1.0;
    let omega = Interval::unit();
    let bases: Vec<(&'static str, Box<dyn Embedder>)> = vec![
        ("chebyshev", Box::new(ChebyshevEmbedder::new(omega, 64))),
        ("legendre", Box::new(LegendreEmbedder::new(omega, 64))),
        ("fourier", Box::new(FourierEmbedder::new(omega, 65))),
    ];
    let mut rows = Vec::new();
    for (label, emb) in bases {
        let bank = PStableHashBank::new(emb.dim(), hashes, 2.0, r, &mut rng);
        let mut err_acc = 0.0;
        let mut obs = Vec::new();
        let mut theo = Vec::new();
        let mut pair_rng = Xoshiro256pp::seed_from_u64(seed ^ 0xABCD);
        for _ in 0..pairs {
            let (f, g) = sine_pair(&mut pair_rng);
            let truth = (1.0 - (f.phase - g.phase).cos()).max(0.0).sqrt();
            let tf = emb.embed_fn(&f);
            let tg = emb.embed_fn(&g);
            err_acc += (l2_dist(&tf, &tg) - truth).abs();
            obs.push(collision_rate(&bank.hash(&tf), &bank.hash(&tg)));
            theo.push(gaussian_collision_probability(truth, r));
        }
        rows.push(BasisRow {
            basis: label,
            embed_err: err_acc / pairs as f64,
            collision_rmse: rmse(&obs, &theo),
        });
    }
    rows
}

/// One row of the 2-D convergence experiment (E11).
#[derive(Debug, Clone, Copy)]
pub struct Dim2Row {
    /// number of sample points N
    pub n: usize,
    /// i.i.d. MC embedding error
    pub mc_err: f64,
    /// 2-D Sobol error
    pub sobol_err: f64,
    /// 2-D Halton error
    pub halton_err: f64,
}

/// E11: embedding error over `Ω = [0,1]²` for plane waves
/// `sin(2π(x+y) + δ)` (closed-form pairwise distances).
pub fn dim2_convergence(pairs: usize, seed: u64) -> Vec<Dim2Row> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let omega = Rectangle::unit();
    let mut rows = Vec::new();
    for &n in &[64usize, 256, 1024, 4096] {
        let mut errs = [0.0f64; 3];
        for _ in 0..pairs {
            let d1 = rng.uniform_in(0.0, 2.0 * PI);
            let d2 = rng.uniform_in(0.0, 2.0 * PI);
            let f = move |x: f64, y: f64| (2.0 * PI * (x + y) + d1).sin();
            let g = move |x: f64, y: f64| (2.0 * PI * (x + y) + d2).sin();
            let truth = (1.0 - (d1 - d2).cos()).max(0.0).sqrt();
            for (slot, sampling) in [
                (0, Sampling2D::Iid),
                (1, Sampling2D::Sobol),
                (2, Sampling2D::Halton),
            ] {
                let emb = MonteCarloEmbedder2D::new(omega, n, 2.0, sampling, &mut rng);
                let d = l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
                errs[slot] += (d - truth).abs();
            }
        }
        rows.push(Dim2Row {
            n,
            mc_err: errs[0] / pairs as f64,
            sobol_err: errs[1] / pairs as f64,
            halton_err: errs[2] / pairs as f64,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_bases_track_theory() {
        let rows = basis_comparison(24, 512, 5);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.collision_rmse < 0.06, "{r:?}");
        }
        // Legendre & Fourier are exactly isometric on this workload —
        // both should beat the √sin-weighted Chebyshev on embedding error.
        let cheb = rows.iter().find(|r| r.basis == "chebyshev").unwrap();
        let leg = rows.iter().find(|r| r.basis == "legendre").unwrap();
        let fou = rows.iter().find(|r| r.basis == "fourier").unwrap();
        assert!(leg.embed_err < cheb.embed_err, "{leg:?} vs {cheb:?}");
        assert!(fou.embed_err < cheb.embed_err, "{fou:?} vs {cheb:?}");
    }

    #[test]
    fn dim2_qmc_beats_mc() {
        let rows = dim2_convergence(6, 7);
        let last = rows.last().unwrap();
        assert!(
            last.sobol_err < last.mc_err,
            "sobol {} vs mc {}",
            last.sobol_err,
            last.mc_err
        );
    }
}
