//! Experiment harnesses reproducing every figure of the paper's §4, plus
//! the extension experiments listed in DESIGN.md (E4–E9).
//!
//! Each harness is a pure function from parameters to result rows so the
//! CLI (`funclsh experiment …`), the benches, and the integration tests
//! all share one implementation. Results include the theoretical curve,
//! the observed collision frequency, and agreement metrics (RMSE, max
//! deviation, Pearson r) that EXPERIMENTS.md records.

pub mod bases_experiments;
pub mod extensions;

use crate::embedding::{
    cosine_sim, l2_dist, ChebyshevEmbedder, Embedder, Interval, MonteCarloEmbedder, QmcEmbedder,
    QmcSequence,
};
use crate::functions::Distribution1D;
use crate::hashing::{HashBank, PStableHashBank, SimHashBank};
use crate::theory::{
    gaussian_collision_probability, simhash_collision_probability,
};
use crate::util::rng::{Rng64, Xoshiro256pp};
use crate::util::stats::{max_abs_dev, pearson, rmse};
use crate::wasserstein::{gaussian_w2, QUANTILE_CLIP};
use crate::workload::{gaussian_pair, sine_pair};

/// Which embedding a figure run used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// §3.1 function approximation (Chebyshev)
    FunctionApproximation,
    /// §3.2 Monte Carlo
    MonteCarlo,
    /// §3.2 quasi-Monte Carlo (Sobol) — extension
    QuasiMonteCarlo,
}

impl Method {
    /// Short label used in CSV output.
    pub fn label(&self) -> &'static str {
        match self {
            Method::FunctionApproximation => "cheb",
            Method::MonteCarlo => "mc",
            Method::QuasiMonteCarlo => "qmc",
        }
    }

    /// Build the embedder for this method on `omega` with dimension `n`.
    pub fn embedder(
        &self,
        omega: Interval,
        n: usize,
        p: f64,
        rng: &mut dyn Rng64,
    ) -> Box<dyn Embedder> {
        match self {
            Method::FunctionApproximation => Box::new(ChebyshevEmbedder::new(omega, n)),
            Method::MonteCarlo => Box::new(MonteCarloEmbedder::new(omega, n, p, rng)),
            Method::QuasiMonteCarlo => {
                Box::new(QmcEmbedder::new(omega, n, p, QmcSequence::Sobol))
            }
        }
    }
}

/// One scatter point of a collision-rate figure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollisionPoint {
    /// x-axis: the true similarity/distance between the pair
    pub similarity: f64,
    /// observed collision frequency across the hash bank
    pub observed: f64,
    /// theoretical collision probability at `similarity`
    pub theoretical: f64,
}

/// A complete figure run: points for one method plus agreement stats.
#[derive(Debug, Clone)]
pub struct FigureSeries {
    /// which embedding generated the series
    pub method: Method,
    /// scatter points (one per sampled pair)
    pub points: Vec<CollisionPoint>,
}

impl FigureSeries {
    /// RMSE between observed and theoretical collision rates.
    pub fn rmse(&self) -> f64 {
        let (o, t): (Vec<f64>, Vec<f64>) = self
            .points
            .iter()
            .map(|p| (p.observed, p.theoretical))
            .unzip();
        rmse(&o, &t)
    }

    /// Maximum absolute deviation.
    pub fn max_dev(&self) -> f64 {
        let (o, t): (Vec<f64>, Vec<f64>) = self
            .points
            .iter()
            .map(|p| (p.observed, p.theoretical))
            .unzip();
        max_abs_dev(&o, &t)
    }

    /// Pearson correlation between observed and theoretical.
    pub fn pearson(&self) -> f64 {
        let (o, t): (Vec<f64>, Vec<f64>) = self
            .points
            .iter()
            .map(|p| (p.observed, p.theoretical))
            .unzip();
        pearson(&o, &t)
    }

    /// CSV rows (`method,similarity,observed,theoretical`).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6}\n",
                self.method.label(),
                p.similarity,
                p.observed,
                p.theoretical
            ));
        }
        out
    }
}

/// Parameters shared by the figure experiments, defaulting to the paper's
/// setup: Ω = \[0,1\], N = 64, 1024 hash functions, r = 1.
#[derive(Debug, Clone, Copy)]
pub struct FigureParams {
    /// number of random pairs (scatter points)
    pub pairs: usize,
    /// hash functions per bank (collision-rate resolution)
    pub hashes: usize,
    /// embedding dimension N
    pub dim: usize,
    /// bucket width r (L² hash experiments)
    pub r: f64,
    /// RNG seed
    pub seed: u64,
}

impl Default for FigureParams {
    fn default() -> Self {
        Self {
            pairs: 256,
            hashes: 1024,
            dim: 64,
            r: 1.0,
            seed: 2020,
        }
    }
}

/// **Figure 1**: SimHash collision rate vs cosine similarity over random
/// sine pairs `sin(2πx + δ)`, for the given embedding method.
///
/// Ground truth: `cossim(f, g) = cos(δ₁ − δ₂)` on `[0, 1]` (closed form);
/// theory: Eq. 7.
pub fn fig1_cosine(method: Method, params: FigureParams) -> FigureSeries {
    let mut rng = Xoshiro256pp::seed_from_u64(params.seed);
    let omega = Interval::unit();
    let emb = method.embedder(omega, params.dim, 2.0, &mut rng);
    let bank = SimHashBank::new(params.dim, params.hashes, &mut rng);
    let mut points = Vec::with_capacity(params.pairs);
    for _ in 0..params.pairs {
        let (f, g) = sine_pair(&mut rng);
        let true_sim = (f.phase - g.phase).cos();
        let tf = emb.embed_fn(&f);
        let tg = emb.embed_fn(&g);
        let observed = collision_rate(&bank.hash(&tf), &bank.hash(&tg));
        points.push(CollisionPoint {
            similarity: true_sim,
            observed,
            theoretical: simhash_collision_probability(true_sim),
        });
    }
    FigureSeries { method, points }
}

/// **Figure 2**: 2-stable L²-distance hash collision rate vs
/// `‖f − g‖_{L²}` over random sine pairs.
///
/// Ground truth: `‖f − g‖² = 1 − cos(δ₁ − δ₂)` on `[0,1]`; theory: Eq. 8.
pub fn fig2_l2(method: Method, params: FigureParams) -> FigureSeries {
    let mut rng = Xoshiro256pp::seed_from_u64(params.seed.wrapping_add(1));
    let omega = Interval::unit();
    let emb = method.embedder(omega, params.dim, 2.0, &mut rng);
    let bank = PStableHashBank::new(params.dim, params.hashes, 2.0, params.r, &mut rng);
    let mut points = Vec::with_capacity(params.pairs);
    for _ in 0..params.pairs {
        let (f, g) = sine_pair(&mut rng);
        let c = (1.0 - (f.phase - g.phase).cos()).max(0.0).sqrt();
        let tf = emb.embed_fn(&f);
        let tg = emb.embed_fn(&g);
        let observed = collision_rate(&bank.hash(&tf), &bank.hash(&tg));
        points.push(CollisionPoint {
            similarity: c,
            observed,
            theoretical: gaussian_collision_probability(c, params.r),
        });
    }
    FigureSeries { method, points }
}

/// **Figure 3**: 2-stable hash collision rate vs `W²(m₁, m₂)` over random
/// Gaussian pairs, hashing the inverse CDFs on `[10⁻³, 1 − 10⁻³]` per the
/// paper's footnote 1.
///
/// Ground truth: Olkin–Pukelsheim closed form; theory: Eq. 8.
pub fn fig3_wasserstein(method: Method, params: FigureParams) -> FigureSeries {
    let mut rng = Xoshiro256pp::seed_from_u64(params.seed.wrapping_add(2));
    // the clipped domain of the quantile functions
    let omega = Interval::new(QUANTILE_CLIP, 1.0 - QUANTILE_CLIP);
    let emb = method.embedder(omega, params.dim, 2.0, &mut rng);
    let bank = PStableHashBank::new(params.dim, params.hashes, 2.0, params.r, &mut rng);
    let mut points = Vec::with_capacity(params.pairs);
    for _ in 0..params.pairs {
        let (a, b) = gaussian_pair(&mut rng);
        let w2 = gaussian_w2(&a, &b);
        let qa = a.quantile_fn();
        let qb = b.quantile_fn();
        let ta = emb.embed_fn(&qa);
        let tb = emb.embed_fn(&qb);
        let observed = collision_rate(&bank.hash(&ta), &bank.hash(&tb));
        points.push(CollisionPoint {
            similarity: w2,
            observed,
            theoretical: gaussian_collision_probability(w2, params.r),
        });
    }
    FigureSeries { method, points }
}

/// Fraction of positions where two signatures agree.
pub fn collision_rate(a: &[i32], b: &[i32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

/// Measure the embedding quality the experiments implicitly rely on:
/// mean |‖T(f)−T(g)‖ − ‖f−g‖| over sine pairs (diagnostic for DESIGN §3).
pub fn embedding_distance_error(method: Method, dim: usize, pairs: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let emb = method.embedder(Interval::unit(), dim, 2.0, &mut rng);
    let mut acc = 0.0;
    for _ in 0..pairs {
        let (f, g) = sine_pair(&mut rng);
        let truth = (1.0 - (f.phase - g.phase).cos()).max(0.0).sqrt();
        let d = l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
        acc += (d - truth).abs();
    }
    acc / pairs as f64
}

/// Same for cosine similarity (diagnostic for Figure 1).
pub fn embedding_cosine_error(method: Method, dim: usize, pairs: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let emb = method.embedder(Interval::unit(), dim, 2.0, &mut rng);
    let mut acc = 0.0;
    for _ in 0..pairs {
        let (f, g) = sine_pair(&mut rng);
        let truth = (f.phase - g.phase).cos();
        let s = cosine_sim(&emb.embed_fn(&f), &emb.embed_fn(&g));
        acc += (s - truth).abs();
    }
    acc / pairs as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> FigureParams {
        FigureParams {
            pairs: 48,
            hashes: 512,
            dim: 64,
            r: 1.0,
            seed: 7,
        }
    }

    #[test]
    fn fig1_tracks_theory_both_methods() {
        for method in [Method::FunctionApproximation, Method::MonteCarlo] {
            let s = fig1_cosine(method, small());
            assert_eq!(s.points.len(), 48);
            // The paper's claim: observed tracks theoretical closely.
            assert!(
                s.rmse() < 0.06,
                "{:?} rmse {} too high",
                method,
                s.rmse()
            );
            assert!(s.pearson() > 0.97, "{:?} r = {}", method, s.pearson());
        }
    }

    #[test]
    fn fig2_tracks_theory_both_methods() {
        for method in [Method::FunctionApproximation, Method::MonteCarlo] {
            let s = fig2_l2(method, small());
            assert!(s.rmse() < 0.06, "{:?} rmse {}", method, s.rmse());
            assert!(s.pearson() > 0.97, "{:?} r {}", method, s.pearson());
        }
    }

    #[test]
    fn fig3_tracks_theory_both_methods() {
        for method in [Method::FunctionApproximation, Method::MonteCarlo] {
            let s = fig3_wasserstein(method, small());
            assert!(s.rmse() < 0.07, "{:?} rmse {}", method, s.rmse());
            assert!(s.pearson() > 0.95, "{:?} r {}", method, s.pearson());
        }
    }

    #[test]
    fn qmc_method_also_valid() {
        let s = fig2_l2(Method::QuasiMonteCarlo, small());
        assert!(s.rmse() < 0.06, "rmse {}", s.rmse());
    }

    #[test]
    fn csv_output_shape() {
        let s = fig1_cosine(Method::MonteCarlo, FigureParams {
            pairs: 4,
            hashes: 64,
            ..small()
        });
        let csv = s.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("mc,"));
    }

    #[test]
    fn embedding_error_diagnostics_small() {
        let e_cheb = embedding_distance_error(Method::FunctionApproximation, 64, 32, 3);
        let e_mc = embedding_distance_error(Method::MonteCarlo, 64, 32, 3);
        assert!(e_cheb < 0.01, "cheb {e_cheb}");
        assert!(e_mc < 0.15, "mc {e_mc}");
        let c = embedding_cosine_error(Method::FunctionApproximation, 64, 32, 3);
        assert!(c < 0.02, "{c}");
    }
}
