//! Chebyshev approximation machinery — the orthonormal-basis half (§3.1) of
//! the paper.
//!
//! A function `f` on `[a, b]` is interpolated at the `N` Chebyshev points of
//! the first kind; its Chebyshev coefficients are extracted with a DCT-II
//! (either the `O(N²)` direct transform or the `O(N log N)` FFT-based one),
//! evaluated with Clenshaw's recurrence, and truncated adaptively with the
//! chebfun-style plateau heuristic (Trefethen 2012; Driscoll et al. 2014) —
//! the "choosing `N_f`" heuristics the paper points to.
//!
//! The embedding of `L²([a,b])` (Lebesgue) into `ℓ²_N` built on top of this
//! lives in [`crate::embedding::ChebyshevEmbedder`].

pub mod fft;

use crate::functions::Function1D;
use std::f64::consts::PI;

/// The `n` Chebyshev points of the first kind on `[-1, 1]`:
/// `x_k = cos(π (k + ½) / n)`, `k = 0..n` (descending in `x`).
pub fn chebyshev_nodes(n: usize) -> Vec<f64> {
    assert!(n > 0);
    (0..n)
        .map(|k| (PI * (k as f64 + 0.5) / n as f64).cos())
        .collect()
}

/// Chebyshev points of the first kind mapped to `[a, b]`.
pub fn chebyshev_nodes_on(n: usize, a: f64, b: f64) -> Vec<f64> {
    chebyshev_nodes(n)
        .into_iter()
        .map(|x| 0.5 * (a + b) + 0.5 * (b - a) * x)
        .collect()
}

/// Direct `O(N²)` DCT-II: `y_j = Σ_k x_k cos(π j (k + ½) / N)`.
///
/// This is the reference implementation; [`fft::dct2_fft`] is the fast
/// path (they are tested against each other).
pub fn dct2_naive(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut y = vec![0.0; n];
    for (j, yj) in y.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (k, &xk) in x.iter().enumerate() {
            acc += xk * (PI * j as f64 * (k as f64 + 0.5) / n as f64).cos();
        }
        *yj = acc;
    }
    y
}

/// DCT-II dispatching to the FFT path for power-of-two sizes and the naive
/// path otherwise.
pub fn dct2(x: &[f64]) -> Vec<f64> {
    if x.len().is_power_of_two() && x.len() >= 8 {
        fft::dct2_fft(x)
    } else {
        dct2_naive(x)
    }
}

/// Chebyshev coefficients of the degree-`n-1` interpolant of `f` through
/// the first-kind points: `c_j` such that `f(x) ≈ Σ c_j T_j(x)`.
///
/// `c_j = (2/N) Σ_k f(x_k) cos(π j (k+½)/N)`, with `c_0` halved.
pub fn chebyshev_coefficients(samples: &[f64]) -> Vec<f64> {
    let n = samples.len();
    let mut c = dct2(samples);
    let scale = 2.0 / n as f64;
    for cj in c.iter_mut() {
        *cj *= scale;
    }
    c[0] *= 0.5;
    c
}

/// A truncated Chebyshev series on `[a, b]`: `f(x) ≈ Σ_j c_j T_j(t(x))`
/// where `t` maps `[a,b]` to `[-1,1]`.
#[derive(Debug, Clone, PartialEq)]
pub struct ChebyshevSeries {
    /// Chebyshev coefficients `c_0 .. c_{m-1}`
    pub coeffs: Vec<f64>,
    /// left endpoint of the domain
    pub a: f64,
    /// right endpoint of the domain
    pub b: f64,
}

impl ChebyshevSeries {
    /// Interpolate `f` at `n` first-kind Chebyshev points on `[a, b]`.
    pub fn fit(f: &dyn Function1D, n: usize, a: f64, b: f64) -> Self {
        assert!(a < b);
        let xs = chebyshev_nodes_on(n, a, b);
        let samples: Vec<f64> = xs.iter().map(|&x| f.eval(x)).collect();
        Self {
            coeffs: chebyshev_coefficients(&samples),
            a,
            b,
        }
    }

    /// Chebfun-style adaptive fit: double `n` starting from `n0` until the
    /// trailing coefficients plateau below `tol` relative to the largest
    /// coefficient, then truncate at the plateau. Returns the truncated
    /// series (the paper's "choose a good `N_f`" step, §3.1 note (i)).
    pub fn fit_adaptive(f: &dyn Function1D, a: f64, b: f64, tol: f64, max_n: usize) -> Self {
        let mut n = 16;
        loop {
            let s = Self::fit(f, n, a, b);
            if let Some(cut) = s.plateau_cutoff(tol) {
                return Self {
                    coeffs: s.coeffs[..cut].to_vec(),
                    a,
                    b,
                };
            }
            if n >= max_n {
                return s;
            }
            n *= 2;
        }
    }

    /// Index after which the coefficient envelope stays below
    /// `tol * max|c|`; `None` if the tail never resolves (under-resolved).
    fn plateau_cutoff(&self, tol: f64) -> Option<usize> {
        let cmax = self
            .coeffs
            .iter()
            .fold(0.0f64, |m, c| m.max(c.abs()));
        if cmax == 0.0 {
            return Some(1);
        }
        let thresh = tol * cmax;
        // Envelope: running max from the tail.
        let n = self.coeffs.len();
        let mut env = vec![0.0; n];
        let mut run = 0.0f64;
        for i in (0..n).rev() {
            run = run.max(self.coeffs[i].abs());
            env[i] = run;
        }
        // Require the last eighth of the envelope to sit below threshold so
        // a single small coefficient doesn't fake convergence.
        let tail_start = n - (n / 8).max(1);
        if env[tail_start] > thresh {
            return None;
        }
        // Truncate at the first index where the envelope drops below.
        let cut = env.iter().position(|&e| e <= thresh).unwrap_or(n);
        Some(cut.max(1))
    }

    /// Degree + 1 (number of retained coefficients) — the paper's `N_f`.
    pub fn len(&self) -> usize {
        self.coeffs.len()
    }

    /// Whether the series has no coefficients.
    pub fn is_empty(&self) -> bool {
        self.coeffs.is_empty()
    }

    /// Evaluate via Clenshaw's recurrence — `O(m)` per point, numerically
    /// stable.
    pub fn eval(&self, x: f64) -> f64 {
        let t = (2.0 * x - (self.a + self.b)) / (self.b - self.a);
        let mut b1 = 0.0;
        let mut b2 = 0.0;
        for &c in self.coeffs.iter().skip(1).rev() {
            let b0 = 2.0 * t * b1 - b2 + c;
            b2 = b1;
            b1 = b0;
        }
        self.coeffs.first().copied().unwrap_or(0.0) + t * b1 - b2
    }

    /// `‖f̂‖²` under the *Chebyshev* inner product implied by discrete
    /// orthogonality: `c₀² + ½ Σ_{j≥1} c_j²` (times π; unnormalized).
    /// Used by the "estimate `‖ε_f‖` when `‖f‖` is known" heuristic.
    pub fn weighted_norm_sq(&self) -> f64 {
        let mut s = 0.0;
        for (j, &c) in self.coeffs.iter().enumerate() {
            s += if j == 0 { c * c } else { 0.5 * c * c };
        }
        s
    }
}

impl Function1D for ChebyshevSeries {
    fn eval(&self, x: f64) -> f64 {
        ChebyshevSeries::eval(self, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::Sine;

    #[test]
    fn nodes_are_cosines_descending() {
        let xs = chebyshev_nodes(4);
        assert_eq!(xs.len(), 4);
        assert!(xs.windows(2).all(|w| w[0] > w[1]));
        assert!((xs[0] - (PI / 8.0).cos()).abs() < 1e-15);
    }

    #[test]
    fn nodes_map_to_interval() {
        let xs = chebyshev_nodes_on(16, 2.0, 5.0);
        assert!(xs.iter().all(|&x| (2.0..=5.0).contains(&x)));
    }

    #[test]
    fn dct_naive_vs_fft() {
        for &n in &[8usize, 16, 64, 128] {
            let x: Vec<f64> = (0..n).map(|i| ((i * 37 % 11) as f64) - 5.0).collect();
            let a = dct2_naive(&x);
            let b = fft::dct2_fft(&x);
            for (ai, bi) in a.iter().zip(&b) {
                assert!((ai - bi).abs() < 1e-9, "n={n}: {ai} vs {bi}");
            }
        }
    }

    #[test]
    fn coefficients_of_pure_chebyshev_polynomials() {
        // f = T_3 on [-1,1] must give c_3 = 1 and everything else ~0.
        let t3 = |x: f64| 4.0 * x.powi(3) - 3.0 * x;
        let xs = chebyshev_nodes(16);
        let samples: Vec<f64> = xs.iter().map(|&x| t3(x)).collect();
        let c = chebyshev_coefficients(&samples);
        for (j, cj) in c.iter().enumerate() {
            let want = if j == 3 { 1.0 } else { 0.0 };
            assert!((cj - want).abs() < 1e-12, "c[{j}] = {cj}");
        }
    }

    #[test]
    fn interpolant_matches_smooth_function() {
        let f = Sine::paper(0.7);
        let s = ChebyshevSeries::fit(&f, 32, 0.0, 1.0);
        for i in 0..100 {
            let x = i as f64 / 99.0;
            assert!(
                (s.eval(x) - f.eval(x)).abs() < 1e-12,
                "x = {x}: {} vs {}",
                s.eval(x),
                f.eval(x)
            );
        }
    }

    #[test]
    fn interpolant_on_shifted_domain() {
        let f = |x: f64| (x * x + 1.0).ln();
        let s = ChebyshevSeries::fit(&f, 48, 2.0, 6.0);
        for i in 0..50 {
            let x = 2.0 + 4.0 * i as f64 / 49.0;
            assert!((s.eval(x) - f(x)).abs() < 1e-11);
        }
    }

    #[test]
    fn adaptive_fit_truncates_smooth_functions() {
        let f = Sine::paper(0.0);
        let s = ChebyshevSeries::fit_adaptive(&f, 0.0, 1.0, 1e-13, 512);
        // sin(2πx) needs ~20 coefficients at machine precision
        assert!(s.len() <= 40, "kept {} coefficients", s.len());
        for i in 0..50 {
            let x = i as f64 / 49.0;
            assert!((s.eval(x) - f.eval(x)).abs() < 1e-10);
        }
    }

    #[test]
    fn adaptive_fit_grows_for_oscillatory_functions() {
        let hard = |x: f64| (40.0 * PI * x).sin();
        let easy = |x: f64| x;
        let sh = ChebyshevSeries::fit_adaptive(&hard, 0.0, 1.0, 1e-10, 1024);
        let se = ChebyshevSeries::fit_adaptive(&easy, 0.0, 1.0, 1e-10, 1024);
        assert!(sh.len() > 4 * se.len());
    }

    #[test]
    fn clenshaw_handles_degenerate_series() {
        let s = ChebyshevSeries {
            coeffs: vec![2.5],
            a: -1.0,
            b: 1.0,
        };
        assert_eq!(s.eval(0.3), 2.5);
        let e = ChebyshevSeries {
            coeffs: vec![],
            a: -1.0,
            b: 1.0,
        };
        assert_eq!(e.eval(0.3), 0.0);
    }
}
