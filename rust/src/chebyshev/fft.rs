//! Radix-2 FFT and the FFT-based DCT-II.
//!
//! The DCT-II uses Makhoul's (1980) even-odd reordering: an `N`-point
//! DCT-II becomes one `N`-point complex FFT plus a twiddle, `O(N log N)`
//! versus the naive `O(N²)`. Correctness is pinned to [`super::dct2_naive`]
//! in tests.

use std::f64::consts::PI;

/// Complex number as a bare pair (re, im) — no external deps.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cpx {
    /// real part
    pub re: f64,
    /// imaginary part
    pub im: f64,
}

impl Cpx {
    /// `re + i·im`
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// `e^{iθ}`
    pub fn cis(theta: f64) -> Self {
        Self::new(theta.cos(), theta.sin())
    }

    /// complex multiplication
    pub fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    /// complex addition
    pub fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }

    /// complex subtraction
    pub fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

/// In-place iterative radix-2 Cooley–Tukey FFT (decimation in time).
/// `data.len()` must be a power of two. Forward transform uses the
/// `e^{-2πi k n / N}` convention.
pub fn fft_in_place(data: &mut [Cpx]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    // butterflies
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f64;
        let wlen = Cpx::cis(ang);
        for start in (0..n).step_by(len) {
            let mut w = Cpx::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[start + k];
                let v = data[start + k + len / 2].mul(w);
                data[start + k] = u.add(v);
                data[start + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
        }
        len <<= 1;
    }
}

/// DCT-II via a single complex FFT (Makhoul 1980):
/// `y_j = Σ_k x_k cos(π j (k + ½) / N)`, same convention as
/// [`super::dct2_naive`]. `x.len()` must be a power of two.
pub fn dct2_fft(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    assert!(n.is_power_of_two());
    // Even-odd reordering: v = [x0, x2, ..., x_{N-2}, x_{N-1}, ..., x3, x1]
    let mut v = vec![Cpx::default(); n];
    for i in 0..n / 2 {
        v[i] = Cpx::new(x[2 * i], 0.0);
        v[n - 1 - i] = Cpx::new(x[2 * i + 1], 0.0);
    }
    fft_in_place(&mut v);
    // y_j = Re( e^{-iπj/(2N)} V_j )
    (0..n)
        .map(|j| {
            let tw = Cpx::cis(-PI * j as f64 / (2.0 * n as f64));
            tw.mul(v[j]).re
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut d = vec![Cpx::default(); 8];
        d[0] = Cpx::new(1.0, 0.0);
        fft_in_place(&mut d);
        for c in d {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_of_constant_is_impulse() {
        let mut d = vec![Cpx::new(1.0, 0.0); 8];
        fft_in_place(&mut d);
        assert!((d[0].re - 8.0).abs() < 1e-12);
        for c in &d[1..] {
            assert!(c.re.abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_matches_dft_random() {
        let n = 16;
        let xs: Vec<Cpx> = (0..n)
            .map(|i| Cpx::new(((i * 7 + 3) % 5) as f64, ((i * 11) % 3) as f64))
            .collect();
        // naive DFT
        let mut want = vec![Cpx::default(); n];
        for (k, w) in want.iter_mut().enumerate() {
            for (j, &x) in xs.iter().enumerate() {
                let tw = Cpx::cis(-2.0 * PI * (k * j) as f64 / n as f64);
                *w = w.add(x.mul(tw));
            }
        }
        let mut got = xs;
        fft_in_place(&mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.re - w.re).abs() < 1e-10 && (g.im - w.im).abs() < 1e-10);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 64;
        let xs: Vec<Cpx> = (0..n)
            .map(|i| Cpx::new((i as f64 * 0.37).sin(), 0.0))
            .collect();
        let time_energy: f64 = xs.iter().map(|c| c.re * c.re + c.im * c.im).sum();
        let mut fs = xs;
        fft_in_place(&mut fs);
        let freq_energy: f64 =
            fs.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / n as f64;
        assert!((time_energy - freq_energy).abs() < 1e-9);
    }
}
