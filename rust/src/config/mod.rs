//! Service configuration: a TOML-subset parser (offline build — no
//! external crates) plus the typed configuration consumed by the
//! coordinator, runtime, and CLI.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with strings,
//! integers, floats, booleans, and homogeneous arrays; `#` comments.

use std::collections::BTreeMap;

/// A parsed TOML-subset document: `section -> key -> raw value`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Toml {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// A scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// string
    Str(String),
    /// integer
    Int(i64),
    /// float
    Float(f64),
    /// boolean
    Bool(bool),
    /// homogeneous array
    Array(Vec<TomlValue>),
}

impl TomlValue {
    fn parse(raw: &str, line: usize) -> Result<Self, ConfigError> {
        let raw = raw.trim();
        if raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2 {
            return Ok(TomlValue::Str(raw[1..raw.len() - 1].to_string()));
        }
        if raw == "true" {
            return Ok(TomlValue::Bool(true));
        }
        if raw == "false" {
            return Ok(TomlValue::Bool(false));
        }
        if raw.starts_with('[') && raw.ends_with(']') {
            let inner = &raw[1..raw.len() - 1];
            let mut items = Vec::new();
            for part in split_top_level(inner) {
                let part = part.trim();
                if !part.is_empty() {
                    items.push(TomlValue::parse(part, line)?);
                }
            }
            return Ok(TomlValue::Array(items));
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
        Err(ConfigError::at(line, format!("cannot parse value `{raw}`")))
    }

    /// Value as f64 (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Value as usize.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// Value as str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Value as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Split on commas not inside quotes or nested brackets.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

/// Configuration error with the offending line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    /// 1-based line (0 = not line-specific)
    pub line: usize,
    /// description
    pub msg: String,
}

impl ConfigError {
    fn at(line: usize, msg: String) -> Self {
        Self { line, msg }
    }

    /// Non-positional error.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self {
            line: 0,
            msg: msg.into(),
        }
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line > 0 {
            write!(f, "config error (line {}): {}", self.line, self.msg)
        } else {
            write!(f, "config error: {}", self.msg)
        }
    }
}

impl std::error::Error for ConfigError {}

impl Toml {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut doc = Toml::default();
        let mut section = String::new();
        for (i, raw_line) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = strip_comment(raw_line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ConfigError::at(line_no, "unterminated section".into()));
                }
                section = line[1..line.len() - 1].trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| ConfigError::at(line_no, "expected `key = value`".into()))?;
            let key = line[..eq].trim().to_string();
            let value = TomlValue::parse(&line[eq + 1..], line_no)?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Which embedding the service uses on its hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmbeddingKind {
    /// Monte Carlo (i.i.d. uniform sample points)
    MonteCarlo,
    /// quasi-Monte Carlo (Sobol points)
    Qmc,
    /// Chebyshev / orthonormal basis
    Chebyshev,
}

/// Which hash family the service uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HashKind {
    /// p-stable L^p distance hash
    PStable,
    /// SimHash (cosine similarity)
    SimHash,
}

/// Which I/O runtime the TCP front-end uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoMode {
    /// readiness-based epoll event loop (Linux; one thread multiplexes
    /// all connections, a fixed worker pool feeds the batcher)
    EventLoop,
    /// acceptor + connection-handler thread pool (`max_conns` threads,
    /// blocking reads; the PR 1 runtime, kept as the portable fallback)
    Threaded,
}

impl IoMode {
    /// The config-file spelling of this mode (the inverse of
    /// [`IoMode::parse`]; used by banners and bench labels).
    pub fn as_str(&self) -> &'static str {
        match self {
            IoMode::EventLoop => "event_loop",
            IoMode::Threaded => "threaded",
        }
    }

    /// Parse the config/CLI spelling — the single source of truth for
    /// accepted mode names (`[server] io_mode` and `--io-mode` both go
    /// through here).
    pub fn parse(s: &str) -> Option<IoMode> {
        match s {
            "event_loop" | "epoll" => Some(IoMode::EventLoop),
            "threaded" | "thread_pool" => Some(IoMode::Threaded),
            _ => None,
        }
    }
}

/// Network front-end configuration (`[server]` section): where the TCP
/// listener binds and how connections are multiplexed.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// bind address (loopback by default; widen deliberately)
    pub host: String,
    /// TCP port (0 = ephemeral, the bound port is printed at startup)
    pub port: u16,
    /// I/O runtime (`event_loop` scales to thousands of sockets;
    /// `threaded` caps concurrency at `max_conns`)
    pub io_mode: IoMode,
    /// threaded mode only: handler threads = max concurrently served
    /// connections (further accepted connections queue until a handler
    /// frees up)
    pub max_conns: usize,
    /// event-loop mode only: worker threads draining parsed requests
    /// into the coordinator's dynamic batcher
    pub io_workers: usize,
    /// event-loop mode only: per-connection response backlog before the
    /// server stops reading that socket (the pipelining backpressure
    /// window; well-behaved clients keep their send window ≤ this)
    pub pipeline_depth: usize,
    /// admission control: in-flight request payload bytes one
    /// connection may have awaiting a response before further frames
    /// from it are shed with a typed `overloaded` envelope
    pub max_inflight_bytes_per_conn: usize,
    /// admission control: in-flight request payload bytes across all
    /// connections before new frames are shed with a typed
    /// `overloaded` envelope (global budget, checked after the
    /// per-connection one)
    pub max_inflight_bytes: usize,
    /// slow-client bound: pending response bytes (write buffer plus
    /// parked out-of-order completions) a connection may accumulate
    /// before it is sent a typed error and disconnected
    pub max_write_queue_bytes: usize,
    /// event-loop mode only: fold adjacent single-op frames from one
    /// connection into a synthetic server-side batch (replies stay
    /// byte-identical and in order; off = one job per frame)
    pub coalesce: bool,
    /// event-loop mode only: max single-op frames folded into one
    /// synthetic batch
    pub coalesce_window: usize,
    /// where graceful shutdown snapshots the index (`FLSH1`); empty
    /// string disables the shutdown snapshot
    pub snapshot_path: String,
    /// per-request stage tracing (on by default — the overhead is a few
    /// monotonic clock reads per request; `trace = false` / `funclsh
    /// serve --no-trace` empties the `stats` stage histograms and slow
    /// log but leaves the op itself answering)
    pub trace: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".to_string(),
            port: 7070,
            io_mode: IoMode::EventLoop,
            max_conns: 32,
            io_workers: 4,
            pipeline_depth: 64,
            max_inflight_bytes_per_conn: 16 << 20,
            max_inflight_bytes: 128 << 20,
            max_write_queue_bytes: 64 << 20,
            coalesce: true,
            coalesce_window: 64,
            snapshot_path: String::new(),
            trace: true,
        }
    }
}

/// Cluster serving configuration (`[cluster]` section): the knobs of
/// `funclsh route` — shard membership, heartbeat liveness, per-shard
/// request timeouts, and the retry/backoff schedule (also reused by the
/// client-side reconnect policy and live migration).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// shard node addresses the router scatters over (`host:port`, one
    /// per shard; CLI `--shards` overrides)
    pub nodes: Vec<String>,
    /// router → shard heartbeat ping period
    pub heartbeat_interval_ms: u64,
    /// consecutive missed heartbeats before a shard is marked down
    pub heartbeat_miss_threshold: u32,
    /// consecutive healthy heartbeats before a down shard is re-admitted
    /// into the scatter set
    pub readmit_after: u32,
    /// per-shard request timeout: a scatter leg slower than this counts
    /// as a failure and enters the retry schedule
    pub request_timeout_ms: u64,
    /// retries per shard request after the first attempt; once spent,
    /// the leg is declared degraded
    pub retry_budget: u32,
    /// first retry backoff; doubles each attempt
    pub retry_backoff_base_ms: u64,
    /// upper bound the exponential backoff saturates at
    pub retry_backoff_cap_ms: u64,
    /// entries per chunk when streaming a shard's store during live
    /// migration
    pub migration_chunk: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: Vec::new(),
            heartbeat_interval_ms: 200,
            heartbeat_miss_threshold: 3,
            readmit_after: 2,
            request_timeout_ms: 1000,
            retry_budget: 2,
            retry_backoff_base_ms: 50,
            retry_backoff_cap_ms: 1000,
            migration_chunk: 512,
        }
    }
}

/// Full service configuration with defaults mirroring the paper's
/// experimental setup (Ω = \[0,1\], N = 64, r = 1, 1024 hash functions).
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// master RNG seed
    pub seed: u64,
    /// domain left endpoint
    pub domain_a: f64,
    /// domain right endpoint
    pub domain_b: f64,
    /// embedding dimension N
    pub dim: usize,
    /// embedding method
    pub embedding: EmbeddingKind,
    /// L^p exponent
    pub p: f64,
    /// hash family
    pub hash: HashKind,
    /// bucket width r
    pub r: f64,
    /// input norm cap `c`: when `> 0`, every sample row is promised to
    /// satisfy `‖x‖∞ ≤ c`, which lets the coordinator derive a provable
    /// hash-value bound from the folded matrix and store signatures at
    /// the narrowest admissible width (`i8`/`i16` instead of `i32` —
    /// see `hashing/quantize.rs`). Rows beyond the admitted range get
    /// per-item errors. `0` (default) disables narrowing.
    pub norm_cap: f64,
    /// hashes per table (AND)
    pub k: usize,
    /// number of tables (OR)
    pub l: usize,
    /// multiprobe depth at query time
    pub probe_depth: usize,
    /// number of index shards (id-partitioned)
    pub shards: usize,
    /// dynamic batcher: max batch size
    pub max_batch: usize,
    /// dynamic batcher: max wait before flushing a partial batch
    pub max_wait_us: u64,
    /// worker threads executing batches
    pub workers: usize,
    /// bounded request queue length (backpressure)
    pub queue_depth: usize,
    /// directory holding AOT artifacts
    pub artifacts_dir: String,
    /// use the PJRT pipeline when artifacts are present
    pub use_pjrt: bool,
    /// which AOT pipeline the service executes (e.g. `mc_l2_hash`,
    /// `mc_l2_hash_jnp`)
    pub pipeline: String,
    /// TCP front-end settings
    pub server: ServerConfig,
    /// cluster serving settings (`funclsh route` + shard nodes)
    pub cluster: ClusterConfig,
    /// slice of the 64-bit routing-key space this node owns (`serve
    /// --shard-range`); `None` = single-node service owning everything
    pub shard_range: Option<crate::lsh::ShardRange>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            seed: 0x5EED,
            domain_a: 0.0,
            domain_b: 1.0,
            dim: 64,
            embedding: EmbeddingKind::MonteCarlo,
            p: 2.0,
            hash: HashKind::PStable,
            r: 1.0,
            norm_cap: 0.0,
            k: 2,
            l: 16,
            probe_depth: 1,
            shards: 4,
            max_batch: 128,
            max_wait_us: 200,
            workers: 2,
            queue_depth: 1024,
            artifacts_dir: "artifacts".to_string(),
            use_pjrt: true,
            pipeline: "mc_l2_hash".to_string(),
            server: ServerConfig::default(),
            cluster: ClusterConfig::default(),
            shard_range: None,
        }
    }
}

impl ServiceConfig {
    /// Load from a TOML-subset file content, overlaying defaults.
    pub fn from_toml(text: &str) -> Result<Self, ConfigError> {
        let doc = Toml::parse(text)?;
        let mut cfg = ServiceConfig::default();
        let get_f64 = |s: &str, k: &str| doc.get(s, k).and_then(TomlValue::as_f64);
        let get_usize = |s: &str, k: &str| doc.get(s, k).and_then(TomlValue::as_usize);

        if let Some(v) = get_usize("service", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_f64("domain", "a") {
            cfg.domain_a = v;
        }
        if let Some(v) = get_f64("domain", "b") {
            cfg.domain_b = v;
        }
        if let Some(v) = get_usize("embedding", "dim") {
            cfg.dim = v;
        }
        if let Some(v) = get_f64("embedding", "p") {
            cfg.p = v;
        }
        if let Some(v) = doc.get("embedding", "method").and_then(TomlValue::as_str) {
            cfg.embedding = match v {
                "monte_carlo" | "mc" => EmbeddingKind::MonteCarlo,
                "qmc" | "sobol" => EmbeddingKind::Qmc,
                "chebyshev" | "cheb" => EmbeddingKind::Chebyshev,
                other => {
                    return Err(ConfigError::msg(format!(
                        "unknown embedding method `{other}`"
                    )))
                }
            };
        }
        if let Some(v) = doc.get("hash", "family").and_then(TomlValue::as_str) {
            cfg.hash = match v {
                "pstable" | "l2" => HashKind::PStable,
                "simhash" | "cosine" => HashKind::SimHash,
                other => {
                    return Err(ConfigError::msg(format!("unknown hash family `{other}`")))
                }
            };
        }
        if let Some(v) = get_f64("hash", "r") {
            cfg.r = v;
        }
        if let Some(v) = get_f64("hash", "norm_cap") {
            if !v.is_finite() || v < 0.0 {
                return Err(ConfigError::msg(format!(
                    "hash norm_cap must be finite and >= 0, got {v}"
                )));
            }
            cfg.norm_cap = v;
        }
        if let Some(v) = get_usize("index", "k") {
            cfg.k = v;
        }
        if let Some(v) = get_usize("index", "l") {
            cfg.l = v;
        }
        if let Some(v) = get_usize("index", "probe_depth") {
            cfg.probe_depth = v;
        }
        if let Some(v) = get_usize("index", "shards") {
            cfg.shards = v;
        }
        if let Some(v) = get_usize("batcher", "max_batch") {
            cfg.max_batch = v;
        }
        if let Some(v) = get_usize("batcher", "max_wait_us") {
            cfg.max_wait_us = v as u64;
        }
        if let Some(v) = get_usize("batcher", "queue_depth") {
            cfg.queue_depth = v;
        }
        if let Some(v) = get_usize("service", "workers") {
            cfg.workers = v;
        }
        if let Some(v) = doc.get("runtime", "artifacts_dir").and_then(TomlValue::as_str) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get("runtime", "use_pjrt").and_then(TomlValue::as_bool) {
            cfg.use_pjrt = v;
        }
        if let Some(v) = doc.get("runtime", "pipeline").and_then(TomlValue::as_str) {
            cfg.pipeline = v.to_string();
        }
        if let Some(v) = doc.get("server", "host").and_then(TomlValue::as_str) {
            cfg.server.host = v.to_string();
        }
        if let Some(v) = get_usize("server", "port") {
            if v > u16::MAX as usize {
                return Err(ConfigError::msg(format!("server port {v} out of range")));
            }
            cfg.server.port = v as u16;
        }
        if let Some(v) = get_usize("server", "max_conns") {
            cfg.server.max_conns = v;
        }
        if let Some(v) = doc.get("server", "io_mode").and_then(TomlValue::as_str) {
            cfg.server.io_mode = IoMode::parse(v)
                .ok_or_else(|| ConfigError::msg(format!("unknown io_mode `{v}`")))?;
        }
        if let Some(v) = get_usize("server", "io_workers") {
            cfg.server.io_workers = v;
        }
        if let Some(v) = get_usize("server", "pipeline_depth") {
            cfg.server.pipeline_depth = v;
        }
        if let Some(v) = get_usize("server", "max_inflight_bytes_per_conn") {
            cfg.server.max_inflight_bytes_per_conn = v;
        }
        if let Some(v) = get_usize("server", "max_inflight_bytes") {
            cfg.server.max_inflight_bytes = v;
        }
        if let Some(v) = get_usize("server", "max_write_queue_bytes") {
            cfg.server.max_write_queue_bytes = v;
        }
        if let Some(raw) = doc.get("server", "coalesce") {
            cfg.server.coalesce = raw
                .as_bool()
                .ok_or_else(|| ConfigError::msg("server coalesce must be a boolean"))?;
        }
        if let Some(v) = get_usize("server", "coalesce_window") {
            cfg.server.coalesce_window = v;
        }
        if let Some(v) = doc.get("server", "snapshot_path").and_then(TomlValue::as_str) {
            cfg.server.snapshot_path = v.to_string();
        }
        if let Some(raw) = doc.get("server", "trace") {
            cfg.server.trace = raw
                .as_bool()
                .ok_or_else(|| ConfigError::msg("server trace must be a boolean"))?;
        }
        if let Some(raw) = doc.get("cluster", "nodes") {
            let TomlValue::Array(items) = raw else {
                return Err(ConfigError::msg("cluster nodes must be an array"));
            };
            cfg.cluster.nodes = items
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| ConfigError::msg("cluster nodes must be strings"))
                })
                .collect::<Result<Vec<_>, _>>()?;
        }
        if let Some(v) = get_usize("cluster", "heartbeat_interval_ms") {
            cfg.cluster.heartbeat_interval_ms = v as u64;
        }
        if let Some(v) = get_usize("cluster", "heartbeat_miss_threshold") {
            cfg.cluster.heartbeat_miss_threshold = v as u32;
        }
        if let Some(v) = get_usize("cluster", "readmit_after") {
            cfg.cluster.readmit_after = v as u32;
        }
        if let Some(v) = get_usize("cluster", "request_timeout_ms") {
            cfg.cluster.request_timeout_ms = v as u64;
        }
        if let Some(v) = get_usize("cluster", "retry_budget") {
            cfg.cluster.retry_budget = v as u32;
        }
        if let Some(v) = get_usize("cluster", "retry_backoff_base_ms") {
            cfg.cluster.retry_backoff_base_ms = v as u64;
        }
        if let Some(v) = get_usize("cluster", "retry_backoff_cap_ms") {
            cfg.cluster.retry_backoff_cap_ms = v as u64;
        }
        if let Some(v) = get_usize("cluster", "migration_chunk") {
            cfg.cluster.migration_chunk = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check invariants.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.domain_a >= self.domain_b {
            return Err(ConfigError::msg("domain must satisfy a < b"));
        }
        if self.dim == 0 || self.k == 0 || self.l == 0 {
            return Err(ConfigError::msg("dim, k, l must be positive"));
        }
        if !(0.0..=2.0).contains(&self.p) || self.p == 0.0 {
            return Err(ConfigError::msg("p must be in (0, 2]"));
        }
        if self.r <= 0.0 {
            return Err(ConfigError::msg("r must be positive"));
        }
        if self.max_batch == 0 || self.workers == 0 || self.queue_depth == 0 {
            return Err(ConfigError::msg(
                "max_batch, workers, queue_depth must be positive",
            ));
        }
        if self.shards == 0 {
            return Err(ConfigError::msg("shards must be positive"));
        }
        if self.server.max_conns == 0 {
            return Err(ConfigError::msg("server max_conns must be positive"));
        }
        if self.server.io_workers == 0 || self.server.pipeline_depth == 0 {
            return Err(ConfigError::msg(
                "server io_workers and pipeline_depth must be positive",
            ));
        }
        // no lower bound beyond zero: tests shrink the byte budgets to
        // force deterministic shedding
        if self.server.max_inflight_bytes_per_conn == 0
            || self.server.max_inflight_bytes == 0
            || self.server.max_write_queue_bytes == 0
        {
            return Err(ConfigError::msg(
                "server byte budgets (max_inflight_bytes_per_conn, max_inflight_bytes, \
                 max_write_queue_bytes) must be positive",
            ));
        }
        if self.server.coalesce_window == 0 {
            return Err(ConfigError::msg("server coalesce_window must be positive"));
        }
        if self.cluster.heartbeat_interval_ms == 0
            || self.cluster.heartbeat_miss_threshold == 0
            || self.cluster.readmit_after == 0
        {
            return Err(ConfigError::msg(
                "cluster heartbeat_interval_ms, heartbeat_miss_threshold, readmit_after \
                 must be positive",
            ));
        }
        if self.cluster.request_timeout_ms == 0 {
            return Err(ConfigError::msg("cluster request_timeout_ms must be positive"));
        }
        // retry_budget = 0 is legal: fail a leg on first error
        if self.cluster.retry_backoff_base_ms == 0
            || self.cluster.retry_backoff_cap_ms < self.cluster.retry_backoff_base_ms
        {
            return Err(ConfigError::msg(
                "cluster retry backoff wants 0 < retry_backoff_base_ms <= retry_backoff_cap_ms",
            ));
        }
        if self.cluster.migration_chunk == 0 {
            return Err(ConfigError::msg("cluster migration_chunk must be positive"));
        }
        Ok(())
    }

    /// Total hash functions the index needs (`k·l`).
    pub fn total_hashes(&self) -> usize {
        self.k * self.l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# demo config
[service]
seed = 42
workers = 4

[domain]
a = 0.0
b = 2.0   # inline comment

[embedding]
method = "chebyshev"
dim = 128
p = 2.0

[hash]
family = "pstable"
r = 0.5
norm_cap = 1.5

[index]
k = 3
l = 8
probe_depth = 2

[batcher]
max_batch = 256
max_wait_us = 100
queue_depth = 512

[runtime]
artifacts_dir = "artifacts"
use_pjrt = false

[server]
host = "0.0.0.0"
port = 9099
io_mode = "threaded"
max_conns = 16
io_workers = 8
pipeline_depth = 32
max_inflight_bytes_per_conn = 1048576
max_inflight_bytes = 8388608
max_write_queue_bytes = 4194304
coalesce = false
coalesce_window = 16
snapshot_path = "/tmp/idx.flsh"
trace = false

[cluster]
nodes = ["127.0.0.1:7071", "127.0.0.1:7072", "127.0.0.1:7073"]
heartbeat_interval_ms = 100
heartbeat_miss_threshold = 5
readmit_after = 3
request_timeout_ms = 750
retry_budget = 4
retry_backoff_base_ms = 25
retry_backoff_cap_ms = 400
migration_chunk = 128
"#;

    #[test]
    fn parse_full_config() {
        let cfg = ServiceConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.domain_b, 2.0);
        assert_eq!(cfg.embedding, EmbeddingKind::Chebyshev);
        assert_eq!(cfg.dim, 128);
        assert_eq!(cfg.r, 0.5);
        assert_eq!(cfg.norm_cap, 1.5);
        assert_eq!(cfg.k, 3);
        assert_eq!(cfg.l, 8);
        assert_eq!(cfg.total_hashes(), 24);
        assert_eq!(cfg.max_batch, 256);
        assert!(!cfg.use_pjrt);
        assert_eq!(cfg.server.host, "0.0.0.0");
        assert_eq!(cfg.server.port, 9099);
        assert_eq!(cfg.server.io_mode, IoMode::Threaded);
        assert_eq!(cfg.server.max_conns, 16);
        assert_eq!(cfg.server.io_workers, 8);
        assert_eq!(cfg.server.pipeline_depth, 32);
        assert_eq!(cfg.server.max_inflight_bytes_per_conn, 1 << 20);
        assert_eq!(cfg.server.max_inflight_bytes, 8 << 20);
        assert_eq!(cfg.server.max_write_queue_bytes, 4 << 20);
        assert!(!cfg.server.coalesce);
        assert_eq!(cfg.server.coalesce_window, 16);
        assert_eq!(cfg.server.snapshot_path, "/tmp/idx.flsh");
        assert!(!cfg.server.trace);
        assert_eq!(cfg.cluster.nodes.len(), 3);
        assert_eq!(cfg.cluster.nodes[1], "127.0.0.1:7072");
        assert_eq!(cfg.cluster.heartbeat_interval_ms, 100);
        assert_eq!(cfg.cluster.heartbeat_miss_threshold, 5);
        assert_eq!(cfg.cluster.readmit_after, 3);
        assert_eq!(cfg.cluster.request_timeout_ms, 750);
        assert_eq!(cfg.cluster.retry_budget, 4);
        assert_eq!(cfg.cluster.retry_backoff_base_ms, 25);
        assert_eq!(cfg.cluster.retry_backoff_cap_ms, 400);
        assert_eq!(cfg.cluster.migration_chunk, 128);
        assert_eq!(cfg.shard_range, None, "shard range is CLI-only");
    }

    #[test]
    fn norm_cap_validated() {
        assert!(ServiceConfig::from_toml("[hash]\nnorm_cap = -1.0\n").is_err());
        let cfg = ServiceConfig::from_toml("").unwrap();
        assert_eq!(cfg.norm_cap, 0.0, "narrowing is opt-in");
        let cfg = ServiceConfig::from_toml("[hash]\nnorm_cap = 2.0\n").unwrap();
        assert_eq!(cfg.norm_cap, 2.0);
    }

    #[test]
    fn cluster_section_validated() {
        assert!(ServiceConfig::from_toml("[cluster]\nheartbeat_interval_ms = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nheartbeat_miss_threshold = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nreadmit_after = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nrequest_timeout_ms = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nretry_backoff_base_ms = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nmigration_chunk = 0\n").is_err());
        // cap below base is an inverted schedule
        assert!(ServiceConfig::from_toml(
            "[cluster]\nretry_backoff_base_ms = 100\nretry_backoff_cap_ms = 50\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nnodes = \"host\"\n").is_err());
        assert!(ServiceConfig::from_toml("[cluster]\nnodes = [1, 2]\n").is_err());
        // retry_budget = 0 legal (fail fast), defaults validate
        let cfg = ServiceConfig::from_toml("[cluster]\nretry_budget = 0\n").unwrap();
        assert_eq!(cfg.cluster.retry_budget, 0);
        assert!(cfg.cluster.nodes.is_empty());
    }

    #[test]
    fn server_section_validated() {
        assert!(ServiceConfig::from_toml("[server]\nport = 70000\n").is_err());
        assert!(ServiceConfig::from_toml("[server]\nmax_conns = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[server]\nio_workers = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[server]\npipeline_depth = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[server]\nio_mode = \"fibers\"\n").is_err());
        let cfg = ServiceConfig::from_toml("[server]\nport = 0\n").unwrap();
        assert_eq!(cfg.server.port, 0);
        assert_eq!(cfg.server.io_mode, IoMode::EventLoop);
        let cfg = ServiceConfig::from_toml("[server]\nio_mode = \"epoll\"\n").unwrap();
        assert_eq!(cfg.server.io_mode, IoMode::EventLoop);
        // tracing defaults on; non-boolean values are rejected
        let cfg = ServiceConfig::from_toml("[server]\nport = 0\n").unwrap();
        assert!(cfg.server.trace);
        assert!(ServiceConfig::from_toml("[server]\ntrace = 1\n").is_err());
        // admission-control budgets: zero rejected, tiny values allowed
        // (tests use them to force deterministic sheds)
        assert!(ServiceConfig::from_toml("[server]\nmax_inflight_bytes = 0\n").is_err());
        assert!(
            ServiceConfig::from_toml("[server]\nmax_inflight_bytes_per_conn = 0\n").is_err()
        );
        assert!(ServiceConfig::from_toml("[server]\nmax_write_queue_bytes = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[server]\ncoalesce_window = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[server]\ncoalesce = \"yes\"\n").is_err());
        let cfg = ServiceConfig::from_toml("[server]\nmax_inflight_bytes = 64\n").unwrap();
        assert_eq!(cfg.server.max_inflight_bytes, 64);
        assert!(cfg.server.coalesce);
        assert_eq!(cfg.server.coalesce_window, 64);
    }

    #[test]
    fn defaults_are_paper_parameters() {
        let cfg = ServiceConfig::default();
        assert_eq!(cfg.dim, 64);
        assert_eq!(cfg.r, 1.0);
        assert_eq!(cfg.domain_a, 0.0);
        assert_eq!(cfg.domain_b, 1.0);
        cfg.validate().unwrap();
    }

    #[test]
    fn empty_config_gives_defaults() {
        let cfg = ServiceConfig::from_toml("").unwrap();
        assert_eq!(cfg, ServiceConfig::default());
    }

    #[test]
    fn invalid_domain_rejected() {
        let bad = "[domain]\na = 2.0\nb = 1.0\n";
        assert!(ServiceConfig::from_toml(bad).is_err());
    }

    #[test]
    fn unknown_method_rejected() {
        let bad = "[embedding]\nmethod = \"fourier\"\n";
        assert!(ServiceConfig::from_toml(bad).is_err());
    }

    #[test]
    fn toml_arrays_and_types() {
        let doc = Toml::parse("[x]\nv = [1, 2, 3]\ns = \"hi\"\nb = true\nf = 1.5\n").unwrap();
        match doc.get("x", "v").unwrap() {
            TomlValue::Array(a) => assert_eq!(a.len(), 3),
            other => panic!("{other:?}"),
        }
        assert_eq!(doc.get("x", "s").unwrap().as_str(), Some("hi"));
        assert_eq!(doc.get("x", "b").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("x", "f").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn comments_inside_strings_preserved() {
        let doc = Toml::parse("[x]\ns = \"a # not comment\"\n").unwrap();
        assert_eq!(doc.get("x", "s").unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn parse_errors_carry_line() {
        let e = Toml::parse("[x]\nkey value\n").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
