//! Minimal JSON support (no serde in the offline vendor set): a value
//! model, a recursive-descent parser, and a writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), metrics
//! output, and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (stored as f64)
    Number(f64),
    /// string
    String(String),
    /// array
    Array(Vec<Value>),
    /// object (ordered for deterministic output)
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As u64. Must be a non-negative integer no larger than 2^53 (the
    /// JSON-number precision limit) — beyond that the f64 carrier has
    /// already rounded the value, so rather than hand back a silently
    /// altered id this returns `None`.
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Value::Number(n) if *n >= 0.0 && *n <= MAX_EXACT && n.fract() == 0.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::String(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from `(key, value)` pairs.
pub fn object(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// human-readable message
    pub msg: String,
    /// byte offset where the error occurred
    pub pos: usize,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex =
                            std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // (surrogate pairs unsupported — manifest is ASCII)
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("bad utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = object(vec![
            ("name", "pipeline".into()),
            ("batch", 128usize.into()),
            ("scale", 0.125.into()),
            ("ok", true.into()),
            ("tags", vec!["a", "b"].into()),
        ]);
        let s = v.to_json();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[1].as_f64(),
            Some(2.5)
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(parse("17").unwrap().as_usize(), Some(17));
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(parse("17").unwrap().as_u64(), Some(17));
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        // 2^53 is the last exactly-representable integer; beyond it the
        // value has been rounded and must be refused
        assert_eq!(
            parse("9007199254740992").unwrap().as_u64(),
            Some(9_007_199_254_740_992)
        );
        assert_eq!(parse("9007199254740994").unwrap().as_u64(), None);
        assert_eq!(parse("18446744073709551615").unwrap().as_u64(), None);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(parse("\"π≈3\"").unwrap().as_str(), Some("π≈3"));
    }

    #[test]
    fn errors_carry_position() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 6, "{e}");
        assert!(parse("[1, 2").is_err());
        assert!(parse("[1] junk").is_err());
    }

    #[test]
    fn escaping_in_writer() {
        let v = Value::String("a\"b\\c\nd".to_string());
        assert_eq!(v.to_json(), r#""a\"b\\c\nd""#);
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Value::Number(64.0).to_json(), "64");
        assert_eq!(Value::Number(0.5).to_json(), "0.5");
    }
}
