//! Numerical quadrature and exact (to quadrature precision) functional
//! similarities.
//!
//! These are the *ground truth* engines: every experiment compares the
//! hashed/embedded similarity against values computed here. Provided rules:
//!
//! * [`gauss_legendre`] — Golub–Welsch-free Newton iteration on Legendre
//!   polynomials; spectrally accurate for smooth integrands.
//! * [`clenshaw_curtis`] — nested Chebyshev-node rule (useful when samples
//!   at Chebyshev points are already available).
//! * [`adaptive_simpson`] — robust fallback for kinky integrands (e.g. the
//!   clipped inverse CDFs of the paper's footnote 1).
//!
//! On top of the rules: `L^p` distances, `L²` inner products and cosine
//! similarity on any [`Function1D`].

use crate::functions::Function1D;
use std::f64::consts::PI;

/// Nodes and weights of the `n`-point Gauss–Legendre rule on `[-1, 1]`.
///
/// Roots of `P_n` by Newton's method from the Tricomi initial guess;
/// weights `w_i = 2 / ((1 - x_i²) P'_n(x_i)²)`. Accurate to ~1e-15 for
/// `n ≤ 10⁴`.
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n > 0);
    let mut xs = vec![0.0; n];
    let mut ws = vec![0.0; n];
    let m = n.div_ceil(2);
    for i in 0..m {
        // initial guess (Tricomi)
        let mut x = (PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        let mut dp = 0.0;
        for _ in 0..100 {
            // evaluate P_n and P'_n via the three-term recurrence
            let mut p0 = 1.0;
            let mut p1 = x;
            for k in 2..=n {
                let p2 = ((2 * k - 1) as f64 * x * p1 - (k - 1) as f64 * p0) / k as f64;
                p0 = p1;
                p1 = p2;
            }
            // P'_n(x) = n (x P_n - P_{n-1}) / (x² - 1)
            dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
            let dx = p1 / dp;
            x -= dx;
            if dx.abs() < 1e-16 {
                break;
            }
        }
        xs[i] = -x;
        xs[n - 1 - i] = x;
        let w = 2.0 / ((1.0 - x * x) * dp * dp);
        ws[i] = w;
        ws[n - 1 - i] = w;
    }
    if n % 2 == 1 {
        // middle node is exactly 0 for odd n
        xs[n / 2] = 0.0;
    }
    (xs, ws)
}

/// Integrate `f` over `[a, b]` with `n`-point Gauss–Legendre.
pub fn integrate_gl(f: &dyn Function1D, a: f64, b: f64, n: usize) -> f64 {
    let (xs, ws) = gauss_legendre(n);
    let c = 0.5 * (b - a);
    let mid = 0.5 * (a + b);
    xs.iter()
        .zip(&ws)
        .map(|(&x, &w)| w * f.eval(mid + c * x))
        .sum::<f64>()
        * c
}

/// Clenshaw–Curtis nodes/weights on `[-1, 1]` (practical points
/// `x_k = cos(kπ/n)`, `k = 0..=n`). Exact for polynomials of degree ≤ n.
pub fn clenshaw_curtis(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 2 && n % 2 == 0, "use an even number of intervals");
    let xs: Vec<f64> = (0..=n).map(|k| (PI * k as f64 / n as f64).cos()).collect();
    let mut ws = vec![0.0; n + 1];
    for (k, wk) in ws.iter_mut().enumerate() {
        let theta = PI * k as f64 / n as f64;
        let mut s = 0.0;
        for j in 1..=n / 2 {
            let b = if j == n / 2 { 1.0 } else { 2.0 };
            s += b * (2.0 * j as f64 * theta).cos() / (4.0 * j as f64 * j as f64 - 1.0);
        }
        let c = if k == 0 || k == n { 1.0 } else { 2.0 };
        *wk = c / n as f64 * (1.0 - s);
    }
    (xs, ws)
}

/// Integrate `f` over `[a, b]` with the `n`-interval Clenshaw–Curtis rule.
pub fn integrate_cc(f: &dyn Function1D, a: f64, b: f64, n: usize) -> f64 {
    let (xs, ws) = clenshaw_curtis(n);
    let c = 0.5 * (b - a);
    let mid = 0.5 * (a + b);
    xs.iter()
        .zip(&ws)
        .map(|(&x, &w)| w * f.eval(mid + c * x))
        .sum::<f64>()
        * c
}

/// Adaptive Simpson quadrature to absolute tolerance `tol`.
pub fn adaptive_simpson(f: &dyn Function1D, a: f64, b: f64, tol: f64) -> f64 {
    fn simpson(fa: f64, fm: f64, fb: f64, a: f64, b: f64) -> f64 {
        (b - a) / 6.0 * (fa + 4.0 * fm + fb)
    }
    #[allow(clippy::too_many_arguments)]
    fn rec(
        f: &dyn Function1D,
        a: f64,
        b: f64,
        fa: f64,
        fm: f64,
        fb: f64,
        whole: f64,
        tol: f64,
        depth: u32,
    ) -> f64 {
        let m = 0.5 * (a + b);
        let lm = 0.5 * (a + m);
        let rm = 0.5 * (m + b);
        let flm = f.eval(lm);
        let frm = f.eval(rm);
        let left = simpson(fa, flm, fm, a, m);
        let right = simpson(fm, frm, fb, m, b);
        let delta = left + right - whole;
        if depth == 0 || delta.abs() <= 15.0 * tol {
            left + right + delta / 15.0
        } else {
            rec(f, a, m, fa, flm, fm, left, tol / 2.0, depth - 1)
                + rec(f, m, b, fm, frm, fb, right, tol / 2.0, depth - 1)
        }
    }
    let m = 0.5 * (a + b);
    let fa = f.eval(a);
    let fm = f.eval(m);
    let fb = f.eval(b);
    let whole = simpson(fa, fm, fb, a, b);
    rec(f, a, b, fa, fm, fb, whole, tol, 50)
}

/// Default node count for the similarity helpers below — enough for
/// machine precision on the smooth workloads of the paper's experiments.
const DEFAULT_GL_NODES: usize = 256;

/// `‖f − g‖_{L^p([a,b])}` by Gauss–Legendre quadrature (Lebesgue measure).
pub fn lp_distance(f: &dyn Function1D, g: &dyn Function1D, a: f64, b: f64, p: f64) -> f64 {
    assert!(p > 0.0);
    let diff = move |x: f64| (f.eval(x) - g.eval(x)).abs().powf(p);
    integrate_gl(&diff, a, b, DEFAULT_GL_NODES).max(0.0).powf(1.0 / p)
}

/// `⟨f, g⟩_{L²([a,b])}` by Gauss–Legendre quadrature.
pub fn inner_product_l2(f: &dyn Function1D, g: &dyn Function1D, a: f64, b: f64) -> f64 {
    let prod = move |x: f64| f.eval(x) * g.eval(x);
    integrate_gl(&prod, a, b, DEFAULT_GL_NODES)
}

/// `‖f‖_{L²([a,b])}`.
pub fn norm_l2(f: &dyn Function1D, a: f64, b: f64) -> f64 {
    inner_product_l2(f, f, a, b).max(0.0).sqrt()
}

/// Cosine similarity `⟨f,g⟩ / (‖f‖·‖g‖)` in `L²([a,b])`.
pub fn cosine_similarity_l2(f: &dyn Function1D, g: &dyn Function1D, a: f64, b: f64) -> f64 {
    let ip = inner_product_l2(f, g, a, b);
    let nf = norm_l2(f, a, b);
    let ng = norm_l2(g, a, b);
    (ip / (nf * ng)).clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::Sine;

    #[test]
    fn gl_nodes_symmetric_weights_sum_to_two() {
        for &n in &[1usize, 2, 5, 16, 64] {
            let (xs, ws) = gauss_legendre(n);
            assert!((ws.iter().sum::<f64>() - 2.0).abs() < 1e-13, "n = {n}");
            for i in 0..n {
                assert!((xs[i] + xs[n - 1 - i]).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn gl_5_known_nodes() {
        // 5-point GL: largest node = sqrt(5 + 2 sqrt(10/7)) / 3
        let (xs, _) = gauss_legendre(5);
        let want = (5.0 + 2.0 * (10.0f64 / 7.0).sqrt()).sqrt() / 3.0;
        assert!((xs[4] - want).abs() < 1e-14, "{} vs {want}", xs[4]);
        assert!(xs[2].abs() < 1e-15);
    }

    #[test]
    fn gl_exact_for_polynomials() {
        // n-point GL is exact for degree 2n-1
        let f = |x: f64| 5.0 * x.powi(7) - 2.0 * x.powi(4) + x;
        // ∫_{-1}^{1} = -4/5 (only even powers survive)
        let got = integrate_gl(&f, -1.0, 1.0, 4);
        assert!((got + 0.8).abs() < 1e-13, "{got}");
    }

    #[test]
    fn gl_smooth_integrand() {
        let f = |x: f64| x.exp();
        let got = integrate_gl(&f, 0.0, 1.0, 20);
        assert!((got - (std::f64::consts::E - 1.0)).abs() < 1e-14);
    }

    #[test]
    fn cc_weights_sum_to_two_and_integrate() {
        let (_, ws) = clenshaw_curtis(16);
        assert!((ws.iter().sum::<f64>() - 2.0).abs() < 1e-12);
        let f = |x: f64| (3.0 * x).cos();
        let want = 2.0 * (3.0f64).sin() / 3.0;
        let got = integrate_cc(&f, -1.0, 1.0, 32);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn adaptive_simpson_kinky_integrand() {
        let f = |x: f64| x.abs().sqrt();
        // ∫_{-1}^{1} sqrt|x| dx = 4/3
        let got = adaptive_simpson(&f, -1.0, 1.0, 1e-10);
        assert!((got - 4.0 / 3.0).abs() < 1e-8, "{got}");
    }

    #[test]
    fn l2_distance_of_shifted_sines_closed_form() {
        // ‖sin(2πx+δ1) − sin(2πx+δ2)‖²_{L²[0,1]} = 1 − cos(δ1−δ2)
        let d1 = 0.4;
        let d2 = 1.9;
        let f = Sine::paper(d1);
        let g = Sine::paper(d2);
        let want = (1.0 - (d1 - d2 as f64).cos()).sqrt();
        let got = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn cosine_similarity_of_sines_closed_form() {
        // cossim(sin(2πx+δ1), sin(2πx+δ2)) = cos(δ1 − δ2) on [0,1]
        let d1 = 0.3;
        let d2 = 2.0;
        let f = Sine::paper(d1);
        let g = Sine::paper(d2);
        let got = cosine_similarity_l2(&f, &g, 0.0, 1.0);
        assert!((got - (d1 - d2 as f64).cos()).abs() < 1e-12);
    }

    #[test]
    fn l1_distance() {
        // ‖x − 0‖_{L¹[0,1]} = 1/2
        let f = |x: f64| x;
        let g = |_x: f64| 0.0;
        let got = lp_distance(&f, &g, 0.0, 1.0, 1.0);
        assert!((got - 0.5).abs() < 1e-10);
    }

    #[test]
    fn fractional_p_distance() {
        // p = 0.5 quasi-norm of f(x) = 1: (∫ 1 dx)^2 = 1
        let f = |_x: f64| 1.0;
        let g = |_x: f64| 0.0;
        let got = lp_distance(&f, &g, 0.0, 1.0, 0.5);
        assert!((got - 1.0).abs() < 1e-10);
    }
}
