//! The network serving layer: a TCP front-end over the
//! [`crate::coordinator`] batching worker pool, with two interchangeable
//! I/O runtimes selected by `[server] io_mode` and two wire formats
//! negotiated per connection.
//!
//! # Architecture
//!
//! **`io_mode = "event_loop"`** (default, Linux) — readiness-based:
//!
//! ```text
//! clients ── TCP ──▶ epoll thread (accept + non-blocking reads +
//!                    incremental framing + write flushing)
//!                         │ Job queue (bounded)
//!                    io_workers threads ──▶ Coordinator::submit_async
//!                         │                 (dynamic batcher: concurrent
//!                         │                  connections share batched
//!                         │                  hash executions)
//!                    completions ──▶ per-conn reorder buffer ──▶ socket
//! ```
//!
//! One thread multiplexes thousands of idle connections; the
//! fixed worker pool turns wire concurrency into batch occupancy.
//!
//! **`io_mode = "threaded"`** (fallback, all platforms) — the PR 1
//! acceptor + connection-handler pool: `max_conns` threads, each owning
//! one connection at a time with blocking reads.
//!
//! # Wire formats and mode negotiation
//!
//! Both runtimes speak **two frame formats on the same port**; a
//! connection's first bytes select its format for the connection's whole
//! lifetime:
//!
//! * a connection whose first five bytes are `FBIN1`
//!   ([`protocol::BINARY_MAGIC`]) speaks the **length-prefixed binary**
//!   format from the byte after the magic on;
//! * any other first byte (valid JSON starts with `{` or whitespace)
//!   selects **newline-delimited JSON** — the default, and what `nc`
//!   speaks. Garbage that merely resembles the magic (e.g. `FBINX…`)
//!   falls through to the JSON parser's error envelope.
//!
//! Either way the cap is 8 MiB per frame payload
//! ([`protocol::MAX_FRAME_BYTES`]), and every request may carry an
//! optional `req_id` (u64) that is echoed in its response.
//!
//! ## The shared framer
//!
//! Both runtimes consume the same incremental [`protocol::Framer`] —
//! the *only* negotiation/framing state machine in the tree. Its
//! contract (push/poll semantics):
//!
//! * `push(bytes)` appends raw socket bytes; `next()` yields each
//!   complete frame exactly once, in order, **independent of chunking**
//!   — byte-at-a-time and whole-buffer feeds decode identically
//!   (property-proved in `tests/framer_properties.rs`).
//! * Negotiation state (`Probe` → JSON/binary) lives inside: the first
//!   bytes pick the mode, `Framer::negotiated()` reports it, and probe
//!   state answers default to JSON.
//! * Cap behavior: a JSON line past 8 MiB (with or without its newline)
//!   and a binary length prefix declaring > 8 MiB are **fatal** — the
//!   framer emits one `Fatal` step (answered with an error envelope,
//!   then close-after-flush) and yields nothing further, because the
//!   framing cannot resync past either. All other malformed input is
//!   per-frame and leaves the connection usable.
//! * `push_eof()` ends the stream: a final unterminated JSON line is
//!   still a frame; a binary frame truncated by EOF is fatal.
//! * `compact()` drops the consumed prefix once per read burst, so a
//!   pipelined burst is memmoved once, not once per frame.
//!
//! Clients read reply frames with the blocking mirror
//! [`protocol::read_frame`].
//!
//! ## JSON frames
//!
//! One UTF-8 JSON object per `\n`-terminated line. **Integer width:**
//! ids and `req_id`s ride JSON numbers (f64), so request values ≥ 2^53
//! are rejected rather than silently rounded — use the binary format
//! for full-width ids. The same rule guards the **response** path: a
//! response that would carry a full-width id (inserted earlier over the
//! binary wire) back to a JSON connection degrades to a correlated
//! per-request error (per-item inside batch envelopes) instead of
//! corrupting the id on the wire.
//!
//! Requests:
//!
//! ```text
//! {"op":"hash",     "samples":[f32…]}
//! {"op":"insert",   "id":u64, "samples":[f32…]}
//! {"op":"query",    "samples":[f32…], "k":usize}
//! {"op":"remove",   "id":u64}
//! {"op":"metrics"}
//! {"op":"stats",    "detail":"summary"}  (observability views; `detail` is
//!                                         optional — "summary" (default),
//!                                         "stages", "index", or "slow")
//! {"op":"snapshot", "path":"…"}          (full-state dump — FLSH1 index
//!                                         block + EMBS1 entry store —
//!                                         to a server-side path)
//! {"op":"ping"}
//! {"op":"points"}                        (published sample points)
//! {"op":"shutdown"}                      (graceful stop + shutdown snapshot)
//! {"op":"hash_batch",   "rows":[[f32…]…]}
//! {"op":"insert_batch", "ids":[u64…], "rows":[[f32…]…]}
//! {"op":"query_batch",  "rows":[[f32…]…], "k":usize}
//! {"op":"migrate_pull",    "from_id":u64, "max":usize}   (inter-node)
//! {"op":"entries_push",    "entries":[{"id":…, "emb":[f64…],
//!                                      "sig":[i32…]}…]}  (inter-node)
//! {"op":"entries_discard", "ids":[u64…]}                 (inter-node)
//! ```
//!
//! The `*_batch` ops carry N rows in **one frame** (one syscall, one
//! reorder-buffer slot, one response frame) and fan out into the
//! coordinator's dynamic batcher, so a single frame fills a kernel
//! batch. Errors are **per item**: a row that fails decode (non-finite
//! sample) or execution (wrong dimension, duplicate id) fails only its
//! slot in the batch envelope — its neighbours still answer. A batch
//! must carry ≥ 1 row; `ids` and `rows` lengths must agree.
//!
//! Responses are an envelope with `"ok"`:
//!
//! ```text
//! {"ok":true, "req_id":…, "type":"signature", "signature":[i32…]}
//! {"ok":true, "req_id":…, "type":"inserted",  "id":u64}
//! {"ok":true, "req_id":…, "type":"hits",      "hits":[{"id":u64,"distance":f64}…]}
//! {"ok":true, "req_id":…, "type":"removed",   "id":u64}
//! {"ok":true, "req_id":…, "type":"metrics",   "metrics":{…}}
//! {"ok":true, "req_id":…, "type":"stats",     "stats":{"detail":…, …}}
//! {"ok":true, "req_id":…, "type":"snapshot",  "path":"…", "bytes":u64}
//! {"ok":true, "req_id":…, "type":"pong",      "indexed":u64}
//! {"ok":true, "req_id":…, "type":"points",    "points":[f64…]}
//! {"ok":true, "req_id":…, "type":"shutting_down"}
//! {"ok":true, "req_id":…, "type":"batch",
//!             "results":[{"ok":true,"type":…,…} | {"ok":false,"error":"…"}, …]}
//! {"ok":true, "req_id":…, "type":"batch_part", "more":bool,
//!             "results":[…]}                   (streamed batch continuation —
//!                                              see "Streaming replies")
//! {"ok":false,"req_id":…, "error":"…"}        (error envelope, both
//!                                              bad requests and op failures)
//! {"ok":false,"req_id":…, "error":"overloaded: …; retry with backoff",
//!             "code":"overloaded"}            (typed load-shed envelope —
//!                                              see "Admission control")
//! ```
//!
//! Batch `results` entries use the same body as the single-op responses
//! and arrive in request row order.
//!
//! ## Binary frames (`FBIN1`)
//!
//! After the 5-byte magic, every frame in **both** directions is a
//! little-endian `u32` payload length followed by the payload. All
//! multi-byte integers and floats are little-endian; sample rows are raw
//! `f32` bits (4 bytes/sample vs ~9–13 bytes of JSON text — the reason
//! this format exists), and ids are native `u64`s with **no 2^53
//! limit**.
//!
//! Request payload: `op:u8`, `flags:u8` (bit 0 = a `req_id:u64`
//! follows), then the op body:
//!
//! ```text
//! op 1 hash      n:u32, samples:[f32; n]
//! op 2 insert    id:u64, n:u32, samples:[f32; n]
//! op 3 query     n:u32, samples:[f32; n], k:u64
//! op 4 remove    id:u64
//! op 5 metrics   —
//! op 6 snapshot  len:u32, path:[utf8; len]
//! op 7 ping      —
//! op 8 points    —
//! op 9 shutdown  —
//! op 10 hash_batch    count:u32, dim:u32, samples:[f32; count·dim]
//! op 11 insert_batch  count:u32, dim:u32, ids:[u64; count],
//!                     samples:[f32; count·dim]
//! op 12 query_batch   count:u32, dim:u32, samples:[f32; count·dim], k:u64
//! op 13 stats         detail:u8 (0 summary, 1 stages, 2 index, 3 slow,
//!                                4 cluster)
//! op 14 migrate_pull    from_id:u64, max:u64            (inter-node)
//! op 15 entries_push    count:u32, then per entry id:u64,
//!                       emb_len:u32, emb:[f64…],
//!                       sig_len:u32, sig:[i32…]         (inter-node)
//! op 16 entries_discard count:u32, ids:[u64; count]     (inter-node)
//! ```
//!
//! Batch rows are contiguous (`row r` occupies samples
//! `[r·dim, (r+1)·dim)`); `count` and `dim` must both be positive and
//! `count·dim·4` must fit the declared payload — violations are
//! frame-level errors (still correlated by `req_id`), while a
//! non-finite value fails only its row's slot.
//!
//! Response payload: `status:u8` (0 = ok, 1 = error), `flags:u8` (bit 0
//! = `req_id:u64` follows). Errors carry `len:u32, msg:[utf8; len]`,
//! optionally followed by one machine-readable code byte (today only
//! `1` = overloaded; absent on plain errors — decoders must treat it as
//! optional). Successes carry `type:u8` + body mirroring the JSON
//! responses (`signature` = `n:u32` + raw `i32`s, `hits` = `n:u32` +
//! `(id:u64, distance:f64)` pairs, `metrics` and `stats` = a
//! length-prefixed JSON string, `points` = `n:u32` + `f64`s, acks =
//! their `u64`). Batch responses are `type:u8 = 10` + `n:u32` + per
//! item a `status:u8` followed by either the single-op reply body (ok)
//! or `len:u32, msg:[utf8; len]` (error), in request row order. A
//! streamed batch continuation is `type:u8 = 12` + `more:u8` (1 = more
//! parts follow) + `n:u32` + the same per-item encoding.
//!
//! ## Inter-node wire ops and the degraded envelope
//!
//! Three ops exist for node-to-node traffic inside a cluster (see
//! [`crate::cluster`]); ordinary clients never need them, but they ride
//! the same two wire formats as everything else, so a shard is just a
//! server:
//!
//! * `migrate_pull` (op 14) streams one ordered chunk of the entry
//!   store: the reply is `entries` (binary reply tag 14) — `done:u8`,
//!   `count:u32`, then each entry as `id:u64`, length-prefixed `f64`
//!   re-rank embedding, length-prefixed `i32` signature.
//!   The cursor is stateless: `from_id` is **inclusive**,
//!   the next pull passes `last_returned_id + 1`, so a retried pull
//!   re-reads instead of skipping.
//! * `entries_push` (op 15) ingests entries **by overwrite** — pushing
//!   the same entry twice is idempotent, which is what makes migration
//!   retries and the delta sweep safe. Ack is `ingested` (tag 15) with
//!   the applied count.
//! * `entries_discard` (op 16) drops ids if present (idempotent, acks
//!   the number actually dropped) — the migration rollback primitive.
//!
//! The **degraded envelope** is how a router answers when some shards
//! could not contribute. It wraps an otherwise-normal reply and names
//! the missing key ranges:
//!
//! ```text
//! {"ok":true, "req_id":…, "type":"degraded",
//!  "missing":["lo-hi@addr", …], "result":{…inner reply…}}
//! ```
//!
//! On the binary wire it is reply tag 13: `n:u32` missing labels
//! (length-prefixed UTF-8), then the complete inner reply body. The
//! wrapper is **top-level only** — an inner reply can never itself be
//! degraded (decoders reject nesting), so one level of unwrapping
//! always yields a plain reply. Item-level unavailability inside
//! batches uses typed `degraded: …; retry with backoff` error strings
//! instead (JSON adds `"code":"degraded"`, binary a trailing code byte
//! `2`); [`protocol::error_is_degraded`] matches both. A degraded reply
//! is an *answer*, not a transport fault — clients must not blindly
//! retry it, the data that did arrive is valid.
//!
//! ## Sample validation
//!
//! Both decoders reject non-finite samples — raw `NaN`/`±inf` bits on
//! the binary path, and JSON numbers that are non-finite *or overflow
//! `f32` to `±inf`* (e.g. `1e39`) — with a per-request error envelope;
//! the coordinator's `Insert` path additionally refuses non-finite rows
//! defensively. A poisoned sample would otherwise corrupt the index and
//! every re-rank distance it touches.
//!
//! ## Admission control and the `overloaded` envelope
//!
//! Every coordinator frame is charged its request payload bytes against
//! two budgets at decode time, **before** it is queued:
//!
//! * `[server] max_inflight_bytes_per_conn` (default 16 MiB) — bytes
//!   one connection may have in flight (dispatched, not yet answered);
//! * `[server] max_inflight_bytes` (default 128 MiB) — the same, summed
//!   across all connections.
//!
//! A frame that would exceed either budget is **shed**: it is answered
//! immediately — in order, with its `req_id` echoed — by a typed
//! `overloaded` envelope, and the connection stays fully usable. The
//! JSON shape is
//!
//! ```text
//! {"ok":false, "code":"overloaded",
//!  "error":"overloaded: <scope>; retry with backoff", "req_id":…}
//! ```
//!
//! where `<scope>` names the exhausted budget (`connection in-flight
//! byte budget`, `server in-flight byte budget`, or the write-queue
//! bound below). On the binary wire the same condition is a status-1
//! error whose message is followed by one trailing code byte `1`;
//! clients should treat the code byte as optional and may equally match
//! on the `overloaded: ` message prefix (what
//! [`protocol::error_is_overloaded`] does). Sheds are counted in
//! `overload_sheds`; connections refused before serving began
//! (accept-queue overflow, poller registration failure) in
//! `rejected_accepts`.
//!
//! A **slow-reading client** — one whose pending output (unflushed
//! write buffer plus parked out-of-order completions) exceeds
//! `[server] max_write_queue_bytes` (default 64 MiB) — is sent a final
//! `overloaded` envelope (best effort) and disconnected, counted in
//! `slow_client_disconnects`; the reorder buffer is bounded by
//! construction. The threaded runtime answers one frame at a time per
//! connection, so only the per-frame and global budgets apply there.
//!
//! ## Server-side coalescing
//!
//! With `[server] coalesce = true` (the default), the event loop folds
//! **adjacent single-op frames** drained from one connection in one
//! read pass — up to `coalesce_window` (default 64) of them — into one
//! synthetic server-side batch job, so naive single-op clients
//! co-occupy kernel batches like `*_batch` callers. The fold is
//! invisible on the wire:
//!
//! * **ordering** — each member keeps its own reorder seq, so replies
//!   flush in request order exactly as without coalescing;
//! * **framing** — each member is answered with its own response frame,
//!   byte-identical to the uncoalesced reply (same `req_id` echo, same
//!   envelope);
//! * **tracing** — each member keeps its own span (decode stamped at
//!   frame parse, kernel/encode/write-queued stamped on its own op).
//!
//! Batch frames, transport ops, and parse failures break a run (they
//! dispatch the accumulated group first); coalesced frame counts land
//! in the `coalesced_frames` metric.
//!
//! ## Streaming replies (continuation frames)
//!
//! A batch response too large for one 8 MiB envelope no longer degrades
//! to an error: it is emitted as a sequence of **continuation frames**,
//! each a legal ≤ 8 MiB frame in the connection's wire format, carrying
//! a contiguous run of the batch's per-item results in order:
//!
//! * JSON: `{"ok":true,"type":"batch_part","more":bool,"results":[…],
//!   "req_id":…}` — `more:false` marks the final part;
//! * binary: `status:u8 = 0`, flags/req_id, `type:u8 = 12`, `more:u8`,
//!   `count:u32`, then `count` items in the batch-item encoding.
//!
//! Every part echoes the request's `req_id`. [`Client`] and
//! [`PipelinedClient`] reassemble parts transparently and deliver one
//! ordinary `batch` reply, so callers never see parts. A *single item*
//! that alone cannot fit a frame (one query's hits > 8 MiB) still
//! degrades to a correlated per-item error in its slot. Single-op
//! (non-batch) oversized responses keep the PR 5 behavior: a correlated
//! per-request error envelope.
//!
//! ## Per-wire-mode metrics
//!
//! Both runtimes feed per-format counters into the service metrics:
//! `conns_json`/`conns_binary` (connections as negotiated),
//! `frames_json`/`frames_binary` (request frames decoded),
//! `bytes_in_json`/`bytes_in_binary` (request wire bytes: payload plus
//! framing overhead — the newline or the `u32` length prefix, plus the
//! one-time `FBIN1` magic — so the counters reconcile against a packet
//! capture), and `bytes_out_json`/`bytes_out_binary` (response bytes
//! queued, whole frames) — so the `bench-wire` grid can be
//! cross-checked against a live server's `metrics` op. Overload
//! behavior is observable via `overload_sheds`, `rejected_accepts`,
//! `coalesced_frames`, and `slow_client_disconnects`.
//!
//! ## Request tracing and the `stats` op
//!
//! Unless tracing is disabled (`funclsh serve --no-trace`, or
//! `[server] trace = false`), both runtimes stamp a [`crate::trace::Span`]
//! through every coordinator op's lifecycle: *decode* (frame parse) →
//! *queue_wait* (admission queue → batcher pop) → *batch_form* (row
//! collection) → *kernel* (blocked hash + embed) → *index_probe* (insert /
//! remove / multiprobe lookup) → *rerank* (exact re-rank, queries only) →
//! *encode* (response serialization) → *write_queued* (bytes handed to the
//! socket). The stamps *partition* a request's wall time — each stage is
//! charged the time since the previous stamp, so the per-stage sum equals
//! the end-to-end latency by construction. Finished spans land in
//! lock-free per-stage × per-op-kind × per-wire-mode histograms and a
//! worst-K slow-request ring, all served by the `stats` op:
//!
//! * `detail:"summary"` — counters + per-stage rollup + index totals,
//! * `detail:"stages"` — every non-empty histogram cell (count, sum,
//!   p50/p99, log₂ ns buckets),
//! * `detail:"index"` — per-shard/per-table occupancy, fingerprint
//!   collision chains, probe-depth hit distribution, candidate-set sizes,
//! * `detail:"slow"` — the worst-K traced requests with full per-stage
//!   breakdowns.
//!
//! `funclsh stats --addr … [--detail …] [--watch N] [--prom]` renders
//! these views from the CLI (including a Prometheus text exposition).
//! A batch frame yields one span per op it carried (the shared decode
//! time is attributed to each); transport ops (`points`, `shutdown`) and
//! parse failures are untraced.
//!
//! # Pipelining contract
//!
//! Clients may write many request frames before reading any response
//! (see [`client::PipelinedClient`]). The server guarantees:
//!
//! * **Ordering** — responses on one connection are written in request
//!   order, even though the coordinator completes batches out of order
//!   internally. `req_id` is still echoed verbatim so clients can (and
//!   should) correlate by id rather than position.
//! * **One response per frame** — every received frame, including
//!   malformed ones, produces exactly one response in the connection's
//!   wire format. Malformed JSON, unknown `op`s/op tags, invalid UTF-8,
//!   empty lines, truncated binary bodies, and trailing garbage get an
//!   error envelope and the connection stays usable. Only two conditions
//!   close the connection (after all earlier responses have flushed):
//!   an oversized request frame (> 8 MiB before its newline, or a binary
//!   length prefix declaring > 8 MiB — the framing cannot resync past
//!   either), and a binary frame truncated by EOF.
//! * **Oversized responses** — a single-op response that cannot fit a
//!   frame (a `query` with a huge `k` against a dense bucket) is
//!   replaced by a *correlated per-request error envelope*; an
//!   oversized **batch** response streams as continuation frames
//!   instead (see "Streaming replies"). The connection and every other
//!   in-flight request stay live either way.
//! * **Backpressure** — a connection with `[server] pipeline_depth`
//!   responses outstanding (or an unflushed write backlog ≥ 8 MiB) is
//!   not read from until it drains; stalls are visible as
//!   `backpressure_stalls` in the metrics. Well-behaved clients keep
//!   their send window ≤ `pipeline_depth`.
//! * **Shutdown** — after a `shutdown` frame (from any connection) the
//!   server stops accepting and stops reading, but every frame already
//!   received — on every connection — is answered and flushed before
//!   its connection closes.
//!
//! A frame written after the server stopped reading (in-flight in the
//! kernel at shutdown, or past the oversized cut-off) is never answered;
//! pipelined clients observe the EOF when draining and report the
//! unanswered ids.
//!
//! The contract above is the **event-loop runtime's**. The threaded
//! fallback frames both formats identically and echoes `req_id` the same
//! way, but answers frames one at a time in request order, and at
//! shutdown only the frame currently being served is answered —
//! pipelined frames still buffered on that connection are dropped with
//! the close. Keep pipelining depth at 1 when targeting
//! `io_mode = "threaded"`.
//!
//! # Shutdown
//!
//! Graceful shutdown (the `shutdown` op, or [`Server::shutdown`]) stops
//! the acceptor, drains in-flight requests as above, and — if
//! `server.snapshot_path` is configured — snapshots the full service
//! state: the `ShardedIndex` in the `FLSH1` format followed by an
//! `EMBS1` entry-store block (re-rank embeddings + insert-time
//! signatures, stamped with a hash-configuration probe). A restart with
//! the same `snapshot_path` restores it on startup
//! (`Coordinator::restore`), so the corpus — including exact re-ranked
//! query answers — survives without re-inserting. `FLSH1`-only readers
//! (`ShardedIndex::load`) still parse the file's index prefix.

pub mod client;
#[cfg(target_os = "linux")]
mod event_loop;
pub mod protocol;
#[cfg(target_os = "linux")]
pub mod reactor;

pub use client::{
    run_load, Client, ClientError, Completion, LatencyHistogram, LoadConfig, LoadReport,
    PipelinedClient, RetryPolicy,
};
pub use protocol::WireMode;
#[cfg(target_os = "linux")]
pub use reactor::raise_nofile_limit;

use crate::config::{IoMode, ServerConfig, ServiceConfig};
use crate::coordinator::{BoundedQueue, Coordinator, ServiceMetrics};
use crate::trace::{Span, SpanWire, Stage};
use protocol::{Request, RequestBody};
use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often blocked I/O paths re-check the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// Admission budgets and coalescing policy (the `[server]` keys),
/// shared by both runtimes.
#[derive(Debug, Clone)]
pub(crate) struct Limits {
    /// in-flight request payload bytes one connection may hold
    pub max_inflight_bytes_per_conn: u64,
    /// in-flight request payload bytes across all connections
    pub max_inflight_bytes: u64,
    /// pending output bytes before a slow reader is disconnected
    pub max_write_queue_bytes: usize,
    /// fold adjacent single-op frames into server-side batches
    pub coalesce: bool,
    /// max frames folded into one synthetic batch
    pub coalesce_window: usize,
}

impl Limits {
    fn from_server(cfg: &ServerConfig) -> Self {
        Self {
            max_inflight_bytes_per_conn: cfg.max_inflight_bytes_per_conn as u64,
            max_inflight_bytes: cfg.max_inflight_bytes as u64,
            max_write_queue_bytes: cfg.max_write_queue_bytes,
            coalesce: cfg.coalesce,
            coalesce_window: cfg.coalesce_window.max(1),
        }
    }
}

/// Charge `cost` bytes against the shared in-flight counter unless that
/// would exceed `cap` (the threaded runtime's global admission check;
/// the event loop keeps its counter on the epoll thread instead).
fn charge_global(inflight: &AtomicU64, cost: u64, cap: u64) -> bool {
    let mut cur = inflight.load(Ordering::Relaxed);
    loop {
        if cur.saturating_add(cost) > cap {
            return false;
        }
        match inflight.compare_exchange_weak(cur, cur + cost, Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// The running TCP front-end.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    runtime: Runtime,
    io_mode: IoMode,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    snapshot_path: String,
}

/// Which I/O runtime is actually serving.
enum Runtime {
    Threaded {
        acceptor: Option<JoinHandle<()>>,
        handlers: Vec<JoinHandle<()>>,
    },
    #[cfg(target_os = "linux")]
    Event(event_loop::EventServer),
}

impl Server {
    /// Bind `cfg.server.host:cfg.server.port` (port 0 = ephemeral) and
    /// start the configured I/O runtime over an already-running
    /// coordinator. `points` are the service's published sample points,
    /// served to clients via the `points` op.
    ///
    /// `io_mode = "event_loop"` needs epoll; on non-Linux targets it
    /// falls back to the threaded runtime with a warning.
    pub fn start(
        cfg: &ServiceConfig,
        svc: Arc<Coordinator>,
        points: Vec<f64>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind((cfg.server.host.as_str(), cfg.server.port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let points = Arc::new(points);

        let io_mode = match cfg.server.io_mode {
            IoMode::EventLoop if cfg!(not(target_os = "linux")) => {
                crate::util::log::warn(
                    "server: io_mode=event_loop needs epoll (Linux); using threaded",
                );
                IoMode::Threaded
            }
            m => m,
        };

        let runtime = match io_mode {
            #[cfg(target_os = "linux")]
            IoMode::EventLoop => Runtime::Event(event_loop::start(
                listener,
                cfg.server.io_workers,
                cfg.server.pipeline_depth,
                cfg.queue_depth,
                Limits::from_server(&cfg.server),
                svc.clone(),
                points.clone(),
                shutdown.clone(),
            )?),
            #[cfg(not(target_os = "linux"))]
            IoMode::EventLoop => unreachable!("event_loop downgraded to threaded above"),
            IoMode::Threaded => {
                start_threaded(listener, cfg, svc.clone(), points.clone(), shutdown.clone())
            }
        };

        Ok(Self {
            addr,
            shutdown,
            runtime,
            io_mode,
            svc,
            points,
            snapshot_path: cfg.server.snapshot_path.clone(),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The I/O runtime actually serving (after platform fallback).
    pub fn io_mode(&self) -> IoMode {
        self.io_mode
    }

    /// The published sample points.
    pub fn points(&self) -> &[f64] {
        &self.points
    }

    /// Whether shutdown has been requested (locally or via the wire).
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain in-flight requests, write the shutdown
    /// snapshot (if configured), and hand the coordinator back to the
    /// caller (who still owns its lifecycle). Returns the snapshot
    /// outcome: `None` if disabled, `Some(Ok(bytes))` / `Some(Err(e))`
    /// otherwise.
    pub fn shutdown(mut self) -> (Arc<Coordinator>, Option<std::io::Result<u64>>) {
        use crate::coordinator::{Op, Response};
        self.shutdown.store(true, Ordering::SeqCst);
        match &mut self.runtime {
            Runtime::Threaded { acceptor, handlers } => {
                if let Some(a) = acceptor.take() {
                    let _ = a.join();
                }
                for h in handlers.drain(..) {
                    let _ = h.join();
                }
            }
            #[cfg(target_os = "linux")]
            Runtime::Event(ev) => ev.stop(),
        }
        let snapshot = if self.snapshot_path.is_empty() {
            None
        } else {
            Some(
                match self.svc.submit(Op::Snapshot {
                    path: self.snapshot_path.clone(),
                }) {
                    Response::Snapshotted { bytes, .. } => Ok(bytes),
                    Response::Error(e) => Err(std::io::Error::other(e)),
                    other => Err(std::io::Error::other(format!(
                        "unexpected snapshot response {other:?}"
                    ))),
                },
            )
        };
        (self.svc, snapshot)
    }
}

/// The PR 1 runtime: acceptor thread + `max_conns` handler threads, each
/// serving one connection at a time with blocking reads.
fn start_threaded(
    listener: TcpListener,
    cfg: &ServiceConfig,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
) -> Runtime {
    // Accepted-but-unserved connections queue here; capacity bounds the
    // accept backlog the same way the coordinator queue bounds requests.
    let conn_queue: Arc<BoundedQueue<TcpStream>> =
        Arc::new(BoundedQueue::new(cfg.server.max_conns.max(1) * 4));
    let limits = Limits::from_server(&cfg.server);
    // global in-flight request bytes across all handler threads
    let inflight: Arc<AtomicU64> = Arc::new(AtomicU64::new(0));

    let mut handlers = Vec::new();
    for _ in 0..cfg.server.max_conns.max(1) {
        let conn_queue = conn_queue.clone();
        let svc = svc.clone();
        let shutdown = shutdown.clone();
        let points = points.clone();
        let limits = limits.clone();
        let inflight = inflight.clone();
        handlers.push(std::thread::spawn(move || {
            while let Some(batch) = conn_queue.pop_batch(1, POLL_INTERVAL) {
                for stream in batch {
                    handle_connection(stream, &svc, &points, &shutdown, &limits, &inflight);
                }
            }
        }));
    }

    let acceptor = {
        let shutdown = shutdown.clone();
        let conn_queue = conn_queue.clone();
        let metrics = svc.shared_metrics();
        std::thread::spawn(move || {
            while !shutdown.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // the listener is non-blocking; handlers use
                        // blocking reads with a timeout. A full backlog
                        // sheds the connection (drop = RST) instead of
                        // blocking the acceptor, so shutdown can never
                        // deadlock on a saturated handler pool.
                        let _ = stream.set_nonblocking(false);
                        if conn_queue.try_push(stream).is_err() {
                            metrics.record_rejected_accept();
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(POLL_INTERVAL),
                }
            }
            conn_queue.close();
        })
    };

    Runtime::Threaded {
        acceptor: Some(acceptor),
        handlers,
    }
}

/// Serve one connection until EOF, I/O error, or server shutdown.
fn handle_connection(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
    limits: &Limits,
    inflight: &AtomicU64,
) {
    let metrics = svc.shared_metrics();
    metrics.record_conn_opened();
    let _ = serve_stream(stream, svc, points, shutdown, limits, inflight);
    metrics.record_conn_closed();
}

/// Blocking frame loop for the threaded runtime: raw reads pushed into
/// the shared incremental [`protocol::Framer`] (the same machine the
/// event loop consumes — one copy of the framing rules), then one reply
/// per complete frame, answered in order without pipelined reordering.
fn serve_stream(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
    limits: &Limits,
    inflight: &AtomicU64,
) -> std::io::Result<()> {
    use protocol::{Framer, FramerStep, WireMode};

    stream.set_nodelay(true)?;
    // Reads time out so an idle connection re-checks the shutdown flag;
    // partial frames persist in the framer across timeouts.
    stream.set_read_timeout(Some(POLL_INTERVAL))?;
    let metrics = svc.shared_metrics();
    let mut reader = stream.try_clone()?;
    let mut writer = BufWriter::new(stream);
    let mut framer = Framer::new();
    let mut counted_mode = false;
    let mut chunk = [0u8; 64 * 1024];
    let mut eof = false;
    loop {
        // 1. answer every complete frame currently buffered
        loop {
            match framer.next() {
                FramerStep::Pending => break,
                // both arms carry the negotiated mode, so count the
                // connection here too — the Fatal and shutdown paths
                // return before the post-loop check would run, and the
                // per-wire counters must agree with the event loop's
                FramerStep::Fatal { wire, msg } => {
                    if !counted_mode {
                        metrics.record_wire_conn(wire == WireMode::Binary);
                        if wire == WireMode::Binary {
                            metrics
                                .record_wire_in(true, 0, protocol::MAGIC_LEN as u64);
                        }
                        counted_mode = true;
                    }
                    // over-cap line / declared length / eof-truncated
                    // binary frame: answer once, then close — the
                    // framing cannot resync past it. The final error
                    // frame still counts toward bytes_out (parity with
                    // the event loop, which counts every flushed frame)
                    let reply = protocol::encode_error_frame(wire, None, &msg);
                    metrics.record_wire_out(wire == WireMode::Binary, reply.len() as u64);
                    write_frame(&mut writer, &reply)?;
                    return Ok(());
                }
                FramerStep::Frame { wire, payload } => {
                    if !counted_mode {
                        metrics.record_wire_conn(wire == WireMode::Binary);
                        if wire == WireMode::Binary {
                            metrics
                                .record_wire_in(true, 0, protocol::MAGIC_LEN as u64);
                        }
                        counted_mode = true;
                    }
                    // whole wire bytes: payload + newline / length prefix
                    let wire_bytes = payload.len() + protocol::frame_overhead_bytes(wire);
                    metrics.record_wire_in(wire == WireMode::Binary, 1, wire_bytes as u64);
                    // admission control: this thread serves one frame at
                    // a time, so the in-flight charge per connection is
                    // exactly this frame — check it against the per-conn
                    // budget directly, then the shared global budget
                    let cost = payload.len() as u64;
                    let shed_scope = if cost > limits.max_inflight_bytes_per_conn {
                        Some("connection in-flight byte budget")
                    } else if !charge_global(inflight, cost, limits.max_inflight_bytes) {
                        Some("server in-flight byte budget")
                    } else {
                        None
                    };
                    let (reply, mut spans) = match shed_scope {
                        Some(scope) => {
                            metrics.record_overload_shed();
                            // parse only for the req_id echo, so the
                            // shed envelope stays correlated
                            let req_id = match protocol::parse_frame_payload(wire, payload) {
                                Ok(req) => req.req_id,
                                Err(e) => e.req_id,
                            };
                            (
                                protocol::encode_overloaded_frame(wire, req_id, scope),
                                Vec::new(),
                            )
                        }
                        None => {
                            let out = answer_frame(wire, payload, svc, points, shutdown, &metrics);
                            inflight.fetch_sub(cost, Ordering::Relaxed);
                            out
                        }
                    };
                    metrics.record_wire_out(wire == WireMode::Binary, reply.len() as u64);
                    write_frame(&mut writer, &reply)?;
                    // the threaded runtime flushes inline, so the
                    // write-queued stage covers the actual socket write
                    for span in spans.iter_mut() {
                        span.stamp(Stage::WriteQueued);
                        metrics.record_span(span);
                    }
                    if shutdown.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
            }
        }
        if !counted_mode {
            if let Some(m) = framer.negotiated() {
                metrics.record_wire_conn(m == WireMode::Binary);
                if m == WireMode::Binary {
                    metrics.record_wire_in(true, 0, protocol::MAGIC_LEN as u64);
                }
                counted_mode = true;
            }
        }
        framer.compact();
        if eof {
            return Ok(());
        }
        // 2. read more bytes (or notice EOF / shutdown)
        match reader.read(&mut chunk) {
            Ok(0) => {
                eof = true;
                framer.push_eof();
            }
            Ok(n) => framer.push(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Ok(()),
        }
    }
}

fn write_frame(writer: &mut BufWriter<TcpStream>, frame: &[u8]) -> std::io::Result<()> {
    writer.write_all(frame)?;
    writer.flush()
}

/// The trace wire label for a connection's negotiated frame format.
pub(crate) fn span_wire(mode: protocol::WireMode) -> SpanWire {
    match mode {
        protocol::WireMode::Json => SpanWire::Json,
        protocol::WireMode::Binary => SpanWire::Binary,
    }
}

/// Decode one request frame payload and produce the complete response
/// frame in the same wire mode, plus the stamped trace spans of every
/// coordinator op the frame carried (empty for transport ops, parse
/// failures, and untraced requests). The caller owns the final
/// write-queued stamp and hands each span to
/// [`ServiceMetrics::record_span`].
fn answer_frame(
    mode: protocol::WireMode,
    payload: &[u8],
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
    metrics: &ServiceMetrics,
) -> (Vec<u8>, Vec<Span>) {
    let mut span = Span::new(span_wire(mode), metrics.tracing_enabled());
    let parsed = protocol::parse_frame_payload(mode, payload);
    span.stamp(Stage::Decode);
    match parsed {
        Err(e) => (
            protocol::encode_error_frame(mode, e.req_id, &format!("bad request: {e}")),
            Vec::new(),
        ),
        Ok(Request { req_id, body }) => match body {
            RequestBody::Points => (
                protocol::encode_points_frame(mode, req_id, points),
                Vec::new(),
            ),
            RequestBody::Shutdown => {
                shutdown.store(true, Ordering::SeqCst);
                (protocol::encode_shutting_down_frame(mode, req_id), Vec::new())
            }
            RequestBody::Op(op) => {
                let (resp, mut rspan) = svc.submit_traced(op, span);
                let frame = protocol::encode_response_frame(mode, req_id, &resp);
                rspan.stamp(Stage::Encode);
                let spans = if rspan.is_enabled() { vec![rspan] } else { Vec::new() };
                (frame, spans)
            }
            RequestBody::Batch(items) => {
                let (results, mut spans) = submit_batch(svc, items, span);
                let frame = protocol::encode_batch_response_frame(mode, req_id, &results);
                for s in spans.iter_mut() {
                    s.stamp(Stage::Encode);
                }
                (frame, spans)
            }
        },
    }
}

/// Per-item outcomes of a submitted batch: a receiver for items the
/// coordinator accepted, or the ready error envelope for items that
/// failed wire decode / admission.
pub(crate) type PendingBatch = Vec<
    Result<
        std::sync::mpsc::Receiver<(crate::coordinator::Response, Span)>,
        crate::coordinator::Response,
    >,
>;

/// Fan one batch frame's items into the coordinator *without awaiting*
/// any of them, so the rows co-occupy one dynamic batch. Shared by both
/// runtimes — the per-item error-envelope wording must stay identical
/// between them (the runtime-parity property tests compare reply bytes).
/// Every accepted item rides its own copy of the frame's span (`Span` is
/// `Copy`), so one batch frame yields one trace per op — the shared
/// decode time is attributed to each.
pub(crate) fn submit_batch_async(
    svc: &Coordinator,
    items: Vec<Result<crate::coordinator::Op, String>>,
    span: Span,
) -> PendingBatch {
    use crate::coordinator::Response;
    items
        .into_iter()
        .map(|item| match item {
            Ok(op) => svc.submit_async(op, span).map_err(Response::Error),
            Err(msg) => Err(Response::Error(format!("bad request: {msg}"))),
        })
        .collect()
}

/// Await a [`submit_batch_async`] submission in row order. Returns the
/// responses plus the stamped spans of the traced items (per-item
/// failures and untraced requests contribute no span, so the histogram
/// counts stay reconcilable against completed traced ops).
pub(crate) fn collect_batch(
    pending: PendingBatch,
) -> (Vec<crate::coordinator::Response>, Vec<Span>) {
    use crate::coordinator::Response;
    let mut responses = Vec::with_capacity(pending.len());
    let mut spans = Vec::new();
    for p in pending {
        match p {
            Ok(rx) => match rx.recv() {
                Ok((resp, span)) => {
                    responses.push(resp);
                    if span.is_enabled() {
                        spans.push(span);
                    }
                }
                Err(_) => responses.push(Response::Error("worker dropped request".into())),
            },
            Err(resp) => responses.push(resp),
        }
    }
    (responses, spans)
}

/// Submit + await one batch frame (the threaded runtime's blocking
/// path; the event loop splits the two halves around its job batch).
pub(crate) fn submit_batch(
    svc: &Coordinator,
    items: Vec<Result<crate::coordinator::Op, String>>,
    span: Span,
) -> (Vec<crate::coordinator::Response>, Vec<Span>) {
    collect_batch(submit_batch_async(svc, items, span))
}
