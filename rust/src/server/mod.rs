//! The network serving layer: a TCP front-end over the
//! [`crate::coordinator`] batching worker pool, with two interchangeable
//! I/O runtimes selected by `[server] io_mode`.
//!
//! # Architecture
//!
//! **`io_mode = "event_loop"`** (default, Linux) — readiness-based:
//!
//! ```text
//! clients ── TCP ──▶ epoll thread (accept + non-blocking reads +
//!                    incremental newline framing + write flushing)
//!                         │ Job queue (bounded)
//!                    io_workers threads ──▶ Coordinator::submit_async
//!                         │                 (dynamic batcher: concurrent
//!                         │                  connections share batched
//!                         │                  hash executions)
//!                    completions ──▶ per-conn reorder buffer ──▶ socket
//! ```
//!
//! One thread multiplexes thousands of idle connections; the
//! fixed worker pool turns wire concurrency into batch occupancy.
//!
//! **`io_mode = "threaded"`** (fallback, all platforms) — the PR 1
//! acceptor + connection-handler pool: `max_conns` threads, each owning
//! one connection at a time with blocking reads.
//!
//! # Wire protocol
//!
//! Newline-delimited JSON, one frame per line, UTF-8, max 8 MiB per
//! line ([`protocol::MAX_LINE_BYTES`]). Every request may carry an
//! optional `req_id` (u64) that is echoed in the response.
//!
//! Requests:
//!
//! ```text
//! {"op":"hash",     "samples":[f32…]}
//! {"op":"insert",   "id":u64, "samples":[f32…]}
//! {"op":"query",    "samples":[f32…], "k":usize}
//! {"op":"remove",   "id":u64}
//! {"op":"metrics"}
//! {"op":"snapshot", "path":"…"}          (full-state dump — FLSH1 index
//!                                         block + EMBS1 entry store —
//!                                         to a server-side path)
//! {"op":"ping"}
//! {"op":"points"}                        (published sample points)
//! {"op":"shutdown"}                      (graceful stop + shutdown snapshot)
//! ```
//!
//! Responses are an envelope with `"ok"`:
//!
//! ```text
//! {"ok":true, "req_id":…, "type":"signature", "signature":[i32…]}
//! {"ok":true, "req_id":…, "type":"inserted",  "id":u64}
//! {"ok":true, "req_id":…, "type":"hits",      "hits":[{"id":u64,"distance":f64}…]}
//! {"ok":true, "req_id":…, "type":"removed",   "id":u64}
//! {"ok":true, "req_id":…, "type":"metrics",   "metrics":{…}}
//! {"ok":true, "req_id":…, "type":"snapshot",  "path":"…", "bytes":u64}
//! {"ok":true, "req_id":…, "type":"pong",      "indexed":u64}
//! {"ok":true, "req_id":…, "type":"points",    "points":[f64…]}
//! {"ok":true, "req_id":…, "type":"shutting_down"}
//! {"ok":false,"req_id":…, "error":"…"}        (error envelope, both
//!                                              bad requests and op failures)
//! ```
//!
//! # Pipelining contract
//!
//! Clients may write many request frames before reading any response
//! (see [`client::PipelinedClient`]). The server guarantees:
//!
//! * **Ordering** — responses on one connection are written in request
//!   order, even though the coordinator completes batches out of order
//!   internally. `req_id` is still echoed verbatim so clients can (and
//!   should) correlate by id rather than position.
//! * **One response per frame** — every received frame, including
//!   malformed ones, produces exactly one response line. Malformed JSON,
//!   unknown `op`s, invalid UTF-8, and empty lines get an
//!   `{"ok":false,…}` envelope and the connection stays usable; only an
//!   oversized frame (> 8 MiB before its newline) is answered with
//!   `request line too long` and then the connection closes after all
//!   earlier responses have flushed.
//! * **Backpressure** — a connection with `[server] pipeline_depth`
//!   responses outstanding (or an unflushed write backlog ≥ 8 MiB) is
//!   not read from until it drains; stalls are visible as
//!   `backpressure_stalls` in the metrics. Well-behaved clients keep
//!   their send window ≤ `pipeline_depth`.
//! * **Shutdown** — after a `shutdown` frame (from any connection) the
//!   server stops accepting and stops reading, but every frame already
//!   received — on every connection — is answered and flushed before
//!   its connection closes.
//!
//! A frame written after the server stopped reading (in-flight in the
//! kernel at shutdown, or past the oversized cut-off) is never answered;
//! pipelined clients observe the EOF when draining and report the
//! unanswered ids.
//!
//! The contract above is the **event-loop runtime's**. The threaded
//! fallback answers frames one at a time in request order and echoes
//! `req_id` identically, but deviates in two documented ways: a frame
//! containing invalid UTF-8 closes the connection without a response
//! (its line-reader cannot recover the framing), and at shutdown only
//! the frame currently being served is answered — pipelined frames
//! still buffered on that connection are dropped with the close. Keep
//! pipelining depth at 1 when targeting `io_mode = "threaded"`.
//!
//! # Shutdown
//!
//! Graceful shutdown (the `shutdown` op, or [`Server::shutdown`]) stops
//! the acceptor, drains in-flight requests as above, and — if
//! `server.snapshot_path` is configured — snapshots the full service
//! state: the `ShardedIndex` in the `FLSH1` format followed by an
//! `EMBS1` entry-store block (re-rank embeddings + insert-time
//! signatures, stamped with a hash-configuration probe). A restart with
//! the same `snapshot_path` restores it on startup
//! (`Coordinator::restore`), so the corpus — including exact re-ranked
//! query answers — survives without re-inserting. `FLSH1`-only readers
//! (`ShardedIndex::load`) still parse the file's index prefix.

pub mod client;
#[cfg(target_os = "linux")]
mod event_loop;
pub mod protocol;
#[cfg(target_os = "linux")]
pub mod reactor;

pub use client::{
    run_load, Client, ClientError, Completion, LatencyHistogram, LoadConfig, LoadReport,
    PipelinedClient,
};
#[cfg(target_os = "linux")]
pub use reactor::raise_nofile_limit;

use crate::config::{IoMode, ServiceConfig};
use crate::coordinator::{BoundedQueue, Coordinator, Op, Response};
use protocol::{Request, RequestBody};
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often blocked I/O paths re-check the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// The running TCP front-end.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    runtime: Runtime,
    io_mode: IoMode,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    snapshot_path: String,
}

/// Which I/O runtime is actually serving.
enum Runtime {
    Threaded {
        acceptor: Option<JoinHandle<()>>,
        handlers: Vec<JoinHandle<()>>,
    },
    #[cfg(target_os = "linux")]
    Event(event_loop::EventServer),
}

impl Server {
    /// Bind `cfg.server.host:cfg.server.port` (port 0 = ephemeral) and
    /// start the configured I/O runtime over an already-running
    /// coordinator. `points` are the service's published sample points,
    /// served to clients via the `points` op.
    ///
    /// `io_mode = "event_loop"` needs epoll; on non-Linux targets it
    /// falls back to the threaded runtime with a warning.
    pub fn start(
        cfg: &ServiceConfig,
        svc: Arc<Coordinator>,
        points: Vec<f64>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind((cfg.server.host.as_str(), cfg.server.port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let points = Arc::new(points);

        let io_mode = match cfg.server.io_mode {
            IoMode::EventLoop if cfg!(not(target_os = "linux")) => {
                eprintln!("server: io_mode=event_loop needs epoll (Linux); using threaded");
                IoMode::Threaded
            }
            m => m,
        };

        let runtime = match io_mode {
            #[cfg(target_os = "linux")]
            IoMode::EventLoop => Runtime::Event(event_loop::start(
                listener,
                cfg.server.io_workers,
                cfg.server.pipeline_depth,
                cfg.queue_depth,
                svc.clone(),
                points.clone(),
                shutdown.clone(),
            )?),
            #[cfg(not(target_os = "linux"))]
            IoMode::EventLoop => unreachable!("event_loop downgraded to threaded above"),
            IoMode::Threaded => {
                start_threaded(listener, cfg, svc.clone(), points.clone(), shutdown.clone())
            }
        };

        Ok(Self {
            addr,
            shutdown,
            runtime,
            io_mode,
            svc,
            points,
            snapshot_path: cfg.server.snapshot_path.clone(),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The I/O runtime actually serving (after platform fallback).
    pub fn io_mode(&self) -> IoMode {
        self.io_mode
    }

    /// The published sample points.
    pub fn points(&self) -> &[f64] {
        &self.points
    }

    /// Whether shutdown has been requested (locally or via the wire).
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain in-flight requests, write the shutdown
    /// snapshot (if configured), and hand the coordinator back to the
    /// caller (who still owns its lifecycle). Returns the snapshot
    /// outcome: `None` if disabled, `Some(Ok(bytes))` / `Some(Err(e))`
    /// otherwise.
    pub fn shutdown(mut self) -> (Arc<Coordinator>, Option<std::io::Result<u64>>) {
        self.shutdown.store(true, Ordering::SeqCst);
        match &mut self.runtime {
            Runtime::Threaded { acceptor, handlers } => {
                if let Some(a) = acceptor.take() {
                    let _ = a.join();
                }
                for h in handlers.drain(..) {
                    let _ = h.join();
                }
            }
            #[cfg(target_os = "linux")]
            Runtime::Event(ev) => ev.stop(),
        }
        let snapshot = if self.snapshot_path.is_empty() {
            None
        } else {
            Some(
                match self.svc.submit(Op::Snapshot {
                    path: self.snapshot_path.clone(),
                }) {
                    Response::Snapshotted { bytes, .. } => Ok(bytes),
                    Response::Error(e) => Err(std::io::Error::other(e)),
                    other => Err(std::io::Error::other(format!(
                        "unexpected snapshot response {other:?}"
                    ))),
                },
            )
        };
        (self.svc, snapshot)
    }
}

/// The PR 1 runtime: acceptor thread + `max_conns` handler threads, each
/// serving one connection at a time with blocking reads.
fn start_threaded(
    listener: TcpListener,
    cfg: &ServiceConfig,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
) -> Runtime {
    // Accepted-but-unserved connections queue here; capacity bounds the
    // accept backlog the same way the coordinator queue bounds requests.
    let conn_queue: Arc<BoundedQueue<TcpStream>> =
        Arc::new(BoundedQueue::new(cfg.server.max_conns.max(1) * 4));

    let mut handlers = Vec::new();
    for _ in 0..cfg.server.max_conns.max(1) {
        let conn_queue = conn_queue.clone();
        let svc = svc.clone();
        let shutdown = shutdown.clone();
        let points = points.clone();
        handlers.push(std::thread::spawn(move || {
            while let Some(batch) = conn_queue.pop_batch(1, POLL_INTERVAL) {
                for stream in batch {
                    handle_connection(stream, &svc, &points, &shutdown);
                }
            }
        }));
    }

    let acceptor = {
        let shutdown = shutdown.clone();
        let conn_queue = conn_queue.clone();
        std::thread::spawn(move || {
            while !shutdown.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // the listener is non-blocking; handlers use
                        // blocking reads with a timeout. A full backlog
                        // sheds the connection (drop = RST) instead of
                        // blocking the acceptor, so shutdown can never
                        // deadlock on a saturated handler pool.
                        let _ = stream.set_nonblocking(false);
                        if conn_queue.try_push(stream).is_err() {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(POLL_INTERVAL),
                }
            }
            conn_queue.close();
        })
    };

    Runtime::Threaded {
        acceptor: Some(acceptor),
        handlers,
    }
}

/// Serve one connection until EOF, I/O error, or server shutdown.
fn handle_connection(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) {
    let metrics = svc.shared_metrics();
    metrics.record_conn_opened();
    let _ = serve_stream(stream, svc, points, shutdown);
    metrics.record_conn_closed();
}

fn serve_stream(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    // Reads time out so an idle connection re-checks the shutdown flag;
    // a timed-out read_line keeps its partial line and resumes.
    stream.set_read_timeout(Some(POLL_INTERVAL))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        // per-call byte limit: a frame that exceeds MAX_LINE_BYTES hits
        // the limit before the newline and is rejected below, so a
        // hostile sender cannot grow the buffer without bound
        let mut limited = (&mut reader).take((protocol::MAX_LINE_BYTES + 1) as u64);
        match limited.read_line(&mut line) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {
                if line.len() > protocol::MAX_LINE_BYTES {
                    let reply = protocol::encode_error(None, "request line too long");
                    writer.write_all(reply.as_bytes())?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                    return Ok(());
                }
                let reply = answer(&line, svc, points, shutdown);
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                line.clear();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                // timed-out reads keep their partial line and resume, but
                // a frame that drips past the cap without a newline is
                // rejected here too
                if shutdown.load(Ordering::SeqCst) || line.len() > protocol::MAX_LINE_BYTES {
                    return Ok(());
                }
            }
            Err(_) => return Ok(()),
        }
    }
}

/// Decode one request line and produce the response line.
fn answer(
    line: &str,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) -> String {
    if line.trim().is_empty() {
        return protocol::encode_error(None, "empty request");
    }
    match protocol::parse_request(line) {
        Err(e) => protocol::encode_error(e.req_id, &format!("bad request: {e}")),
        Ok(Request { req_id, body }) => match body {
            RequestBody::Points => protocol::encode_points(req_id, points),
            RequestBody::Shutdown => {
                shutdown.store(true, Ordering::SeqCst);
                protocol::encode_shutting_down(req_id)
            }
            RequestBody::Op(op) => {
                let resp = svc.submit(op);
                protocol::encode_response(req_id, &resp)
            }
        },
    }
}
