//! The network serving layer: a TCP front-end over the
//! [`crate::coordinator`] batching worker pool, with two interchangeable
//! I/O runtimes selected by `[server] io_mode` and two wire formats
//! negotiated per connection.
//!
//! # Architecture
//!
//! **`io_mode = "event_loop"`** (default, Linux) — readiness-based:
//!
//! ```text
//! clients ── TCP ──▶ epoll thread (accept + non-blocking reads +
//!                    incremental framing + write flushing)
//!                         │ Job queue (bounded)
//!                    io_workers threads ──▶ Coordinator::submit_async
//!                         │                 (dynamic batcher: concurrent
//!                         │                  connections share batched
//!                         │                  hash executions)
//!                    completions ──▶ per-conn reorder buffer ──▶ socket
//! ```
//!
//! One thread multiplexes thousands of idle connections; the
//! fixed worker pool turns wire concurrency into batch occupancy.
//!
//! **`io_mode = "threaded"`** (fallback, all platforms) — the PR 1
//! acceptor + connection-handler pool: `max_conns` threads, each owning
//! one connection at a time with blocking reads.
//!
//! # Wire formats and mode negotiation
//!
//! Both runtimes speak **two frame formats on the same port**; a
//! connection's first bytes select its format for the connection's whole
//! lifetime:
//!
//! * a connection whose first five bytes are `FBIN1`
//!   ([`protocol::BINARY_MAGIC`]) speaks the **length-prefixed binary**
//!   format from the byte after the magic on;
//! * any other first byte (valid JSON starts with `{` or whitespace)
//!   selects **newline-delimited JSON** — the default, and what `nc`
//!   speaks. Garbage that merely resembles the magic (e.g. `FBINX…`)
//!   falls through to the JSON parser's error envelope.
//!
//! Either way the cap is 8 MiB per frame payload
//! ([`protocol::MAX_FRAME_BYTES`]), and every request may carry an
//! optional `req_id` (u64) that is echoed in its response.
//!
//! ## JSON frames
//!
//! One UTF-8 JSON object per `\n`-terminated line. **Integer width:**
//! ids and `req_id`s ride JSON numbers (f64), so values ≥ 2^53 are
//! rejected rather than silently rounded — use the binary format for
//! full-width ids.
//!
//! Requests:
//!
//! ```text
//! {"op":"hash",     "samples":[f32…]}
//! {"op":"insert",   "id":u64, "samples":[f32…]}
//! {"op":"query",    "samples":[f32…], "k":usize}
//! {"op":"remove",   "id":u64}
//! {"op":"metrics"}
//! {"op":"snapshot", "path":"…"}          (full-state dump — FLSH1 index
//!                                         block + EMBS1 entry store —
//!                                         to a server-side path)
//! {"op":"ping"}
//! {"op":"points"}                        (published sample points)
//! {"op":"shutdown"}                      (graceful stop + shutdown snapshot)
//! ```
//!
//! Responses are an envelope with `"ok"`:
//!
//! ```text
//! {"ok":true, "req_id":…, "type":"signature", "signature":[i32…]}
//! {"ok":true, "req_id":…, "type":"inserted",  "id":u64}
//! {"ok":true, "req_id":…, "type":"hits",      "hits":[{"id":u64,"distance":f64}…]}
//! {"ok":true, "req_id":…, "type":"removed",   "id":u64}
//! {"ok":true, "req_id":…, "type":"metrics",   "metrics":{…}}
//! {"ok":true, "req_id":…, "type":"snapshot",  "path":"…", "bytes":u64}
//! {"ok":true, "req_id":…, "type":"pong",      "indexed":u64}
//! {"ok":true, "req_id":…, "type":"points",    "points":[f64…]}
//! {"ok":true, "req_id":…, "type":"shutting_down"}
//! {"ok":false,"req_id":…, "error":"…"}        (error envelope, both
//!                                              bad requests and op failures)
//! ```
//!
//! ## Binary frames (`FBIN1`)
//!
//! After the 5-byte magic, every frame in **both** directions is a
//! little-endian `u32` payload length followed by the payload. All
//! multi-byte integers and floats are little-endian; sample rows are raw
//! `f32` bits (4 bytes/sample vs ~9–13 bytes of JSON text — the reason
//! this format exists), and ids are native `u64`s with **no 2^53
//! limit**.
//!
//! Request payload: `op:u8`, `flags:u8` (bit 0 = a `req_id:u64`
//! follows), then the op body:
//!
//! ```text
//! op 1 hash      n:u32, samples:[f32; n]
//! op 2 insert    id:u64, n:u32, samples:[f32; n]
//! op 3 query     n:u32, samples:[f32; n], k:u64
//! op 4 remove    id:u64
//! op 5 metrics   —
//! op 6 snapshot  len:u32, path:[utf8; len]
//! op 7 ping      —
//! op 8 points    —
//! op 9 shutdown  —
//! ```
//!
//! Response payload: `status:u8` (0 = ok, 1 = error), `flags:u8` (bit 0
//! = `req_id:u64` follows). Errors carry `len:u32, msg:[utf8; len]`;
//! successes carry `type:u8` + body mirroring the JSON responses
//! (`signature` = `n:u32` + raw `i32`s, `hits` = `n:u32` + `(id:u64,
//! distance:f64)` pairs, `metrics` = a length-prefixed JSON string,
//! `points` = `n:u32` + `f64`s, acks = their `u64`).
//!
//! ## Sample validation
//!
//! Both decoders reject non-finite samples — raw `NaN`/`±inf` bits on
//! the binary path, and JSON numbers that are non-finite *or overflow
//! `f32` to `±inf`* (e.g. `1e39`) — with a per-request error envelope;
//! the coordinator's `Insert` path additionally refuses non-finite rows
//! defensively. A poisoned sample would otherwise corrupt the index and
//! every re-rank distance it touches.
//!
//! # Pipelining contract
//!
//! Clients may write many request frames before reading any response
//! (see [`client::PipelinedClient`]). The server guarantees:
//!
//! * **Ordering** — responses on one connection are written in request
//!   order, even though the coordinator completes batches out of order
//!   internally. `req_id` is still echoed verbatim so clients can (and
//!   should) correlate by id rather than position.
//! * **One response per frame** — every received frame, including
//!   malformed ones, produces exactly one response in the connection's
//!   wire format. Malformed JSON, unknown `op`s/op tags, invalid UTF-8,
//!   empty lines, truncated binary bodies, and trailing garbage get an
//!   error envelope and the connection stays usable. Only two conditions
//!   close the connection (after all earlier responses have flushed):
//!   an oversized request frame (> 8 MiB before its newline, or a binary
//!   length prefix declaring > 8 MiB — the framing cannot resync past
//!   either), and a binary frame truncated by EOF.
//! * **Oversized responses** — a response that cannot fit a frame
//!   (a `query` with a huge `k` against a dense bucket) is replaced by a
//!   *correlated per-request error envelope*; the connection and every
//!   other in-flight request stay live.
//! * **Backpressure** — a connection with `[server] pipeline_depth`
//!   responses outstanding (or an unflushed write backlog ≥ 8 MiB) is
//!   not read from until it drains; stalls are visible as
//!   `backpressure_stalls` in the metrics. Well-behaved clients keep
//!   their send window ≤ `pipeline_depth`.
//! * **Shutdown** — after a `shutdown` frame (from any connection) the
//!   server stops accepting and stops reading, but every frame already
//!   received — on every connection — is answered and flushed before
//!   its connection closes.
//!
//! A frame written after the server stopped reading (in-flight in the
//! kernel at shutdown, or past the oversized cut-off) is never answered;
//! pipelined clients observe the EOF when draining and report the
//! unanswered ids.
//!
//! The contract above is the **event-loop runtime's**. The threaded
//! fallback frames both formats identically and echoes `req_id` the same
//! way, but answers frames one at a time in request order, and at
//! shutdown only the frame currently being served is answered —
//! pipelined frames still buffered on that connection are dropped with
//! the close. Keep pipelining depth at 1 when targeting
//! `io_mode = "threaded"`.
//!
//! # Shutdown
//!
//! Graceful shutdown (the `shutdown` op, or [`Server::shutdown`]) stops
//! the acceptor, drains in-flight requests as above, and — if
//! `server.snapshot_path` is configured — snapshots the full service
//! state: the `ShardedIndex` in the `FLSH1` format followed by an
//! `EMBS1` entry-store block (re-rank embeddings + insert-time
//! signatures, stamped with a hash-configuration probe). A restart with
//! the same `snapshot_path` restores it on startup
//! (`Coordinator::restore`), so the corpus — including exact re-ranked
//! query answers — survives without re-inserting. `FLSH1`-only readers
//! (`ShardedIndex::load`) still parse the file's index prefix.

pub mod client;
#[cfg(target_os = "linux")]
mod event_loop;
pub mod protocol;
#[cfg(target_os = "linux")]
pub mod reactor;

pub use client::{
    run_load, Client, ClientError, Completion, LatencyHistogram, LoadConfig, LoadReport,
    PipelinedClient,
};
pub use protocol::WireMode;
#[cfg(target_os = "linux")]
pub use reactor::raise_nofile_limit;

use crate::config::{IoMode, ServiceConfig};
use crate::coordinator::{BoundedQueue, Coordinator};
use protocol::{Negotiation, Request, RequestBody};
use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often blocked I/O paths re-check the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// The running TCP front-end.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    runtime: Runtime,
    io_mode: IoMode,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    snapshot_path: String,
}

/// Which I/O runtime is actually serving.
enum Runtime {
    Threaded {
        acceptor: Option<JoinHandle<()>>,
        handlers: Vec<JoinHandle<()>>,
    },
    #[cfg(target_os = "linux")]
    Event(event_loop::EventServer),
}

impl Server {
    /// Bind `cfg.server.host:cfg.server.port` (port 0 = ephemeral) and
    /// start the configured I/O runtime over an already-running
    /// coordinator. `points` are the service's published sample points,
    /// served to clients via the `points` op.
    ///
    /// `io_mode = "event_loop"` needs epoll; on non-Linux targets it
    /// falls back to the threaded runtime with a warning.
    pub fn start(
        cfg: &ServiceConfig,
        svc: Arc<Coordinator>,
        points: Vec<f64>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind((cfg.server.host.as_str(), cfg.server.port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let points = Arc::new(points);

        let io_mode = match cfg.server.io_mode {
            IoMode::EventLoop if cfg!(not(target_os = "linux")) => {
                eprintln!("server: io_mode=event_loop needs epoll (Linux); using threaded");
                IoMode::Threaded
            }
            m => m,
        };

        let runtime = match io_mode {
            #[cfg(target_os = "linux")]
            IoMode::EventLoop => Runtime::Event(event_loop::start(
                listener,
                cfg.server.io_workers,
                cfg.server.pipeline_depth,
                cfg.queue_depth,
                svc.clone(),
                points.clone(),
                shutdown.clone(),
            )?),
            #[cfg(not(target_os = "linux"))]
            IoMode::EventLoop => unreachable!("event_loop downgraded to threaded above"),
            IoMode::Threaded => {
                start_threaded(listener, cfg, svc.clone(), points.clone(), shutdown.clone())
            }
        };

        Ok(Self {
            addr,
            shutdown,
            runtime,
            io_mode,
            svc,
            points,
            snapshot_path: cfg.server.snapshot_path.clone(),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The I/O runtime actually serving (after platform fallback).
    pub fn io_mode(&self) -> IoMode {
        self.io_mode
    }

    /// The published sample points.
    pub fn points(&self) -> &[f64] {
        &self.points
    }

    /// Whether shutdown has been requested (locally or via the wire).
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain in-flight requests, write the shutdown
    /// snapshot (if configured), and hand the coordinator back to the
    /// caller (who still owns its lifecycle). Returns the snapshot
    /// outcome: `None` if disabled, `Some(Ok(bytes))` / `Some(Err(e))`
    /// otherwise.
    pub fn shutdown(mut self) -> (Arc<Coordinator>, Option<std::io::Result<u64>>) {
        use crate::coordinator::{Op, Response};
        self.shutdown.store(true, Ordering::SeqCst);
        match &mut self.runtime {
            Runtime::Threaded { acceptor, handlers } => {
                if let Some(a) = acceptor.take() {
                    let _ = a.join();
                }
                for h in handlers.drain(..) {
                    let _ = h.join();
                }
            }
            #[cfg(target_os = "linux")]
            Runtime::Event(ev) => ev.stop(),
        }
        let snapshot = if self.snapshot_path.is_empty() {
            None
        } else {
            Some(
                match self.svc.submit(Op::Snapshot {
                    path: self.snapshot_path.clone(),
                }) {
                    Response::Snapshotted { bytes, .. } => Ok(bytes),
                    Response::Error(e) => Err(std::io::Error::other(e)),
                    other => Err(std::io::Error::other(format!(
                        "unexpected snapshot response {other:?}"
                    ))),
                },
            )
        };
        (self.svc, snapshot)
    }
}

/// The PR 1 runtime: acceptor thread + `max_conns` handler threads, each
/// serving one connection at a time with blocking reads.
fn start_threaded(
    listener: TcpListener,
    cfg: &ServiceConfig,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
) -> Runtime {
    // Accepted-but-unserved connections queue here; capacity bounds the
    // accept backlog the same way the coordinator queue bounds requests.
    let conn_queue: Arc<BoundedQueue<TcpStream>> =
        Arc::new(BoundedQueue::new(cfg.server.max_conns.max(1) * 4));

    let mut handlers = Vec::new();
    for _ in 0..cfg.server.max_conns.max(1) {
        let conn_queue = conn_queue.clone();
        let svc = svc.clone();
        let shutdown = shutdown.clone();
        let points = points.clone();
        handlers.push(std::thread::spawn(move || {
            while let Some(batch) = conn_queue.pop_batch(1, POLL_INTERVAL) {
                for stream in batch {
                    handle_connection(stream, &svc, &points, &shutdown);
                }
            }
        }));
    }

    let acceptor = {
        let shutdown = shutdown.clone();
        let conn_queue = conn_queue.clone();
        std::thread::spawn(move || {
            while !shutdown.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // the listener is non-blocking; handlers use
                        // blocking reads with a timeout. A full backlog
                        // sheds the connection (drop = RST) instead of
                        // blocking the acceptor, so shutdown can never
                        // deadlock on a saturated handler pool.
                        let _ = stream.set_nonblocking(false);
                        if conn_queue.try_push(stream).is_err() {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(POLL_INTERVAL),
                }
            }
            conn_queue.close();
        })
    };

    Runtime::Threaded {
        acceptor: Some(acceptor),
        handlers,
    }
}

/// Serve one connection until EOF, I/O error, or server shutdown.
fn handle_connection(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) {
    let metrics = svc.shared_metrics();
    metrics.record_conn_opened();
    let _ = serve_stream(stream, svc, points, shutdown);
    metrics.record_conn_closed();
}

/// Blocking frame loop for the threaded runtime: raw reads into a local
/// buffer, wire-mode negotiation on the first bytes, then one reply per
/// complete frame — the same framing rules as the event loop, minus
/// pipelined reordering (frames are answered one at a time).
fn serve_stream(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    use protocol::WireMode;

    stream.set_nodelay(true)?;
    // Reads time out so an idle connection re-checks the shutdown flag;
    // partial frames persist in `buf` across timeouts.
    stream.set_read_timeout(Some(POLL_INTERVAL))?;
    let mut reader = stream.try_clone()?;
    let mut writer = BufWriter::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut mode: Option<WireMode> = None;
    // resume offset for the JSON newline scan
    let mut scan_from = 0usize;
    let mut chunk = [0u8; 64 * 1024];
    let mut eof = false;
    loop {
        // 1. drain every complete frame currently buffered
        loop {
            if mode.is_none() {
                match protocol::negotiate(&buf) {
                    Negotiation::NeedMore if !eof => break,
                    // an unfinished negotiation at EOF can only be JSON
                    // garbage — fall through to the JSON tail handling
                    Negotiation::NeedMore => mode = Some(WireMode::Json),
                    Negotiation::Json => mode = Some(WireMode::Json),
                    Negotiation::Binary => {
                        buf.drain(..protocol::BINARY_MAGIC.len());
                        mode = Some(WireMode::Binary);
                    }
                }
            }
            // answer every complete frame by offset, then drop the
            // consumed prefix in ONE drain (a burst of pipelined frames
            // in a single read must not memmove the buffer per frame)
            let m = mode.expect("negotiated above");
            let mut start = 0usize;
            match m {
                WireMode::Json => {
                    while let Some(rel) = buf[scan_from..].iter().position(|&b| b == b'\n') {
                        let end = scan_from + rel;
                        let mut line = &buf[start..end];
                        if line.last() == Some(&b'\r') {
                            line = &line[..line.len() - 1];
                        }
                        if line.len() > protocol::MAX_LINE_BYTES {
                            write_frame(
                                &mut writer,
                                &protocol::encode_error_frame(m, None, "request line too long"),
                            )?;
                            return Ok(());
                        }
                        let reply = answer_frame(m, line, svc, points, shutdown);
                        write_frame(&mut writer, &reply)?;
                        if shutdown.load(Ordering::SeqCst) {
                            return Ok(());
                        }
                        start = end + 1;
                        scan_from = start;
                    }
                    scan_from = buf.len();
                    if start > 0 {
                        buf.drain(..start);
                        scan_from -= start;
                    }
                    if buf.len() > protocol::MAX_LINE_BYTES {
                        // a frame that drips past the cap without its
                        // newline cannot be served
                        write_frame(
                            &mut writer,
                            &protocol::encode_error_frame(m, None, "request line too long"),
                        )?;
                        return Ok(());
                    }
                    if eof && !buf.is_empty() {
                        // a final unterminated line is still a frame
                        // (write-all then half-close)
                        let tail = std::mem::take(&mut buf);
                        scan_from = 0;
                        let reply = answer_frame(m, &tail, svc, points, shutdown);
                        write_frame(&mut writer, &reply)?;
                    }
                    break;
                }
                WireMode::Binary => {
                    loop {
                        match protocol::split_binary_frame(&buf[start..]) {
                            Err(msg) => {
                                // oversized declared length: binary
                                // framing cannot resync past it
                                write_frame(
                                    &mut writer,
                                    &protocol::encode_error_frame(m, None, &msg),
                                )?;
                                return Ok(());
                            }
                            Ok(None) => break,
                            Ok(Some(consumed)) => {
                                let payload = &buf[start + 4..start + consumed];
                                let reply = answer_frame(m, payload, svc, points, shutdown);
                                write_frame(&mut writer, &reply)?;
                                if shutdown.load(Ordering::SeqCst) {
                                    return Ok(());
                                }
                                start += consumed;
                            }
                        }
                    }
                    if start > 0 {
                        buf.drain(..start);
                    }
                    if eof && !buf.is_empty() {
                        write_frame(
                            &mut writer,
                            &protocol::encode_error_frame(
                                m,
                                None,
                                "truncated binary frame before eof",
                            ),
                        )?;
                        buf.clear();
                    }
                    break;
                }
            }
        }
        if eof {
            return Ok(());
        }
        // 2. read more bytes (or notice EOF / shutdown)
        match reader.read(&mut chunk) {
            Ok(0) => eof = true,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Ok(()),
        }
    }
}

fn write_frame(writer: &mut BufWriter<TcpStream>, frame: &[u8]) -> std::io::Result<()> {
    writer.write_all(frame)?;
    writer.flush()
}

/// Decode one request frame payload and produce the complete response
/// frame in the same wire mode.
fn answer_frame(
    mode: protocol::WireMode,
    payload: &[u8],
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) -> Vec<u8> {
    use protocol::WireMode;
    let parsed = match mode {
        WireMode::Json => {
            let line = match std::str::from_utf8(payload) {
                Ok(s) => s,
                Err(_) => {
                    return protocol::encode_error_frame(mode, None, "bad request: invalid utf-8")
                }
            };
            if line.trim().is_empty() {
                return protocol::encode_error_frame(mode, None, "empty request");
            }
            protocol::parse_request(line)
        }
        WireMode::Binary => protocol::parse_request_binary(payload),
    };
    match parsed {
        Err(e) => protocol::encode_error_frame(mode, e.req_id, &format!("bad request: {e}")),
        Ok(Request { req_id, body }) => match body {
            RequestBody::Points => protocol::encode_points_frame(mode, req_id, points),
            RequestBody::Shutdown => {
                shutdown.store(true, Ordering::SeqCst);
                protocol::encode_shutting_down_frame(mode, req_id)
            }
            RequestBody::Op(op) => {
                let resp = svc.submit(op);
                protocol::encode_response_frame(mode, req_id, &resp)
            }
        },
    }
}
