//! The network serving layer: a `std::net` TCP front-end over the
//! [`crate::coordinator`] batching worker pool.
//!
//! # Architecture
//!
//! ```text
//! client ── TCP ──▶ acceptor thread ──▶ BoundedQueue<TcpStream>
//!                                            │
//!                                   handler pool (max_conns threads)
//!                                            │  parse line → Op
//!                                            ▼
//!                                  Coordinator::submit  (dynamic
//!                                  batcher: concurrent connections
//!                                  share batched hash executions)
//!                                            │
//!                                            ▼
//!                                   encode Response → write line
//! ```
//!
//! The coordinator queue is the *shared* batching point: requests from
//! different connections land in the same [`crate::coordinator::BoundedQueue`] and are
//! hashed in one batched matmul, so wire concurrency directly feeds
//! batch occupancy.
//!
//! # Wire protocol
//!
//! Newline-delimited JSON, one frame per line, UTF-8, max 8 MiB per
//! line. Every request may carry an optional `req_id` (u64) that is
//! echoed in the response, enabling client-side correlation.
//!
//! Requests:
//!
//! ```text
//! {"op":"hash",     "samples":[f32…]}
//! {"op":"insert",   "id":u64, "samples":[f32…]}
//! {"op":"query",    "samples":[f32…], "k":usize}
//! {"op":"remove",   "id":u64}
//! {"op":"metrics"}
//! {"op":"snapshot", "path":"…"}          (FLSH1 index dump, server-side path)
//! {"op":"ping"}
//! {"op":"points"}                        (published sample points)
//! {"op":"shutdown"}                      (graceful stop + shutdown snapshot)
//! ```
//!
//! Responses are an envelope with `"ok"`:
//!
//! ```text
//! {"ok":true, "req_id":…, "type":"signature", "signature":[i32…]}
//! {"ok":true, "req_id":…, "type":"inserted",  "id":u64}
//! {"ok":true, "req_id":…, "type":"hits",      "hits":[{"id":u64,"distance":f64}…]}
//! {"ok":true, "req_id":…, "type":"removed",   "id":u64}
//! {"ok":true, "req_id":…, "type":"metrics",   "metrics":{…}}
//! {"ok":true, "req_id":…, "type":"snapshot",  "path":"…", "bytes":u64}
//! {"ok":true, "req_id":…, "type":"pong",      "indexed":u64}
//! {"ok":true, "req_id":…, "type":"points",    "points":[f64…]}
//! {"ok":true, "req_id":…, "type":"shutting_down"}
//! {"ok":false,"req_id":…, "error":"…"}        (error envelope, both
//!                                              bad requests and op failures)
//! ```
//!
//! # Shutdown
//!
//! Graceful shutdown (the `shutdown` op, or [`Server::shutdown`]) stops
//! the acceptor, drains handler threads (in-flight requests complete),
//! and — if `server.snapshot_path` is configured — snapshots the
//! `ShardedIndex` in the `FLSH1` format so a restart can skip
//! re-hashing the corpus.

pub mod client;
pub mod protocol;

pub use client::{run_load, Client, ClientError, LatencyHistogram, LoadConfig, LoadReport};

use crate::config::ServiceConfig;
use crate::coordinator::{BoundedQueue, Coordinator, Op, Response};
use protocol::{Request, RequestBody};
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often blocked I/O paths re-check the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// The running TCP front-end.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    snapshot_path: String,
}

impl Server {
    /// Bind `cfg.server.host:cfg.server.port` (port 0 = ephemeral) and
    /// start the acceptor + handler pool over an already-running
    /// coordinator. `points` are the service's published sample points,
    /// served to clients via the `points` op.
    pub fn start(
        cfg: &ServiceConfig,
        svc: Arc<Coordinator>,
        points: Vec<f64>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind((cfg.server.host.as_str(), cfg.server.port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let points = Arc::new(points);
        // Accepted-but-unserved connections queue here; capacity bounds
        // the accept backlog the same way the coordinator queue bounds
        // requests.
        let conn_queue: Arc<BoundedQueue<TcpStream>> =
            Arc::new(BoundedQueue::new(cfg.server.max_conns.max(1) * 4));

        let mut handlers = Vec::new();
        for _ in 0..cfg.server.max_conns.max(1) {
            let conn_queue = conn_queue.clone();
            let svc = svc.clone();
            let shutdown = shutdown.clone();
            let points = points.clone();
            handlers.push(std::thread::spawn(move || {
                while let Some(batch) = conn_queue.pop_batch(1, POLL_INTERVAL) {
                    for stream in batch {
                        handle_connection(stream, &svc, &points, &shutdown);
                    }
                }
            }));
        }

        let acceptor = {
            let shutdown = shutdown.clone();
            let conn_queue = conn_queue.clone();
            std::thread::spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // the listener is non-blocking; handlers use
                            // blocking reads with a timeout. A full
                            // backlog sheds the connection (drop = RST)
                            // instead of blocking the acceptor, so
                            // shutdown can never deadlock on a saturated
                            // handler pool.
                            let _ = stream.set_nonblocking(false);
                            if conn_queue.try_push(stream).is_err() {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => std::thread::sleep(POLL_INTERVAL),
                    }
                }
                conn_queue.close();
            })
        };

        Ok(Self {
            addr,
            shutdown,
            acceptor: Some(acceptor),
            handlers,
            svc,
            points,
            snapshot_path: cfg.server.snapshot_path.clone(),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The published sample points.
    pub fn points(&self) -> &[f64] {
        &self.points
    }

    /// Whether shutdown has been requested (locally or via the wire).
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain handlers, write the shutdown snapshot (if
    /// configured), and hand the coordinator back to the caller (who
    /// still owns its lifecycle). Returns the snapshot outcome:
    /// `None` if disabled, `Some(Ok(bytes))` / `Some(Err(e))` otherwise.
    pub fn shutdown(mut self) -> (Arc<Coordinator>, Option<std::io::Result<u64>>) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
        let snapshot = if self.snapshot_path.is_empty() {
            None
        } else {
            Some(
                match self.svc.submit(Op::Snapshot {
                    path: self.snapshot_path.clone(),
                }) {
                    Response::Snapshotted { bytes, .. } => Ok(bytes),
                    Response::Error(e) => Err(std::io::Error::other(e)),
                    other => Err(std::io::Error::other(format!(
                        "unexpected snapshot response {other:?}"
                    ))),
                },
            )
        };
        (self.svc, snapshot)
    }
}

/// Serve one connection until EOF, I/O error, or server shutdown.
fn handle_connection(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) {
    let metrics = svc.shared_metrics();
    metrics.record_conn_opened();
    let _ = serve_stream(stream, svc, points, shutdown);
    metrics.record_conn_closed();
}

fn serve_stream(
    stream: TcpStream,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    // Reads time out so an idle connection re-checks the shutdown flag;
    // a timed-out read_line keeps its partial line and resumes.
    stream.set_read_timeout(Some(POLL_INTERVAL))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        // per-call byte limit: a frame that exceeds MAX_LINE_BYTES hits
        // the limit before the newline and is rejected below, so a
        // hostile sender cannot grow the buffer without bound
        let mut limited = (&mut reader).take((protocol::MAX_LINE_BYTES + 1) as u64);
        match limited.read_line(&mut line) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {
                if line.len() > protocol::MAX_LINE_BYTES {
                    let reply = protocol::encode_error(None, "request line too long");
                    writer.write_all(reply.as_bytes())?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                    return Ok(());
                }
                let reply = answer(&line, svc, points, shutdown);
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                line.clear();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                // timed-out reads keep their partial line and resume, but
                // a frame that drips past the cap without a newline is
                // rejected here too
                if shutdown.load(Ordering::SeqCst) || line.len() > protocol::MAX_LINE_BYTES {
                    return Ok(());
                }
            }
            Err(_) => return Ok(()),
        }
    }
}

/// Decode one request line and produce the response line.
fn answer(
    line: &str,
    svc: &Arc<Coordinator>,
    points: &Arc<Vec<f64>>,
    shutdown: &Arc<AtomicBool>,
) -> String {
    if line.trim().is_empty() {
        return protocol::encode_error(None, "empty request");
    }
    match protocol::parse_request(line) {
        Err(e) => protocol::encode_error(None, &format!("bad request: {e}")),
        Ok(Request { req_id, body }) => match body {
            RequestBody::Points => protocol::encode_points(req_id, points),
            RequestBody::Shutdown => {
                shutdown.store(true, Ordering::SeqCst);
                protocol::encode_shutting_down(req_id)
            }
            RequestBody::Op(op) => {
                let resp = svc.submit(op);
                protocol::encode_response(req_id, &resp)
            }
        },
    }
}
