//! The readiness-based serving mode (Linux): one epoll thread multiplexes
//! every connection, a fixed worker pool feeds the coordinator's dynamic
//! batcher, and per-connection reorder buffers keep wire responses in
//! request order even though batches complete out of order.
//!
//! ```text
//!                    ┌──────────────── epoll thread ───────────────┐
//! clients ── TCP ──▶ │ accept / read / shared protocol::Framer     │
//!                    │  (newline JSON, or FBIN1 length prefixes    │
//!                    │   when the first 5 bytes negotiate binary)  │
//!                    │   parse → Job{token, seq, req_id, ops, wire,│
//!                    │           span (decode stamped)}            │
//!                    └──────────────┬──────────────────────────────┘
//!                                   │ BoundedQueue<Job>
//!                          io_workers threads: submit_async the whole
//!                          job batch → coordinator batcher → recv
//!                                   │ completions + eventfd wake
//!                    ┌──────────────▼──────────────────────────────┐
//!                    │ reorder by per-conn seq → write_buf → socket│
//!                    └─────────────────────────────────────────────┘
//! ```
//!
//! Each connection carries its own wire mode ([`protocol::negotiate`] on
//! its first bytes); completions are pre-encoded frames in that mode, so
//! JSON and binary connections interleave freely on one loop.
//!
//! Backpressure: a connection with `pipeline_depth` responses outstanding
//! (or an unflushed write buffer past the high-water mark) has its read
//! interest cleared until it drains; the stall is counted in
//! [`ServiceMetrics`]. The job queue is bounded too — overflow parks in a
//! FIFO spill list and retries each tick, so the epoll thread never
//! blocks.
//!
//! Admission control: every coordinator frame is charged its payload
//! bytes against a per-connection and a global in-flight budget at
//! decode; over budget, the frame is answered with a typed `overloaded`
//! envelope (and counted as a shed) instead of being queued. A
//! connection whose pending output (write buffer plus parked
//! out-of-order completions) exceeds the write-queue bound is a slow
//! reader: it gets a final typed error and is disconnected, so the
//! reorder buffer cannot grow without limit.
//!
//! Coalescing: adjacent single-op frames drained from one connection in
//! one read pass are folded into a synthetic server-side batch job, so
//! naive clients co-occupy kernel batches like `*_batch` callers; each
//! member keeps its own seq/req_id/span and is answered with its own
//! frame, byte-identical to the uncoalesced reply, in request order.

use super::protocol::{self, Framer, FramerStep, WireMode};
use super::reactor::{event, Poller, Waker};
use crate::coordinator::{BoundedQueue, Coordinator, Op, Response, ServiceMetrics};
use crate::trace::{Span, Stage};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// How often the loop re-checks the shutdown flag when idle.
const TICK: Duration = Duration::from_millis(50);

/// Unflushed output past this mark pauses reads from that connection.
const WRITE_HIGH_WATER: usize = protocol::MAX_FRAME_BYTES;

/// How long the shutdown drain waits for in-flight responses to flush
/// before force-closing whatever is left (a peer that never reads its
/// responses must not pin the server open).
const SHUTDOWN_GRACE: Duration = Duration::from_secs(10);

/// A parsed coordinator request (or a coalesced run of them) in flight
/// between the epoll thread and the worker pool.
struct Job {
    token: u64,
    /// frame format of the connection that sent it (every response is
    /// encoded in the same format)
    wire: WireMode,
    payload: JobPayload,
}

/// One single-op frame folded into a coalesced job: it keeps its own
/// ordering seq, correlation id, span, and admission charge, so its
/// reply frame is indistinguishable from an uncoalesced one.
struct CoalescedFrame {
    seq: u64,
    req_id: Option<u64>,
    op: Op,
    span: Span,
    cost: u64,
}

/// What the job asks the coordinator to do. `span`s are already stamped
/// through decode; `cost` is the admission-control charge (request
/// payload bytes) released when the frame's completion returns to the
/// epoll thread.
enum JobPayload {
    /// a single op → a single response frame
    One {
        seq: u64,
        req_id: Option<u64>,
        op: Op,
        span: Span,
        cost: u64,
    },
    /// a batch frame's items (per-item decode failures ride as `Err`) →
    /// one batch envelope with per-item results
    Batch {
        seq: u64,
        req_id: Option<u64>,
        items: Vec<Result<Op, String>>,
        span: Span,
        cost: u64,
    },
    /// adjacent single-op frames folded server-side: submitted
    /// back-to-back so they co-occupy kernel batches, but each member
    /// is answered with its own frame
    Coalesced(Vec<CoalescedFrame>),
}

/// A finished response on its way back to the epoll thread, already
/// encoded as complete wire bytes for its connection's mode. `spans`
/// carries the frame's traced ops, stamped through encode; the loop adds
/// the write-queued stamp when the frame enters the write buffer (empty
/// — no allocation — for untraced requests and inline completions).
/// `cost` is the admission charge to release on arrival.
struct Completion {
    token: u64,
    seq: u64,
    frame: Vec<u8>,
    spans: Vec<Span>,
    cost: u64,
}

/// Handles owned by [`super::Server`] for the event-loop runtime.
pub(super) struct EventServer {
    loop_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    jobs: Arc<BoundedQueue<Job>>,
    waker: Arc<Waker>,
}

impl EventServer {
    /// Wake the loop (the caller has set the shutdown flag), wait for it
    /// to drain and exit, then stop the worker pool.
    pub(super) fn stop(&mut self) {
        self.waker.wake();
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Spawn the epoll thread + worker pool over an already-bound,
/// non-blocking listener.
pub(super) fn start(
    listener: TcpListener,
    io_workers: usize,
    pipeline_depth: usize,
    job_queue_depth: usize,
    limits: super::Limits,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<EventServer> {
    listener.set_nonblocking(true)?;
    let poller = Poller::new(1024)?;
    let waker = Arc::new(Waker::new()?);
    poller.register(listener.as_raw_fd(), event::READ, TOKEN_LISTENER)?;
    poller.register(waker.fd(), event::READ, TOKEN_WAKER)?;

    let jobs: Arc<BoundedQueue<Job>> = Arc::new(BoundedQueue::new(job_queue_depth.max(64)));
    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let metrics = svc.shared_metrics();
    // test-only fault injection: a worker panics while handling
    // `remove` of this id, exercising the poison-recovery path
    let panic_op_id: Option<u64> = std::env::var("FUNCLSH_TEST_WORKER_PANIC")
        .ok()
        .and_then(|v| v.parse().ok());

    let mut workers = Vec::new();
    for _ in 0..io_workers.max(1) {
        let jobs = jobs.clone();
        let svc = svc.clone();
        let completions = completions.clone();
        let waker = waker.clone();
        workers.push(std::thread::spawn(move || {
            worker_loop(&jobs, &svc, &completions, &waker, panic_op_id);
        }));
    }

    let state = LoopState {
        poller,
        listener,
        waker: waker.clone(),
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        jobs: jobs.clone(),
        pending_jobs: VecDeque::new(),
        completions,
        metrics,
        points,
        shutdown,
        pipeline_depth: pipeline_depth.max(1),
        limits,
        inflight_global: 0,
    };
    let loop_thread = std::thread::spawn(move || state.run());

    Ok(EventServer {
        loop_thread: Some(loop_thread),
        workers,
        jobs,
        waker,
    })
}

/// Error answered for a frame whose worker-side processing panicked:
/// the bug fails that request alone, not the reactor.
const WORKER_PANIC_MSG: &str = "internal error: request processing panicked";

/// Test hook: `FUNCLSH_TEST_WORKER_PANIC=<id>` makes a worker panic
/// while handling `remove` of that id, simulating a request-processing
/// bug so the panic-isolation path stays covered end to end.
fn maybe_injected_panic(panic_op_id: Option<u64>, op: &Op) {
    if let (Some(target), Op::Remove { id }) = (panic_op_id, op) {
        if *id == target {
            panic!("injected worker panic (FUNCLSH_TEST_WORKER_PANIC)");
        }
    }
}

/// The completion a panicked frame falls back to (admission charge still
/// released on arrival).
fn panic_completion(
    token: u64,
    seq: u64,
    req_id: Option<u64>,
    wire: WireMode,
    cost: u64,
) -> Completion {
    Completion {
        token,
        seq,
        frame: protocol::encode_error_frame(wire, req_id, WORKER_PANIC_MSG),
        spans: Vec::new(),
        cost,
    }
}

/// Worker: drain a batch of jobs, push them *all* into the coordinator
/// (so wire concurrency turns into batch occupancy), then collect the
/// responses and hand them back to the epoll thread. Submission and
/// encoding run under `catch_unwind` per frame: a panicking request
/// degrades to an error envelope for its own connection instead of
/// poisoning shared state and taking down the reactor.
fn worker_loop(
    jobs: &BoundedQueue<Job>,
    svc: &Coordinator,
    completions: &Mutex<Vec<Completion>>,
    waker: &Waker,
    panic_op_id: Option<u64>,
) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    /// One frame's submitted receivers (a single op is a batch of one; a
    /// batch frame keeps `batched` so its response stays one envelope).
    struct Wait {
        token: u64,
        seq: u64,
        req_id: Option<u64>,
        wire: WireMode,
        cost: u64,
        rxs: super::PendingBatch,
        batched: bool,
    }
    while let Some(batch) = jobs.pop_batch(32, Duration::from_micros(200)) {
        // every op of every job is submitted before any is awaited, so
        // wire concurrency, in-frame batching, AND server-side
        // coalescing all turn into coordinator batch occupancy; the
        // per-item mapping is the shared submit_batch_async, so both
        // runtimes emit identical per-item error envelopes
        let mut waits: Vec<Result<Wait, Completion>> = Vec::with_capacity(batch.len());
        for job in batch {
            let Job {
                token,
                wire,
                payload,
            } = job;
            let submit_one =
                |seq: u64, req_id: Option<u64>, op: Op, span: Span, cost: u64, batched: bool| {
                    let sub = catch_unwind(AssertUnwindSafe(|| {
                        maybe_injected_panic(panic_op_id, &op);
                        super::submit_batch_async(svc, vec![Ok(op)], span)
                    }));
                    match sub {
                        Ok(rxs) => Ok(Wait {
                            token,
                            seq,
                            req_id,
                            wire,
                            cost,
                            rxs,
                            batched,
                        }),
                        Err(_) => Err(panic_completion(token, seq, req_id, wire, cost)),
                    }
                };
            match payload {
                JobPayload::One {
                    seq,
                    req_id,
                    op,
                    span,
                    cost,
                } => waits.push(submit_one(seq, req_id, op, span, cost, false)),
                JobPayload::Coalesced(members) => {
                    for m in members {
                        waits.push(submit_one(m.seq, m.req_id, m.op, m.span, m.cost, false));
                    }
                }
                JobPayload::Batch {
                    seq,
                    req_id,
                    items,
                    span,
                    cost,
                } => {
                    let sub = catch_unwind(AssertUnwindSafe(|| {
                        for op in items.iter().flatten() {
                            maybe_injected_panic(panic_op_id, op);
                        }
                        super::submit_batch_async(svc, items, span)
                    }));
                    waits.push(match sub {
                        Ok(rxs) => Ok(Wait {
                            token,
                            seq,
                            req_id,
                            wire,
                            cost,
                            rxs,
                            batched: true,
                        }),
                        Err(_) => Err(panic_completion(token, seq, req_id, wire, cost)),
                    });
                }
            }
        }
        let mut done = Vec::with_capacity(waits.len());
        for w in waits {
            let Wait {
                token,
                seq,
                req_id,
                wire,
                cost,
                rxs,
                batched,
            } = match w {
                Ok(w) => w,
                Err(c) => {
                    done.push(c);
                    continue;
                }
            };
            let enc = catch_unwind(AssertUnwindSafe(|| {
                let (results, mut spans): (Vec<Response>, Vec<Span>) = super::collect_batch(rxs);
                // Signature responses serialize straight from the
                // coordinator's shared flat block here; a batch too big
                // for one envelope streams as continuation frames
                let frame = if batched {
                    protocol::encode_batch_response_frame(wire, req_id, &results)
                } else {
                    protocol::encode_response_frame(wire, req_id, &results[0])
                };
                for s in spans.iter_mut() {
                    s.stamp(Stage::Encode);
                }
                (frame, spans)
            }));
            done.push(match enc {
                Ok((frame, spans)) => Completion {
                    token,
                    seq,
                    frame,
                    spans,
                    cost,
                },
                Err(_) => panic_completion(token, seq, req_id, wire, cost),
            });
        }
        // a worker that panicked past catch_unwind in an earlier life
        // may have poisoned this mutex; the Vec inside is still
        // well-formed (extend is atomic with respect to panics here),
        // so recover the guard rather than cascading the poison
        completions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .extend(done);
        waker.wake();
    }
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    /// the shared incremental framer: negotiation state, partial
    /// frames, scan offsets, and the frame caps all live in here
    framer: Framer,
    /// whether this connection's negotiated wire mode has been counted
    /// in the per-format metrics
    counted_mode: bool,
    /// encoded responses awaiting the socket
    write_buf: Vec<u8>,
    /// first unwritten byte of `write_buf`
    write_from: usize,
    /// sequence number assigned to the next frame read
    next_seq: u64,
    /// sequence number of the next response to put on the wire
    next_write_seq: u64,
    /// out-of-order completions parked until their turn (pre-encoded
    /// frames in this connection's wire mode, plus the traced spans
    /// awaiting their write-queued stamp)
    completed: BTreeMap<u64, (Vec<u8>, Vec<Span>)>,
    /// total bytes of the parked frames in `completed` (the slow-client
    /// bound covers these plus the unflushed write buffer)
    parked_bytes: usize,
    /// admission-control charge outstanding for this connection
    /// (request payload bytes dispatched, not yet completed)
    inflight_bytes: u64,
    /// EOF seen, or reads retired by shutdown
    read_closed: bool,
    /// fatal protocol error: close once all responses have flushed
    close_after_flush: bool,
    /// currently read-stalled (for backpressure accounting)
    was_stalled: bool,
    /// interest mask currently registered with the poller
    interest: u32,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            framer: Framer::new(),
            counted_mode: false,
            write_buf: Vec::new(),
            write_from: 0,
            next_seq: 0,
            next_write_seq: 0,
            completed: BTreeMap::new(),
            parked_bytes: 0,
            inflight_bytes: 0,
            read_closed: false,
            close_after_flush: false,
            was_stalled: false,
            interest: event::READ,
        }
    }

    /// Frames read but not yet answered on the wire.
    fn in_flight(&self) -> u64 {
        self.next_seq - self.next_write_seq
    }

    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    fn complete(&mut self, seq: u64, frame: Vec<u8>, spans: Vec<Span>) {
        self.parked_bytes += frame.len();
        if let Some((old, _)) = self.completed.insert(seq, (frame, spans)) {
            self.parked_bytes -= old.len();
        }
    }

    /// Bytes queued toward this peer: unflushed write buffer plus
    /// parked out-of-order completions (what the slow-client bound
    /// limits).
    fn pending_out_bytes(&self) -> usize {
        (self.write_buf.len() - self.write_from) + self.parked_bytes
    }

    /// Move in-order completions into the write buffer (frames carry
    /// their own terminator/prefix); returns the bytes moved so the
    /// caller can feed the per-wire-mode output counters. Traced spans
    /// finish here — write-queued is stamped the moment the frame's
    /// bytes are queued for the socket, then the span is recorded.
    fn flush_ready(&mut self, metrics: &ServiceMetrics) -> usize {
        let before = self.write_buf.len();
        while let Some((frame, mut spans)) = self.completed.remove(&self.next_write_seq) {
            self.parked_bytes -= frame.len();
            self.write_buf.extend_from_slice(&frame);
            self.next_write_seq += 1;
            for span in spans.iter_mut() {
                span.stamp(Stage::WriteQueued);
                metrics.record_span(span);
            }
        }
        self.write_buf.len() - before
    }

    fn has_pending_write(&self) -> bool {
        self.write_from < self.write_buf.len()
    }

    /// Whether reads should pause until this connection drains.
    fn stalled(&self, pipeline_depth: usize) -> bool {
        self.in_flight() >= pipeline_depth as u64
            || self.write_buf.len() - self.write_from >= WRITE_HIGH_WATER
    }

    /// Push buffered output to the (non-blocking) socket.
    fn try_write(&mut self) -> std::io::Result<()> {
        while self.write_from < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_from..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => self.write_from += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.write_from == self.write_buf.len() {
            self.write_buf.clear();
            self.write_from = 0;
        }
        Ok(())
    }
}

struct LoopState {
    poller: Poller,
    listener: TcpListener,
    waker: Arc<Waker>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    jobs: Arc<BoundedQueue<Job>>,
    /// jobs that found the queue full; retried each tick in FIFO order
    pending_jobs: VecDeque<Job>,
    completions: Arc<Mutex<Vec<Completion>>>,
    metrics: Arc<ServiceMetrics>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
    pipeline_depth: usize,
    /// admission budgets + coalescing policy (the `[server]` keys)
    limits: super::Limits,
    /// request payload bytes dispatched and not yet completed, across
    /// all connections (charged and released on the epoll thread only,
    /// so a plain counter suffices)
    inflight_global: u64,
}

impl LoopState {
    fn run(mut self) {
        let mut shutting_down = false;
        let mut drain_deadline: Option<Instant> = None;
        loop {
            let ready = match self.poller.wait(TICK) {
                Ok(r) => r,
                Err(e) => {
                    crate::util::log::warn(format!("server event loop: poll failed: {e}"));
                    break;
                }
            };
            if !ready.is_empty() {
                self.metrics.record_readiness_events(ready.len() as u64);
            }
            for r in ready {
                match r.token {
                    TOKEN_LISTENER => {
                        if !shutting_down {
                            self.accept_ready();
                        }
                    }
                    TOKEN_WAKER => self.waker.drain(),
                    token => {
                        if r.readable() {
                            self.handle_readable(token);
                        }
                        if r.writable() {
                            self.finish_io(token);
                        }
                    }
                }
            }
            self.retry_pending_jobs();
            self.apply_completions();
            if !shutting_down && self.shutdown.load(Ordering::SeqCst) {
                shutting_down = true;
                drain_deadline = Some(Instant::now() + SHUTDOWN_GRACE);
                self.begin_shutdown();
            }
            if shutting_down {
                if self.conns.is_empty() && self.pending_jobs.is_empty() {
                    break;
                }
                if drain_deadline.is_some_and(|d| Instant::now() >= d) {
                    // grace expired: stop waiting on peers that will not
                    // drain (the final cleanup below closes them)
                    self.pending_jobs.clear();
                    break;
                }
            }
        }
        // abnormal exit (poll failure): drop whatever is left, with the
        // close counters kept honest
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(c) = self.conns.remove(&t) {
                self.drop_conn(t, c);
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        self.metrics.record_rejected_accept();
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .poller
                        .register(stream.as_raw_fd(), event::READ, token)
                        .is_err()
                    {
                        // fd table exhausted: shed the connection
                        self.metrics.record_rejected_accept();
                        continue;
                    }
                    self.metrics.record_conn_opened();
                    self.conns.insert(token, Conn::new(stream));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    // EMFILE/ENFILE etc.: the pending connection keeps the
                    // level-triggered listener readable, so without a pause
                    // this would spin the loop at 100% until an fd frees
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    fn handle_readable(&mut self, token: u64) {
        let mut conn = match self.conns.remove(&token) {
            Some(c) => c,
            None => return,
        };
        let mut buf = [0u8; 64 * 1024];
        loop {
            if conn.read_closed || conn.close_after_flush {
                break;
            }
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.read_closed = true;
                    conn.framer.push_eof();
                    self.parse_frames(&mut conn, token);
                    break;
                }
                Ok(n) => {
                    conn.framer.push(&buf[..n]);
                    self.parse_frames(&mut conn, token);
                    if conn.stalled(self.pipeline_depth) {
                        break; // backpressure: leave the rest in the kernel
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.drop_conn(token, conn);
                    return;
                }
            }
        }
        self.settle(token, conn);
    }

    /// Pull every complete frame out of the connection's [`Framer`] and
    /// answer it. The framer is taken out of the connection for the
    /// duration so frames are handled as zero-copy slices; a `Fatal`
    /// step (over-cap line/length, eof-truncated binary frame) is
    /// answered once and closes the connection after the flush.
    fn parse_frames(&mut self, conn: &mut Conn, token: u64) {
        let mut framer = std::mem::take(&mut conn.framer);
        let mut group: Vec<CoalescedFrame> = Vec::new();
        while !conn.close_after_flush {
            match framer.next() {
                FramerStep::Pending => break,
                FramerStep::Fatal { wire, msg } => {
                    let seq = conn.take_seq();
                    conn.complete(
                        seq,
                        protocol::encode_error_frame(wire, None, &msg),
                        Vec::new(),
                    );
                    conn.close_after_flush = true;
                    conn.read_closed = true;
                }
                FramerStep::Frame { wire, payload } => {
                    // count whole wire bytes (payload + newline or
                    // length prefix), so bytes_in_* reconciles against
                    // a packet capture; record_wire_out already counts
                    // whole frames
                    let wire_bytes = payload.len() + protocol::frame_overhead_bytes(wire);
                    self.metrics
                        .record_wire_in(wire == WireMode::Binary, 1, wire_bytes as u64);
                    self.handle_frame(conn, token, wire, payload, &mut group);
                }
            }
        }
        if !group.is_empty() {
            self.flush_group(token, framer.wire_mode(), &mut group);
        }
        framer.compact();
        if !conn.counted_mode {
            if let Some(m) = framer.negotiated() {
                self.metrics.record_wire_conn(m == WireMode::Binary);
                if m == WireMode::Binary {
                    // the 5 FBIN1 magic bytes crossed the wire exactly
                    // once, before the first counted frame
                    self.metrics
                        .record_wire_in(true, 0, protocol::MAGIC_LEN as u64);
                }
                conn.counted_mode = true;
            }
        }
        conn.framer = framer;
    }

    /// Dispatch an accumulated run of adjacent single-op frames: one
    /// frame stays a plain `One` job, two or more fold into a
    /// `Coalesced` job (counted) so they co-occupy a kernel batch.
    fn flush_group(&mut self, token: u64, wire: WireMode, group: &mut Vec<CoalescedFrame>) {
        match group.len() {
            0 => {}
            1 => {
                let m = group.pop().expect("len checked");
                self.dispatch(Job {
                    token,
                    wire,
                    payload: JobPayload::One {
                        seq: m.seq,
                        req_id: m.req_id,
                        op: m.op,
                        span: m.span,
                        cost: m.cost,
                    },
                });
            }
            n => {
                self.metrics.record_coalesced_frames(n as u64);
                self.dispatch(Job {
                    token,
                    wire,
                    payload: JobPayload::Coalesced(std::mem::take(group)),
                });
            }
        }
    }

    /// Answer one frame in its connection's wire format: transport ops
    /// inline, coordinator ops via the worker pool. Every frame gets a
    /// seq so responses flush in request order regardless of completion
    /// order. Payload decoding (UTF-8/empty rules + format dispatch) is
    /// the shared [`protocol::parse_frame_payload`] — one copy for both
    /// runtimes, like the framing itself.
    fn handle_frame(
        &mut self,
        conn: &mut Conn,
        token: u64,
        wire: WireMode,
        payload: &[u8],
        group: &mut Vec<CoalescedFrame>,
    ) {
        let seq = conn.take_seq();
        let cost = payload.len() as u64;
        let mut span = Span::new(super::span_wire(wire), self.metrics.tracing_enabled());
        let parsed = protocol::parse_frame_payload(wire, payload);
        span.stamp(Stage::Decode);
        self.route(conn, token, seq, wire, parsed, span, cost, group);
    }

    /// Admission control: charge `cost` request bytes against the
    /// per-connection and global in-flight budgets, or return the
    /// exhausted budget's scope (the frame is then shed with a typed
    /// `overloaded` envelope instead of being queued).
    fn admit(&mut self, conn: &mut Conn, cost: u64) -> Option<&'static str> {
        if conn.inflight_bytes.saturating_add(cost) > self.limits.max_inflight_bytes_per_conn {
            return Some("connection in-flight byte budget");
        }
        if self.inflight_global.saturating_add(cost) > self.limits.max_inflight_bytes {
            return Some("server in-flight byte budget");
        }
        conn.inflight_bytes += cost;
        self.inflight_global += cost;
        None
    }

    /// Shared request routing: transport ops answered inline, coordinator
    /// ops admitted against the byte budgets then dispatched to the
    /// worker pool (adjacent single ops accumulate in `group` for
    /// coalescing), parse failures answered with a correlated error
    /// envelope in the connection's wire mode.
    #[allow(clippy::too_many_arguments)]
    fn route(
        &mut self,
        conn: &mut Conn,
        token: u64,
        seq: u64,
        wire: WireMode,
        parsed: Result<protocol::Request, protocol::RequestError>,
        span: Span,
        cost: u64,
        group: &mut Vec<CoalescedFrame>,
    ) {
        match parsed {
            Err(e) => {
                self.flush_group(token, wire, group);
                conn.complete(
                    seq,
                    protocol::encode_error_frame(wire, e.req_id, &format!("bad request: {e}")),
                    Vec::new(),
                );
            }
            Ok(protocol::Request { req_id, body }) => match body {
                protocol::RequestBody::Points => {
                    self.flush_group(token, wire, group);
                    conn.complete(
                        seq,
                        protocol::encode_points_frame(wire, req_id, &self.points),
                        Vec::new(),
                    );
                }
                protocol::RequestBody::Shutdown => {
                    self.flush_group(token, wire, group);
                    self.shutdown.store(true, Ordering::SeqCst);
                    conn.complete(
                        seq,
                        protocol::encode_shutting_down_frame(wire, req_id),
                        Vec::new(),
                    );
                }
                protocol::RequestBody::Op(op) => {
                    if let Some(scope) = self.shed_check(conn, cost) {
                        // shed frames keep their seq, so reply order is
                        // intact and the remaining group stays adjacent
                        conn.complete(
                            seq,
                            protocol::encode_overloaded_frame(wire, req_id, scope),
                            Vec::new(),
                        );
                        return;
                    }
                    if self.limits.coalesce {
                        group.push(CoalescedFrame {
                            seq,
                            req_id,
                            op,
                            span,
                            cost,
                        });
                        if group.len() >= self.limits.coalesce_window {
                            self.flush_group(token, wire, group);
                        }
                    } else {
                        self.dispatch(Job {
                            token,
                            wire,
                            payload: JobPayload::One {
                                seq,
                                req_id,
                                op,
                                span,
                                cost,
                            },
                        });
                    }
                }
                protocol::RequestBody::Batch(items) => {
                    self.flush_group(token, wire, group);
                    if let Some(scope) = self.shed_check(conn, cost) {
                        conn.complete(
                            seq,
                            protocol::encode_overloaded_frame(wire, req_id, scope),
                            Vec::new(),
                        );
                        return;
                    }
                    self.dispatch(Job {
                        token,
                        wire,
                        payload: JobPayload::Batch {
                            seq,
                            req_id,
                            items,
                            span,
                            cost,
                        },
                    });
                }
            },
        }
    }

    /// [`Self::admit`] plus the shed bookkeeping, shared by the single
    /// and batch arms.
    fn shed_check(&mut self, conn: &mut Conn, cost: u64) -> Option<&'static str> {
        let scope = self.admit(conn, cost)?;
        self.metrics.record_overload_shed();
        Some(scope)
    }

    fn dispatch(&mut self, job: Job) {
        if !self.pending_jobs.is_empty() {
            self.pending_jobs.push_back(job); // keep global FIFO order
            return;
        }
        if let Err((Some(job), _)) = self.jobs.try_push(job) {
            self.pending_jobs.push_back(job);
        }
    }

    fn retry_pending_jobs(&mut self) {
        while let Some(job) = self.pending_jobs.pop_front() {
            if let Err((Some(job), _)) = self.jobs.try_push(job) {
                self.pending_jobs.push_front(job);
                break;
            }
        }
    }

    /// Route finished responses to their reorder buffers and flush every
    /// connection that may have output or a close decision pending.
    fn apply_completions(&mut self) {
        // a worker panic may have poisoned the mutex; the inner Vec is
        // always well-formed, so take it through the poison rather than
        // letting one bad request kill the reactor (the request itself
        // already degraded to an error envelope in the worker)
        let done: Vec<Completion> = std::mem::take(
            &mut *self
                .completions
                .lock()
                .unwrap_or_else(|e| e.into_inner()),
        );
        let mut touched: Vec<u64> = Vec::with_capacity(done.len());
        for c in done {
            // release the admission charge even if the connection died
            // while the job was in flight — the global budget must not
            // leak
            self.inflight_global = self.inflight_global.saturating_sub(c.cost);
            if let Some(conn) = self.conns.get_mut(&c.token) {
                conn.inflight_bytes = conn.inflight_bytes.saturating_sub(c.cost);
                conn.complete(c.seq, c.frame, c.spans);
                touched.push(c.token);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for t in touched {
            self.finish_io(t);
        }
    }

    fn finish_io(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            self.settle(token, conn);
        }
    }

    /// Flush, decide close-vs-keep, and refresh poller interest.
    fn settle(&mut self, token: u64, mut conn: Conn) {
        let moved = conn.flush_ready(&self.metrics);
        if moved > 0 {
            self.metrics
                .record_wire_out(conn.framer.wire_mode() == WireMode::Binary, moved as u64);
        }
        if conn.try_write().is_err() {
            self.drop_conn(token, conn);
            return;
        }
        if conn.pending_out_bytes() > self.limits.max_write_queue_bytes {
            // slow reader: its backlog is past the bound, so the
            // reorder buffer would otherwise grow without limit. Send a
            // final typed error (best effort — the socket is already
            // backed up) and disconnect.
            self.metrics.record_slow_client_disconnect();
            let frame = protocol::encode_overloaded_frame(
                conn.framer.wire_mode(),
                None,
                "write queue bound exceeded; client reading too slowly",
            );
            conn.write_buf.extend_from_slice(&frame);
            self.metrics
                .record_wire_out(conn.framer.wire_mode() == WireMode::Binary, frame.len() as u64);
            let _ = conn.try_write();
            self.drop_conn(token, conn);
            return;
        }
        let drained = conn.in_flight() == 0 && !conn.has_pending_write();
        if drained && (conn.read_closed || conn.close_after_flush) {
            self.drop_conn(token, conn);
            return;
        }
        let stalled = conn.stalled(self.pipeline_depth);
        if stalled && !conn.was_stalled {
            self.metrics.record_backpressure_stall();
        }
        conn.was_stalled = stalled;
        let mut interest = 0u32;
        if !conn.read_closed && !conn.close_after_flush && !stalled {
            interest |= event::READ;
        }
        if conn.has_pending_write() {
            interest |= event::WRITE;
        }
        if interest != conn.interest {
            let _ = self
                .poller
                .modify(conn.stream.as_raw_fd(), interest, token);
            conn.interest = interest;
        }
        self.conns.insert(token, conn);
    }

    fn drop_conn(&mut self, _token: u64, conn: Conn) {
        let _ = self.poller.deregister(conn.stream.as_raw_fd());
        self.metrics.record_conn_closed();
        // conn (and its stream) drops here
    }

    /// Stop accepting and reading; connections close as they drain.
    fn begin_shutdown(&mut self) {
        let _ = self.poller.deregister(self.listener.as_raw_fd());
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(mut conn) = self.conns.remove(&t) {
                conn.read_closed = true;
                self.settle(t, conn);
            }
        }
    }
}
