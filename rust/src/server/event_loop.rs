//! The readiness-based serving mode (Linux): one epoll thread multiplexes
//! every connection, a fixed worker pool feeds the coordinator's dynamic
//! batcher, and per-connection reorder buffers keep wire responses in
//! request order even though batches complete out of order.
//!
//! ```text
//!                    ┌──────────────── epoll thread ───────────────┐
//! clients ── TCP ──▶ │ accept / read / shared protocol::Framer     │
//!                    │  (newline JSON, or FBIN1 length prefixes    │
//!                    │   when the first 5 bytes negotiate binary)  │
//!                    │   parse → Job{token, seq, req_id, ops, wire,│
//!                    │           span (decode stamped)}            │
//!                    └──────────────┬──────────────────────────────┘
//!                                   │ BoundedQueue<Job>
//!                          io_workers threads: submit_async the whole
//!                          job batch → coordinator batcher → recv
//!                                   │ completions + eventfd wake
//!                    ┌──────────────▼──────────────────────────────┐
//!                    │ reorder by per-conn seq → write_buf → socket│
//!                    └─────────────────────────────────────────────┘
//! ```
//!
//! Each connection carries its own wire mode ([`protocol::negotiate`] on
//! its first bytes); completions are pre-encoded frames in that mode, so
//! JSON and binary connections interleave freely on one loop.
//!
//! Backpressure: a connection with `pipeline_depth` responses outstanding
//! (or an unflushed write buffer past the high-water mark) has its read
//! interest cleared until it drains; the stall is counted in
//! [`ServiceMetrics`]. The job queue is bounded too — overflow parks in a
//! FIFO spill list and retries each tick, so the epoll thread never
//! blocks.

use super::protocol::{self, Framer, FramerStep, WireMode};
use super::reactor::{event, Poller, Waker};
use crate::coordinator::{BoundedQueue, Coordinator, Op, Response, ServiceMetrics};
use crate::trace::{Span, Stage};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// How often the loop re-checks the shutdown flag when idle.
const TICK: Duration = Duration::from_millis(50);

/// Unflushed output past this mark pauses reads from that connection.
const WRITE_HIGH_WATER: usize = protocol::MAX_LINE_BYTES;

/// How long the shutdown drain waits for in-flight responses to flush
/// before force-closing whatever is left (a peer that never reads its
/// responses must not pin the server open).
const SHUTDOWN_GRACE: Duration = Duration::from_secs(10);

/// A parsed coordinator request in flight between the epoll thread and
/// the worker pool.
struct Job {
    token: u64,
    seq: u64,
    req_id: Option<u64>,
    payload: JobPayload,
    /// frame format of the connection that sent it (the response is
    /// encoded in the same format)
    wire: WireMode,
    /// the frame's trace span, already stamped through decode; every op
    /// the job carries rides its own copy through the coordinator
    span: Span,
}

/// What one frame asked the coordinator to do.
enum JobPayload {
    /// a single op → a single response frame
    One(Op),
    /// a batch frame's items (per-item decode failures ride as `Err`) →
    /// one batch envelope with per-item results
    Batch(Vec<Result<Op, String>>),
}

/// A finished response on its way back to the epoll thread, already
/// encoded as complete wire bytes for its connection's mode. `spans`
/// carries the frame's traced ops, stamped through encode; the loop adds
/// the write-queued stamp when the frame enters the write buffer (empty
/// — no allocation — for untraced requests and inline completions).
struct Completion {
    token: u64,
    seq: u64,
    frame: Vec<u8>,
    spans: Vec<Span>,
}

/// Handles owned by [`super::Server`] for the event-loop runtime.
pub(super) struct EventServer {
    loop_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    jobs: Arc<BoundedQueue<Job>>,
    waker: Arc<Waker>,
}

impl EventServer {
    /// Wake the loop (the caller has set the shutdown flag), wait for it
    /// to drain and exit, then stop the worker pool.
    pub(super) fn stop(&mut self) {
        self.waker.wake();
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Spawn the epoll thread + worker pool over an already-bound,
/// non-blocking listener.
pub(super) fn start(
    listener: TcpListener,
    io_workers: usize,
    pipeline_depth: usize,
    job_queue_depth: usize,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<EventServer> {
    listener.set_nonblocking(true)?;
    let poller = Poller::new(1024)?;
    let waker = Arc::new(Waker::new()?);
    poller.register(listener.as_raw_fd(), event::READ, TOKEN_LISTENER)?;
    poller.register(waker.fd(), event::READ, TOKEN_WAKER)?;

    let jobs: Arc<BoundedQueue<Job>> = Arc::new(BoundedQueue::new(job_queue_depth.max(64)));
    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let metrics = svc.shared_metrics();

    let mut workers = Vec::new();
    for _ in 0..io_workers.max(1) {
        let jobs = jobs.clone();
        let svc = svc.clone();
        let completions = completions.clone();
        let waker = waker.clone();
        workers.push(std::thread::spawn(move || {
            worker_loop(&jobs, &svc, &completions, &waker);
        }));
    }

    let state = LoopState {
        poller,
        listener,
        waker: waker.clone(),
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        jobs: jobs.clone(),
        pending_jobs: VecDeque::new(),
        completions,
        metrics,
        points,
        shutdown,
        pipeline_depth: pipeline_depth.max(1),
    };
    let loop_thread = std::thread::spawn(move || state.run());

    Ok(EventServer {
        loop_thread: Some(loop_thread),
        workers,
        jobs,
        waker,
    })
}

/// Worker: drain a batch of jobs, push them *all* into the coordinator
/// (so wire concurrency turns into batch occupancy), then collect the
/// responses and hand them back to the epoll thread.
fn worker_loop(
    jobs: &BoundedQueue<Job>,
    svc: &Coordinator,
    completions: &Mutex<Vec<Completion>>,
    waker: &Waker,
) {
    /// One job's submitted receivers (a single op is a batch of one; a
    /// batch frame keeps `batched` so its response stays one envelope).
    struct Wait {
        token: u64,
        seq: u64,
        req_id: Option<u64>,
        wire: WireMode,
        rxs: super::PendingBatch,
        batched: bool,
    }
    while let Some(batch) = jobs.pop_batch(32, Duration::from_micros(200)) {
        let mut waits = Vec::with_capacity(batch.len());
        for job in batch {
            let Job {
                token,
                seq,
                req_id,
                payload,
                wire,
                span,
            } = job;
            // every op of every job is submitted before any is awaited,
            // so wire concurrency AND in-frame batching both turn into
            // coordinator batch occupancy; the per-item mapping is the
            // shared submit_batch_async, so both runtimes emit identical
            // per-item error envelopes
            let (rxs, batched) = match payload {
                JobPayload::One(op) => {
                    (super::submit_batch_async(svc, vec![Ok(op)], span), false)
                }
                JobPayload::Batch(items) => (super::submit_batch_async(svc, items, span), true),
            };
            waits.push(Wait {
                token,
                seq,
                req_id,
                wire,
                rxs,
                batched,
            });
        }
        let mut done = Vec::with_capacity(waits.len());
        for w in waits {
            let (results, mut spans): (Vec<Response>, Vec<Span>) = super::collect_batch(w.rxs);
            // Signature responses serialize straight from the
            // coordinator's shared flat block here; the oversize guard
            // degrades an unframeable response to a correlated error
            // envelope instead of a dead connection
            let frame = if w.batched {
                protocol::encode_batch_response_frame(w.wire, w.req_id, &results)
            } else {
                protocol::encode_response_frame(w.wire, w.req_id, &results[0])
            };
            for s in spans.iter_mut() {
                s.stamp(Stage::Encode);
            }
            done.push(Completion {
                token: w.token,
                seq: w.seq,
                frame,
                spans,
            });
        }
        completions.lock().unwrap().extend(done);
        waker.wake();
    }
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    /// the shared incremental framer: negotiation state, partial
    /// frames, scan offsets, and the frame caps all live in here
    framer: Framer,
    /// whether this connection's negotiated wire mode has been counted
    /// in the per-format metrics
    counted_mode: bool,
    /// encoded responses awaiting the socket
    write_buf: Vec<u8>,
    /// first unwritten byte of `write_buf`
    write_from: usize,
    /// sequence number assigned to the next frame read
    next_seq: u64,
    /// sequence number of the next response to put on the wire
    next_write_seq: u64,
    /// out-of-order completions parked until their turn (pre-encoded
    /// frames in this connection's wire mode, plus the traced spans
    /// awaiting their write-queued stamp)
    completed: BTreeMap<u64, (Vec<u8>, Vec<Span>)>,
    /// EOF seen, or reads retired by shutdown
    read_closed: bool,
    /// fatal protocol error: close once all responses have flushed
    close_after_flush: bool,
    /// currently read-stalled (for backpressure accounting)
    was_stalled: bool,
    /// interest mask currently registered with the poller
    interest: u32,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            framer: Framer::new(),
            counted_mode: false,
            write_buf: Vec::new(),
            write_from: 0,
            next_seq: 0,
            next_write_seq: 0,
            completed: BTreeMap::new(),
            read_closed: false,
            close_after_flush: false,
            was_stalled: false,
            interest: event::READ,
        }
    }

    /// Frames read but not yet answered on the wire.
    fn in_flight(&self) -> u64 {
        self.next_seq - self.next_write_seq
    }

    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    fn complete(&mut self, seq: u64, frame: Vec<u8>, spans: Vec<Span>) {
        self.completed.insert(seq, (frame, spans));
    }

    /// Move in-order completions into the write buffer (frames carry
    /// their own terminator/prefix); returns the bytes moved so the
    /// caller can feed the per-wire-mode output counters. Traced spans
    /// finish here — write-queued is stamped the moment the frame's
    /// bytes are queued for the socket, then the span is recorded.
    fn flush_ready(&mut self, metrics: &ServiceMetrics) -> usize {
        let before = self.write_buf.len();
        while let Some((frame, mut spans)) = self.completed.remove(&self.next_write_seq) {
            self.write_buf.extend_from_slice(&frame);
            self.next_write_seq += 1;
            for span in spans.iter_mut() {
                span.stamp(Stage::WriteQueued);
                metrics.record_span(span);
            }
        }
        self.write_buf.len() - before
    }

    fn has_pending_write(&self) -> bool {
        self.write_from < self.write_buf.len()
    }

    /// Whether reads should pause until this connection drains.
    fn stalled(&self, pipeline_depth: usize) -> bool {
        self.in_flight() >= pipeline_depth as u64
            || self.write_buf.len() - self.write_from >= WRITE_HIGH_WATER
    }

    /// Push buffered output to the (non-blocking) socket.
    fn try_write(&mut self) -> std::io::Result<()> {
        while self.write_from < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_from..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => self.write_from += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.write_from == self.write_buf.len() {
            self.write_buf.clear();
            self.write_from = 0;
        }
        Ok(())
    }
}

struct LoopState {
    poller: Poller,
    listener: TcpListener,
    waker: Arc<Waker>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    jobs: Arc<BoundedQueue<Job>>,
    /// jobs that found the queue full; retried each tick in FIFO order
    pending_jobs: VecDeque<Job>,
    completions: Arc<Mutex<Vec<Completion>>>,
    metrics: Arc<ServiceMetrics>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
    pipeline_depth: usize,
}

impl LoopState {
    fn run(mut self) {
        let mut shutting_down = false;
        let mut drain_deadline: Option<Instant> = None;
        loop {
            let ready = match self.poller.wait(TICK) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("server event loop: poll failed: {e}");
                    break;
                }
            };
            if !ready.is_empty() {
                self.metrics.record_readiness_events(ready.len() as u64);
            }
            for r in ready {
                match r.token {
                    TOKEN_LISTENER => {
                        if !shutting_down {
                            self.accept_ready();
                        }
                    }
                    TOKEN_WAKER => self.waker.drain(),
                    token => {
                        if r.readable() {
                            self.handle_readable(token);
                        }
                        if r.writable() {
                            self.finish_io(token);
                        }
                    }
                }
            }
            self.retry_pending_jobs();
            self.apply_completions();
            if !shutting_down && self.shutdown.load(Ordering::SeqCst) {
                shutting_down = true;
                drain_deadline = Some(Instant::now() + SHUTDOWN_GRACE);
                self.begin_shutdown();
            }
            if shutting_down {
                if self.conns.is_empty() && self.pending_jobs.is_empty() {
                    break;
                }
                if drain_deadline.is_some_and(|d| Instant::now() >= d) {
                    // grace expired: stop waiting on peers that will not
                    // drain (the final cleanup below closes them)
                    self.pending_jobs.clear();
                    break;
                }
            }
        }
        // abnormal exit (poll failure): drop whatever is left, with the
        // close counters kept honest
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(c) = self.conns.remove(&t) {
                self.drop_conn(t, c);
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .poller
                        .register(stream.as_raw_fd(), event::READ, token)
                        .is_err()
                    {
                        continue; // fd table exhausted: shed the connection
                    }
                    self.metrics.record_conn_opened();
                    self.conns.insert(token, Conn::new(stream));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    // EMFILE/ENFILE etc.: the pending connection keeps the
                    // level-triggered listener readable, so without a pause
                    // this would spin the loop at 100% until an fd frees
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    fn handle_readable(&mut self, token: u64) {
        let mut conn = match self.conns.remove(&token) {
            Some(c) => c,
            None => return,
        };
        let mut buf = [0u8; 64 * 1024];
        loop {
            if conn.read_closed || conn.close_after_flush {
                break;
            }
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.read_closed = true;
                    conn.framer.push_eof();
                    self.parse_frames(&mut conn, token);
                    break;
                }
                Ok(n) => {
                    conn.framer.push(&buf[..n]);
                    self.parse_frames(&mut conn, token);
                    if conn.stalled(self.pipeline_depth) {
                        break; // backpressure: leave the rest in the kernel
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.drop_conn(token, conn);
                    return;
                }
            }
        }
        self.settle(token, conn);
    }

    /// Pull every complete frame out of the connection's [`Framer`] and
    /// answer it. The framer is taken out of the connection for the
    /// duration so frames are handled as zero-copy slices; a `Fatal`
    /// step (over-cap line/length, eof-truncated binary frame) is
    /// answered once and closes the connection after the flush.
    fn parse_frames(&mut self, conn: &mut Conn, token: u64) {
        let mut framer = std::mem::take(&mut conn.framer);
        while !conn.close_after_flush {
            match framer.next() {
                FramerStep::Pending => break,
                FramerStep::Fatal { wire, msg } => {
                    let seq = conn.take_seq();
                    conn.complete(
                        seq,
                        protocol::encode_error_frame(wire, None, &msg),
                        Vec::new(),
                    );
                    conn.close_after_flush = true;
                    conn.read_closed = true;
                }
                FramerStep::Frame { wire, payload } => {
                    self.metrics
                        .record_wire_in(wire == WireMode::Binary, 1, payload.len() as u64);
                    self.handle_frame(conn, token, wire, payload);
                }
            }
        }
        framer.compact();
        if !conn.counted_mode {
            if let Some(m) = framer.negotiated() {
                self.metrics.record_wire_conn(m == WireMode::Binary);
                conn.counted_mode = true;
            }
        }
        conn.framer = framer;
    }

    /// Answer one frame in its connection's wire format: transport ops
    /// inline, coordinator ops via the worker pool. Every frame gets a
    /// seq so responses flush in request order regardless of completion
    /// order. Payload decoding (UTF-8/empty rules + format dispatch) is
    /// the shared [`protocol::parse_frame_payload`] — one copy for both
    /// runtimes, like the framing itself.
    fn handle_frame(&mut self, conn: &mut Conn, token: u64, wire: WireMode, payload: &[u8]) {
        let seq = conn.take_seq();
        let mut span = Span::new(super::span_wire(wire), self.metrics.tracing_enabled());
        let parsed = protocol::parse_frame_payload(wire, payload);
        span.stamp(Stage::Decode);
        self.route(conn, token, seq, wire, parsed, span);
    }

    /// Shared request routing: transport ops answered inline, coordinator
    /// ops dispatched to the worker pool, parse failures answered with a
    /// correlated error envelope in the connection's wire mode.
    fn route(
        &mut self,
        conn: &mut Conn,
        token: u64,
        seq: u64,
        wire: WireMode,
        parsed: Result<protocol::Request, protocol::RequestError>,
        span: Span,
    ) {
        match parsed {
            Err(e) => {
                conn.complete(
                    seq,
                    protocol::encode_error_frame(wire, e.req_id, &format!("bad request: {e}")),
                    Vec::new(),
                );
            }
            Ok(protocol::Request { req_id, body }) => match body {
                protocol::RequestBody::Points => {
                    conn.complete(
                        seq,
                        protocol::encode_points_frame(wire, req_id, &self.points),
                        Vec::new(),
                    );
                }
                protocol::RequestBody::Shutdown => {
                    self.shutdown.store(true, Ordering::SeqCst);
                    conn.complete(
                        seq,
                        protocol::encode_shutting_down_frame(wire, req_id),
                        Vec::new(),
                    );
                }
                protocol::RequestBody::Op(op) => self.dispatch(Job {
                    token,
                    seq,
                    req_id,
                    payload: JobPayload::One(op),
                    wire,
                    span,
                }),
                protocol::RequestBody::Batch(items) => self.dispatch(Job {
                    token,
                    seq,
                    req_id,
                    payload: JobPayload::Batch(items),
                    wire,
                    span,
                }),
            },
        }
    }

    fn dispatch(&mut self, job: Job) {
        if !self.pending_jobs.is_empty() {
            self.pending_jobs.push_back(job); // keep global FIFO order
            return;
        }
        if let Err((Some(job), _)) = self.jobs.try_push(job) {
            self.pending_jobs.push_back(job);
        }
    }

    fn retry_pending_jobs(&mut self) {
        while let Some(job) = self.pending_jobs.pop_front() {
            if let Err((Some(job), _)) = self.jobs.try_push(job) {
                self.pending_jobs.push_front(job);
                break;
            }
        }
    }

    /// Route finished responses to their reorder buffers and flush every
    /// connection that may have output or a close decision pending.
    fn apply_completions(&mut self) {
        let done: Vec<Completion> = std::mem::take(&mut *self.completions.lock().unwrap());
        let mut touched: Vec<u64> = Vec::with_capacity(done.len());
        for c in done {
            if let Some(conn) = self.conns.get_mut(&c.token) {
                conn.complete(c.seq, c.frame, c.spans);
                touched.push(c.token);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for t in touched {
            self.finish_io(t);
        }
    }

    fn finish_io(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            self.settle(token, conn);
        }
    }

    /// Flush, decide close-vs-keep, and refresh poller interest.
    fn settle(&mut self, token: u64, mut conn: Conn) {
        let moved = conn.flush_ready(&self.metrics);
        if moved > 0 {
            self.metrics
                .record_wire_out(conn.framer.wire_mode() == WireMode::Binary, moved as u64);
        }
        if conn.try_write().is_err() {
            self.drop_conn(token, conn);
            return;
        }
        let drained = conn.in_flight() == 0 && !conn.has_pending_write();
        if drained && (conn.read_closed || conn.close_after_flush) {
            self.drop_conn(token, conn);
            return;
        }
        let stalled = conn.stalled(self.pipeline_depth);
        if stalled && !conn.was_stalled {
            self.metrics.record_backpressure_stall();
        }
        conn.was_stalled = stalled;
        let mut interest = 0u32;
        if !conn.read_closed && !conn.close_after_flush && !stalled {
            interest |= event::READ;
        }
        if conn.has_pending_write() {
            interest |= event::WRITE;
        }
        if interest != conn.interest {
            let _ = self
                .poller
                .modify(conn.stream.as_raw_fd(), interest, token);
            conn.interest = interest;
        }
        self.conns.insert(token, conn);
    }

    fn drop_conn(&mut self, _token: u64, conn: Conn) {
        let _ = self.poller.deregister(conn.stream.as_raw_fd());
        self.metrics.record_conn_closed();
        // conn (and its stream) drops here
    }

    /// Stop accepting and reading; connections close as they drain.
    fn begin_shutdown(&mut self) {
        let _ = self.poller.deregister(self.listener.as_raw_fd());
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(mut conn) = self.conns.remove(&t) {
                conn.read_closed = true;
                self.settle(t, conn);
            }
        }
    }
}
