//! The readiness-based serving mode (Linux): one epoll thread multiplexes
//! every connection, a fixed worker pool feeds the coordinator's dynamic
//! batcher, and per-connection reorder buffers keep wire responses in
//! request order even though batches complete out of order.
//!
//! ```text
//!                    ┌──────────────── epoll thread ───────────────┐
//! clients ── TCP ──▶ │ accept / read / incremental framing         │
//!                    │  (newline JSON, or FBIN1 length prefixes    │
//!                    │   when the first 5 bytes negotiate binary)  │
//!                    │   parse → Job{token, seq, req_id, op, wire} │
//!                    └──────────────┬──────────────────────────────┘
//!                                   │ BoundedQueue<Job>
//!                          io_workers threads: submit_async the whole
//!                          job batch → coordinator batcher → recv
//!                                   │ completions + eventfd wake
//!                    ┌──────────────▼──────────────────────────────┐
//!                    │ reorder by per-conn seq → write_buf → socket│
//!                    └─────────────────────────────────────────────┘
//! ```
//!
//! Each connection carries its own wire mode ([`protocol::negotiate`] on
//! its first bytes); completions are pre-encoded frames in that mode, so
//! JSON and binary connections interleave freely on one loop.
//!
//! Backpressure: a connection with `pipeline_depth` responses outstanding
//! (or an unflushed write buffer past the high-water mark) has its read
//! interest cleared until it drains; the stall is counted in
//! [`ServiceMetrics`]. The job queue is bounded too — overflow parks in a
//! FIFO spill list and retries each tick, so the epoll thread never
//! blocks.

use super::protocol::{self, WireMode};
use super::reactor::{event, Poller, Waker};
use crate::coordinator::{BoundedQueue, Coordinator, Op, Response, ServiceMetrics};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// How often the loop re-checks the shutdown flag when idle.
const TICK: Duration = Duration::from_millis(50);

/// Unflushed output past this mark pauses reads from that connection.
const WRITE_HIGH_WATER: usize = protocol::MAX_LINE_BYTES;

/// How long the shutdown drain waits for in-flight responses to flush
/// before force-closing whatever is left (a peer that never reads its
/// responses must not pin the server open).
const SHUTDOWN_GRACE: Duration = Duration::from_secs(10);

/// A parsed coordinator request in flight between the epoll thread and
/// the worker pool.
struct Job {
    token: u64,
    seq: u64,
    req_id: Option<u64>,
    op: Op,
    /// frame format of the connection that sent it (the response is
    /// encoded in the same format)
    wire: WireMode,
}

/// A finished response on its way back to the epoll thread, already
/// encoded as complete wire bytes for its connection's mode.
struct Completion {
    token: u64,
    seq: u64,
    frame: Vec<u8>,
}

/// Per-connection framing state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ConnMode {
    /// first bytes not yet seen: mode undecided
    Probe,
    /// newline-delimited JSON
    Json,
    /// FBIN1 length-prefixed binary
    Binary,
}

/// Handles owned by [`super::Server`] for the event-loop runtime.
pub(super) struct EventServer {
    loop_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    jobs: Arc<BoundedQueue<Job>>,
    waker: Arc<Waker>,
}

impl EventServer {
    /// Wake the loop (the caller has set the shutdown flag), wait for it
    /// to drain and exit, then stop the worker pool.
    pub(super) fn stop(&mut self) {
        self.waker.wake();
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Spawn the epoll thread + worker pool over an already-bound,
/// non-blocking listener.
pub(super) fn start(
    listener: TcpListener,
    io_workers: usize,
    pipeline_depth: usize,
    job_queue_depth: usize,
    svc: Arc<Coordinator>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<EventServer> {
    listener.set_nonblocking(true)?;
    let poller = Poller::new(1024)?;
    let waker = Arc::new(Waker::new()?);
    poller.register(listener.as_raw_fd(), event::READ, TOKEN_LISTENER)?;
    poller.register(waker.fd(), event::READ, TOKEN_WAKER)?;

    let jobs: Arc<BoundedQueue<Job>> = Arc::new(BoundedQueue::new(job_queue_depth.max(64)));
    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let metrics = svc.shared_metrics();

    let mut workers = Vec::new();
    for _ in 0..io_workers.max(1) {
        let jobs = jobs.clone();
        let svc = svc.clone();
        let completions = completions.clone();
        let waker = waker.clone();
        workers.push(std::thread::spawn(move || {
            worker_loop(&jobs, &svc, &completions, &waker);
        }));
    }

    let state = LoopState {
        poller,
        listener,
        waker: waker.clone(),
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        jobs: jobs.clone(),
        pending_jobs: VecDeque::new(),
        completions,
        metrics,
        points,
        shutdown,
        pipeline_depth: pipeline_depth.max(1),
    };
    let loop_thread = std::thread::spawn(move || state.run());

    Ok(EventServer {
        loop_thread: Some(loop_thread),
        workers,
        jobs,
        waker,
    })
}

/// Worker: drain a batch of jobs, push them *all* into the coordinator
/// (so wire concurrency turns into batch occupancy), then collect the
/// responses and hand them back to the epoll thread.
fn worker_loop(
    jobs: &BoundedQueue<Job>,
    svc: &Coordinator,
    completions: &Mutex<Vec<Completion>>,
    waker: &Waker,
) {
    while let Some(batch) = jobs.pop_batch(32, Duration::from_micros(200)) {
        let mut waits = Vec::with_capacity(batch.len());
        for job in batch {
            let Job {
                token,
                seq,
                req_id,
                op,
                wire,
            } = job;
            waits.push((token, seq, req_id, wire, svc.submit_async(op)));
        }
        let mut done = Vec::with_capacity(waits.len());
        for (token, seq, req_id, wire, rx) in waits {
            let resp = match rx {
                Ok(rx) => rx
                    .recv()
                    .unwrap_or_else(|_| Response::Error("worker dropped request".into())),
                Err(e) => Response::Error(e),
            };
            done.push(Completion {
                token,
                seq,
                // Signature responses serialize straight from the
                // coordinator's shared flat block here; the oversize
                // guard degrades an unframeable response to a correlated
                // error envelope instead of a dead connection
                frame: protocol::encode_response_frame(wire, req_id, &resp),
            });
        }
        completions.lock().unwrap().extend(done);
        waker.wake();
    }
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    /// negotiated frame format (Probe until the first bytes arrive)
    mode: ConnMode,
    /// bytes received but not yet framed
    read_buf: Vec<u8>,
    /// resume offset for the newline scan (avoid rescanning the prefix;
    /// JSON mode only)
    scan_from: usize,
    /// encoded responses awaiting the socket
    write_buf: Vec<u8>,
    /// first unwritten byte of `write_buf`
    write_from: usize,
    /// sequence number assigned to the next frame read
    next_seq: u64,
    /// sequence number of the next response to put on the wire
    next_write_seq: u64,
    /// out-of-order completions parked until their turn (pre-encoded
    /// frames in this connection's wire mode)
    completed: BTreeMap<u64, Vec<u8>>,
    /// EOF seen, or reads retired by shutdown
    read_closed: bool,
    /// fatal protocol error: close once all responses have flushed
    close_after_flush: bool,
    /// currently read-stalled (for backpressure accounting)
    was_stalled: bool,
    /// interest mask currently registered with the poller
    interest: u32,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            mode: ConnMode::Probe,
            read_buf: Vec::new(),
            scan_from: 0,
            write_buf: Vec::new(),
            write_from: 0,
            next_seq: 0,
            next_write_seq: 0,
            completed: BTreeMap::new(),
            read_closed: false,
            close_after_flush: false,
            was_stalled: false,
            interest: event::READ,
        }
    }

    /// Frames read but not yet answered on the wire.
    fn in_flight(&self) -> u64 {
        self.next_seq - self.next_write_seq
    }

    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    fn complete(&mut self, seq: u64, frame: Vec<u8>) {
        self.completed.insert(seq, frame);
    }

    /// Move in-order completions into the write buffer (frames carry
    /// their own terminator/prefix).
    fn flush_ready(&mut self) {
        while let Some(frame) = self.completed.remove(&self.next_write_seq) {
            self.write_buf.extend_from_slice(&frame);
            self.next_write_seq += 1;
        }
    }

    fn has_pending_write(&self) -> bool {
        self.write_from < self.write_buf.len()
    }

    /// Whether reads should pause until this connection drains.
    fn stalled(&self, pipeline_depth: usize) -> bool {
        self.in_flight() >= pipeline_depth as u64
            || self.write_buf.len() - self.write_from >= WRITE_HIGH_WATER
    }

    /// Push buffered output to the (non-blocking) socket.
    fn try_write(&mut self) -> std::io::Result<()> {
        while self.write_from < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_from..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => self.write_from += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.write_from == self.write_buf.len() {
            self.write_buf.clear();
            self.write_from = 0;
        }
        Ok(())
    }
}

struct LoopState {
    poller: Poller,
    listener: TcpListener,
    waker: Arc<Waker>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    jobs: Arc<BoundedQueue<Job>>,
    /// jobs that found the queue full; retried each tick in FIFO order
    pending_jobs: VecDeque<Job>,
    completions: Arc<Mutex<Vec<Completion>>>,
    metrics: Arc<ServiceMetrics>,
    points: Arc<Vec<f64>>,
    shutdown: Arc<AtomicBool>,
    pipeline_depth: usize,
}

impl LoopState {
    fn run(mut self) {
        let mut shutting_down = false;
        let mut drain_deadline: Option<Instant> = None;
        loop {
            let ready = match self.poller.wait(TICK) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("server event loop: poll failed: {e}");
                    break;
                }
            };
            if !ready.is_empty() {
                self.metrics.record_readiness_events(ready.len() as u64);
            }
            for r in ready {
                match r.token {
                    TOKEN_LISTENER => {
                        if !shutting_down {
                            self.accept_ready();
                        }
                    }
                    TOKEN_WAKER => self.waker.drain(),
                    token => {
                        if r.readable() {
                            self.handle_readable(token);
                        }
                        if r.writable() {
                            self.finish_io(token);
                        }
                    }
                }
            }
            self.retry_pending_jobs();
            self.apply_completions();
            if !shutting_down && self.shutdown.load(Ordering::SeqCst) {
                shutting_down = true;
                drain_deadline = Some(Instant::now() + SHUTDOWN_GRACE);
                self.begin_shutdown();
            }
            if shutting_down {
                if self.conns.is_empty() && self.pending_jobs.is_empty() {
                    break;
                }
                if drain_deadline.is_some_and(|d| Instant::now() >= d) {
                    // grace expired: stop waiting on peers that will not
                    // drain (the final cleanup below closes them)
                    self.pending_jobs.clear();
                    break;
                }
            }
        }
        // abnormal exit (poll failure): drop whatever is left, with the
        // close counters kept honest
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(c) = self.conns.remove(&t) {
                self.drop_conn(t, c);
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    if self
                        .poller
                        .register(stream.as_raw_fd(), event::READ, token)
                        .is_err()
                    {
                        continue; // fd table exhausted: shed the connection
                    }
                    self.metrics.record_conn_opened();
                    self.conns.insert(token, Conn::new(stream));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    // EMFILE/ENFILE etc.: the pending connection keeps the
                    // level-triggered listener readable, so without a pause
                    // this would spin the loop at 100% until an fd frees
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    fn handle_readable(&mut self, token: u64) {
        let mut conn = match self.conns.remove(&token) {
            Some(c) => c,
            None => return,
        };
        let mut buf = [0u8; 64 * 1024];
        loop {
            if conn.read_closed || conn.close_after_flush {
                break;
            }
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.read_closed = true;
                    self.eof_tail(&mut conn, token);
                    break;
                }
                Ok(n) => {
                    conn.read_buf.extend_from_slice(&buf[..n]);
                    self.parse_frames(&mut conn, token);
                    if conn.stalled(self.pipeline_depth) {
                        break; // backpressure: leave the rest in the kernel
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.drop_conn(token, conn);
                    return;
                }
            }
        }
        self.settle(token, conn);
    }

    /// EOF with unframed bytes still buffered. A JSON connection's final
    /// unterminated line is still a frame (clients may write-all then
    /// half-close); a binary connection's partial frame gets a typed
    /// error; an unfinished negotiation can only be JSON garbage.
    fn eof_tail(&mut self, conn: &mut Conn, token: u64) {
        if conn.read_buf.is_empty() {
            return;
        }
        let tail = std::mem::take(&mut conn.read_buf);
        conn.scan_from = 0;
        match conn.mode {
            ConnMode::Binary => {
                let seq = conn.take_seq();
                conn.complete(
                    seq,
                    protocol::encode_error_frame(
                        WireMode::Binary,
                        None,
                        "truncated binary frame before eof",
                    ),
                );
            }
            _ => self.handle_frame(conn, token, &tail),
        }
    }

    /// Split complete frames out of the read buffer according to the
    /// connection's (possibly just-negotiated) wire mode.
    fn parse_frames(&mut self, conn: &mut Conn, token: u64) {
        if conn.mode == ConnMode::Probe {
            match protocol::negotiate(&conn.read_buf) {
                protocol::Negotiation::NeedMore => return,
                protocol::Negotiation::Json => conn.mode = ConnMode::Json,
                protocol::Negotiation::Binary => {
                    conn.read_buf.drain(..protocol::BINARY_MAGIC.len());
                    conn.mode = ConnMode::Binary;
                }
            }
        }
        match conn.mode {
            ConnMode::Json => self.parse_json_frames(conn, token),
            ConnMode::Binary => self.parse_binary_frames(conn, token),
            ConnMode::Probe => unreachable!("negotiated above"),
        }
    }

    /// Split complete newline-terminated frames out of the read buffer.
    /// The buffer is taken out of the connection for the duration, so
    /// frames are handled as zero-copy slices and the consumed prefix is
    /// drained once per call (not once per frame).
    fn parse_json_frames(&mut self, conn: &mut Conn, token: u64) {
        let buf = std::mem::take(&mut conn.read_buf);
        let mut start = 0usize;
        let mut scan = conn.scan_from;
        while !conn.close_after_flush {
            match buf[scan..].iter().position(|&b| b == b'\n') {
                Some(rel) => {
                    let end = scan + rel;
                    let mut line = &buf[start..end];
                    if line.last() == Some(&b'\r') {
                        line = &line[..line.len() - 1];
                    }
                    self.handle_frame(conn, token, line);
                    start = end + 1;
                    scan = start;
                }
                None => {
                    scan = buf.len();
                    break;
                }
            }
        }
        // put the buffer back and drop the consumed prefix in one move;
        // everything kept has already been scanned for newlines
        conn.read_buf = buf;
        if start > 0 {
            conn.read_buf.drain(..start);
        }
        conn.scan_from = conn.read_buf.len();
        if !conn.close_after_flush && conn.read_buf.len() > protocol::MAX_LINE_BYTES {
            let seq = conn.take_seq();
            conn.complete(
                seq,
                protocol::encode_error_frame(WireMode::Json, None, "request line too long"),
            );
            conn.close_after_flush = true;
            conn.read_closed = true;
        }
    }

    /// Split complete length-prefixed frames out of the read buffer. An
    /// oversized declared length is answered once and closes the
    /// connection after the flush — binary framing cannot resync past it.
    fn parse_binary_frames(&mut self, conn: &mut Conn, token: u64) {
        let buf = std::mem::take(&mut conn.read_buf);
        let mut start = 0usize;
        while !conn.close_after_flush {
            match protocol::split_binary_frame(&buf[start..]) {
                Ok(None) => break,
                Ok(Some(consumed)) => {
                    self.handle_binary_frame(conn, token, &buf[start + 4..start + consumed]);
                    start += consumed;
                }
                Err(msg) => {
                    let seq = conn.take_seq();
                    conn.complete(
                        seq,
                        protocol::encode_error_frame(WireMode::Binary, None, &msg),
                    );
                    conn.close_after_flush = true;
                    conn.read_closed = true;
                }
            }
        }
        conn.read_buf = buf;
        if start > 0 {
            conn.read_buf.drain(..start);
        }
    }

    /// Answer one JSON frame: transport ops inline, coordinator ops via
    /// the worker pool. Every frame gets a seq so responses flush in
    /// request order regardless of completion order.
    fn handle_frame(&mut self, conn: &mut Conn, token: u64, bytes: &[u8]) {
        let seq = conn.take_seq();
        if bytes.len() > protocol::MAX_LINE_BYTES {
            conn.complete(
                seq,
                protocol::encode_error_frame(WireMode::Json, None, "request line too long"),
            );
            conn.close_after_flush = true;
            conn.read_closed = true;
            return;
        }
        let line = match std::str::from_utf8(bytes) {
            Ok(s) => s,
            Err(_) => {
                conn.complete(
                    seq,
                    protocol::encode_error_frame(
                        WireMode::Json,
                        None,
                        "bad request: invalid utf-8",
                    ),
                );
                return;
            }
        };
        if line.trim().is_empty() {
            conn.complete(
                seq,
                protocol::encode_error_frame(WireMode::Json, None, "empty request"),
            );
            return;
        }
        self.route(conn, token, seq, WireMode::Json, protocol::parse_request(line));
    }

    /// Answer one binary frame payload (the bytes after the length
    /// prefix).
    fn handle_binary_frame(&mut self, conn: &mut Conn, token: u64, payload: &[u8]) {
        let seq = conn.take_seq();
        self.route(
            conn,
            token,
            seq,
            WireMode::Binary,
            protocol::parse_request_binary(payload),
        );
    }

    /// Shared request routing: transport ops answered inline, coordinator
    /// ops dispatched to the worker pool, parse failures answered with a
    /// correlated error envelope in the connection's wire mode.
    fn route(
        &mut self,
        conn: &mut Conn,
        token: u64,
        seq: u64,
        wire: WireMode,
        parsed: Result<protocol::Request, protocol::RequestError>,
    ) {
        match parsed {
            Err(e) => {
                conn.complete(
                    seq,
                    protocol::encode_error_frame(wire, e.req_id, &format!("bad request: {e}")),
                );
            }
            Ok(protocol::Request { req_id, body }) => match body {
                protocol::RequestBody::Points => {
                    conn.complete(seq, protocol::encode_points_frame(wire, req_id, &self.points));
                }
                protocol::RequestBody::Shutdown => {
                    self.shutdown.store(true, Ordering::SeqCst);
                    conn.complete(seq, protocol::encode_shutting_down_frame(wire, req_id));
                }
                protocol::RequestBody::Op(op) => self.dispatch(Job {
                    token,
                    seq,
                    req_id,
                    op,
                    wire,
                }),
            },
        }
    }

    fn dispatch(&mut self, job: Job) {
        if !self.pending_jobs.is_empty() {
            self.pending_jobs.push_back(job); // keep global FIFO order
            return;
        }
        if let Err((Some(job), _)) = self.jobs.try_push(job) {
            self.pending_jobs.push_back(job);
        }
    }

    fn retry_pending_jobs(&mut self) {
        while let Some(job) = self.pending_jobs.pop_front() {
            if let Err((Some(job), _)) = self.jobs.try_push(job) {
                self.pending_jobs.push_front(job);
                break;
            }
        }
    }

    /// Route finished responses to their reorder buffers and flush every
    /// connection that may have output or a close decision pending.
    fn apply_completions(&mut self) {
        let done: Vec<Completion> = std::mem::take(&mut *self.completions.lock().unwrap());
        let mut touched: Vec<u64> = Vec::with_capacity(done.len());
        for c in done {
            if let Some(conn) = self.conns.get_mut(&c.token) {
                conn.complete(c.seq, c.frame);
                touched.push(c.token);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for t in touched {
            self.finish_io(t);
        }
    }

    fn finish_io(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            self.settle(token, conn);
        }
    }

    /// Flush, decide close-vs-keep, and refresh poller interest.
    fn settle(&mut self, token: u64, mut conn: Conn) {
        conn.flush_ready();
        if conn.try_write().is_err() {
            self.drop_conn(token, conn);
            return;
        }
        let drained = conn.in_flight() == 0 && !conn.has_pending_write();
        if drained && (conn.read_closed || conn.close_after_flush) {
            self.drop_conn(token, conn);
            return;
        }
        let stalled = conn.stalled(self.pipeline_depth);
        if stalled && !conn.was_stalled {
            self.metrics.record_backpressure_stall();
        }
        conn.was_stalled = stalled;
        let mut interest = 0u32;
        if !conn.read_closed && !conn.close_after_flush && !stalled {
            interest |= event::READ;
        }
        if conn.has_pending_write() {
            interest |= event::WRITE;
        }
        if interest != conn.interest {
            let _ = self
                .poller
                .modify(conn.stream.as_raw_fd(), interest, token);
            conn.interest = interest;
        }
        self.conns.insert(token, conn);
    }

    fn drop_conn(&mut self, _token: u64, conn: Conn) {
        let _ = self.poller.deregister(conn.stream.as_raw_fd());
        self.metrics.record_conn_closed();
        // conn (and its stream) drops here
    }

    /// Stop accepting and reading; connections close as they drain.
    fn begin_shutdown(&mut self) {
        let _ = self.poller.deregister(self.listener.as_raw_fd());
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(mut conn) = self.conns.remove(&t) {
                conn.read_closed = true;
                self.settle(t, conn);
            }
        }
    }
}
