//! A minimal epoll reactor (Linux only) — the readiness layer under the
//! event-loop server.
//!
//! The offline vendor set has no `libc`/`mio`, so this module declares
//! the handful of C symbols it needs directly (they resolve against the
//! libc every Rust binary on Linux already links) and wraps them in a
//! safe, purpose-built API:
//!
//! * [`Poller`] — `epoll_create1` / `epoll_ctl` / `epoll_wait` with
//!   per-fd `u64` tokens and level-triggered interest masks,
//! * [`Waker`] — an `eventfd` registered in the poller so worker threads
//!   can interrupt `epoll_wait` from outside the loop,
//! * [`raise_nofile_limit`] — lift `RLIMIT_NOFILE`'s soft limit to the
//!   hard limit, so one process can hold thousands of sockets (the whole
//!   point of readiness-based I/O).

#![allow(clippy::upper_case_acronyms)]

use std::io;
use std::os::fd::RawFd;
use std::os::raw::{c_int, c_uint, c_void};
use std::time::Duration;

// ---------------------------------------------------------------- ffi

#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout_ms: c_int,
    ) -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    // the 64-bit variants exist on every glibc/musl target, so Rlimit's
    // u64 fields match the ABI even on 32-bit Linux
    fn getrlimit64(resource: c_int, rlim: *mut Rlimit) -> c_int;
    fn setrlimit64(resource: c_int, rlim: *const Rlimit) -> c_int;
}

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

const RLIMIT_NOFILE: c_int = 7;

#[repr(C)]
struct Rlimit {
    rlim_cur: u64,
    rlim_max: u64,
}

/// Readiness interest / readiness result bits (subset of `EPOLL*`).
pub mod event {
    /// fd is readable (`EPOLLIN`)
    pub const READ: u32 = 0x001;
    /// fd is writable (`EPOLLOUT`)
    pub const WRITE: u32 = 0x004;
    /// error condition (`EPOLLERR`) — always reported, never requested
    pub const ERROR: u32 = 0x008;
    /// peer hung up (`EPOLLHUP`) — always reported, never requested
    pub const HANGUP: u32 = 0x010;
    /// peer closed its write side (`EPOLLRDHUP`)
    pub const READ_HANGUP: u32 = 0x2000;
}

/// One readiness notification: which registration fired, and how.
#[derive(Debug, Clone, Copy)]
pub struct Readiness {
    /// the token the fd was registered with
    pub token: u64,
    /// bitmask of [`event`] flags
    pub events: u32,
}

impl Readiness {
    /// Readable (or peer half-closed — a read will observe the EOF).
    pub fn readable(&self) -> bool {
        self.events & (event::READ | event::READ_HANGUP | event::HANGUP | event::ERROR) != 0
    }

    /// Writable.
    pub fn writable(&self) -> bool {
        self.events & (event::WRITE | event::HANGUP | event::ERROR) != 0
    }
}

// ---------------------------------------------------------------- poller

/// Level-triggered epoll instance. Registrations carry a caller-chosen
/// `u64` token that comes back in each [`Readiness`].
pub struct Poller {
    epfd: RawFd,
    buf: Vec<EpollEvent>,
}

impl Poller {
    /// Create an epoll instance able to report up to `capacity` events
    /// per [`Poller::wait`] call.
    pub fn new(capacity: usize) -> io::Result<Self> {
        // SAFETY: epoll_create1 takes no pointers; any flag value is
        // safe to pass and errors come back as -1/errno.
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self {
            epfd,
            buf: vec![EpollEvent { events: 0, data: 0 }; capacity.max(16)],
        })
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent {
            events: interest,
            data: token,
        };
        let arg = if op == EPOLL_CTL_DEL {
            std::ptr::null_mut()
        } else {
            &mut ev as *mut EpollEvent
        };
        // SAFETY: `arg` is either null (DEL, where the kernel ignores
        // it) or a valid pointer to the stack-owned `ev`, which outlives
        // the call; the kernel copies the struct before returning.
        if unsafe { epoll_ctl(self.epfd, op, fd, arg) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` with the given interest mask ([`event`] bits).
    pub fn register(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask of a registered fd.
    pub fn modify(&self, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Remove a registration (safe to call on an already-closed fd's
    /// former number only before reuse — callers deregister first).
    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block until at least one registration is ready or `timeout`
    /// elapses; returns the readiness set (possibly empty on timeout).
    pub fn wait(&mut self, timeout: Duration) -> io::Result<Vec<Readiness>> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as c_int;
        // SAFETY: `buf` is a live Vec whose length is passed as
        // `maxevents`, so the kernel writes at most `buf.len()` entries
        // into memory we own; `&mut self` keeps the buffer exclusive.
        let n =
            unsafe { epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(Vec::new());
            }
            return Err(e);
        }
        Ok(self.buf[..n as usize]
            .iter()
            .map(|ev| Readiness {
                // copy out of the (possibly packed) ffi struct field by
                // field; no references into it escape
                token: { ev.data },
                events: { ev.events },
            })
            .collect())
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: `epfd` was returned by epoll_create1, is owned solely
        // by this Poller, and is closed exactly once (Drop runs once).
        unsafe { close(self.epfd) };
    }
}

// ---------------------------------------------------------------- waker

/// Cross-thread wakeup for a [`Poller`]: an `eventfd` the loop registers
/// for readability. Cloneable/shareable by `&` — `write(2)` is atomic.
pub struct Waker {
    fd: RawFd,
}

impl Waker {
    /// Create the eventfd (non-blocking: a full counter never blocks the
    /// waking thread).
    pub fn new() -> io::Result<Self> {
        // SAFETY: eventfd takes no pointers; errors come back as
        // -1/errno and are checked below.
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { fd })
    }

    /// The fd to register in the poller (read interest).
    pub fn fd(&self) -> RawFd {
        self.fd
    }

    /// Wake the poller. Coalesces: many wakes before a drain count once.
    pub fn wake(&self) {
        let one: u64 = 1;
        // EAGAIN (counter at max) still leaves the fd readable — ignore
        // SAFETY: the pointer is to the local `one`, valid for the 8
        // bytes the call is told to read; the fd is owned by self.
        let _ = unsafe { write(self.fd, &one as *const u64 as *const c_void, 8) };
    }

    /// Drain the counter after the poller reported the fd readable.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        // SAFETY: the pointer is to the local `buf`, writable for the 8
        // bytes the call is told to fill; the fd is owned by self.
        let _ = unsafe { read(self.fd, &mut buf as *mut u64 as *mut c_void, 8) };
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: `fd` was returned by eventfd, is owned solely by this
        // Waker, and is closed exactly once (Drop runs once).
        unsafe { close(self.fd) };
    }
}

// ---------------------------------------------------------------- rlimit

/// Raise the soft `RLIMIT_NOFILE` to the hard limit (the event loop's
/// reason to exist is holding thousands of sockets; the traditional soft
/// default of 1024 would cap it). Returns the resulting soft limit.
pub fn raise_nofile_limit() -> io::Result<u64> {
    let mut rl = Rlimit {
        rlim_cur: 0,
        rlim_max: 0,
    };
    // SAFETY: the pointer is to the local `rl`, matching the 64-bit
    // Rlimit ABI the *rlimit64 symbols are defined against.
    if unsafe { getrlimit64(RLIMIT_NOFILE, &mut rl) } < 0 {
        return Err(io::Error::last_os_error());
    }
    if rl.rlim_cur < rl.rlim_max {
        let want = Rlimit {
            rlim_cur: rl.rlim_max,
            rlim_max: rl.rlim_max,
        };
        // SAFETY: the pointer is to the local `want`, fully initialized
        // above; the kernel only reads through it.
        if unsafe { setrlimit64(RLIMIT_NOFILE, &want) } < 0 {
            return Err(io::Error::last_os_error());
        }
        return Ok(rl.rlim_max);
    }
    Ok(rl.rlim_cur)
}

// Miri cannot emulate epoll/eventfd syscalls, so the whole suite is
// host-only; the nightly sanitizer jobs cover it instead.
#[cfg(all(test, not(miri)))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn poller_reports_readable_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = Poller::new(8).unwrap();
        poller
            .register(listener.as_raw_fd(), event::READ, 7)
            .unwrap();
        // nothing pending: times out empty
        let quiet = poller.wait(Duration::from_millis(10)).unwrap();
        assert!(quiet.iter().all(|r| r.token != 7));
        // connect → listener becomes readable with our token
        let mut client = TcpStream::connect(addr).unwrap();
        let ready = poller.wait(Duration::from_secs(5)).unwrap();
        assert!(ready.iter().any(|r| r.token == 7 && r.readable()));
        let (mut accepted, _) = listener.accept().unwrap();
        // a connected socket with empty send buffer is writable
        poller
            .register(accepted.as_raw_fd(), event::WRITE, 9)
            .unwrap();
        let ready = poller.wait(Duration::from_secs(5)).unwrap();
        assert!(ready.iter().any(|r| r.token == 9 && r.writable()));
        // swap interest to read; peer data wakes us
        poller
            .modify(accepted.as_raw_fd(), event::READ, 9)
            .unwrap();
        client.write_all(b"x").unwrap();
        let ready = poller.wait(Duration::from_secs(5)).unwrap();
        assert!(ready.iter().any(|r| r.token == 9 && r.readable()));
        poller.deregister(accepted.as_raw_fd()).unwrap();
        let _ = accepted.write_all(b"y");
    }

    #[test]
    fn waker_crosses_threads() {
        let mut poller = Poller::new(4).unwrap();
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        poller.register(waker.fd(), event::READ, 1).unwrap();
        let w2 = waker.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            w2.wake();
            w2.wake(); // coalesces
        });
        let ready = poller.wait(Duration::from_secs(5)).unwrap();
        assert!(ready.iter().any(|r| r.token == 1 && r.readable()));
        waker.drain();
        // drained: next wait times out
        let quiet = poller.wait(Duration::from_millis(10)).unwrap();
        assert!(quiet.is_empty());
        h.join().unwrap();
    }

    #[test]
    fn nofile_limit_can_be_raised() {
        let soft = raise_nofile_limit().unwrap();
        assert!(soft >= 1024);
    }
}
