//! Wire-format encode/decode for both frame formats the server speaks on
//! one port (see the [`crate::server`] module doc for the full frame
//! reference):
//!
//! * **newline-delimited JSON** — one UTF-8 JSON object per line; the
//!   original format, kept as the default and the debugging-friendly
//!   option (`nc` works).
//! * **`FBIN1` length-prefixed binary** — negotiated by a connection
//!   whose first five bytes are [`BINARY_MAGIC`]; every subsequent frame
//!   in *both* directions is a little-endian `u32` payload length
//!   followed by the payload. Sample rows travel as raw `f32` bits and
//!   ids as native `u64`s, so bulk rows cost 4 bytes/sample instead of
//!   ~9–13 bytes of decimal text, and the JSON carrier's 2^53 id
//!   precision limit does not apply.
//!
//! Both directions are symmetric: the server uses [`parse_request`] /
//! [`parse_request_binary`] + the `encode_*_frame` response builders; the
//! client uses the `encode_*_frame` request builders + [`decode_reply`] /
//! [`decode_reply_binary`]. JSON round-trips through [`crate::json`]; the
//! binary codec is hand-rolled little-endian — no external serialization
//! crates in either path.
//!
//! Sample values are validated at the wire: a non-finite sample — or a
//! JSON number that overflows `f32` to `±inf` — is rejected with a
//! per-request error envelope before it can poison the index or the
//! re-rank distances. Batched ops (`hash_batch` / `insert_batch` /
//! `query_batch`) validate per row: one bad row fails that row's entry
//! in the batch envelope, not the frame.
//!
//! Framing itself — wire-mode negotiation, the newline scan, the
//! length-prefix split, and the 8 MiB caps — lives in **one** place:
//! the incremental [`Framer`]. Both server runtimes (the threaded
//! `serve_stream` loop and the epoll event loop) push raw socket bytes
//! into it and pull complete frames out, so the two formats can never
//! drift between runtimes; clients read reply frames one at a time with
//! [`read_frame`].

use crate::coordinator::{EntryRecord, Op, Response, StatsDetail};
use crate::json::{self, object, Value};
use crate::search::Hit;

/// Hard cap on one request/response frame (the JSON line without its
/// newline, or the binary payload without its length prefix); longer
/// frames are a protocol error (protects both sides from unbounded
/// buffering).
///
/// Note on integer width: in the JSON format ids and `req_id`s travel as
/// JSON numbers, which this crate's [`crate::json`] (like most JSON
/// stacks) carries as `f64` — values ≥ 2^53 lose precision on the wire
/// and `Value::as_u64` rejects them server-side. The binary format
/// carries ids as native little-endian `u64`s and has no such limit.
pub const MAX_LINE_BYTES: usize = 8 << 20;

/// Alias of [`MAX_LINE_BYTES`] for the binary framing (one cap, two
/// formats).
pub const MAX_FRAME_BYTES: usize = MAX_LINE_BYTES;

/// First bytes of a binary-mode connection. A connection that opens with
/// anything else speaks newline-delimited JSON.
pub const BINARY_MAGIC: &[u8; 5] = b"FBIN1";

/// Wire length of [`BINARY_MAGIC`] — what metrics charge for the
/// one-time handshake. Callers outside this module use this (and
/// [`write_magic`]) rather than touching the magic bytes themselves,
/// keeping every byte-level framing detail localized here (the
/// `frame-localization` rule in [`crate::analysis`] enforces it).
pub const MAGIC_LEN: usize = BINARY_MAGIC.len();

/// Open a binary-mode stream: write the `FBIN1` magic. The only way
/// code outside this module puts magic bytes on a wire.
pub fn write_magic<W: std::io::Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(BINARY_MAGIC)
}

/// Which frame format a connection (or client) speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireMode {
    /// newline-delimited JSON (the default)
    Json,
    /// `FBIN1` length-prefixed binary
    Binary,
}

impl WireMode {
    /// The CLI/config spelling (inverse of [`WireMode::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            WireMode::Json => "json",
            WireMode::Binary => "binary",
        }
    }

    /// Parse the CLI spelling (`funclsh load --wire …` goes through
    /// here).
    pub fn parse(s: &str) -> Option<WireMode> {
        match s {
            "json" | "jsonl" => Some(WireMode::Json),
            "binary" | "bin" | "fbin1" => Some(WireMode::Binary),
            _ => None,
        }
    }
}

/// Outcome of sniffing the first bytes of a connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Negotiation {
    /// the bytes so far are a proper prefix of [`BINARY_MAGIC`]; read
    /// more before deciding
    NeedMore,
    /// JSON mode — no bytes consumed
    Json,
    /// binary mode — the caller must consume the 5 magic bytes
    Binary,
}

/// Decide a connection's wire mode from its first buffered bytes. Any
/// first byte that cannot begin [`BINARY_MAGIC`] selects JSON (a valid
/// JSON frame starts with `{` or whitespace, so garbage that *almost*
/// spells the magic falls through to the JSON parser's error envelope).
pub fn negotiate(first: &[u8]) -> Negotiation {
    let n = first.len().min(BINARY_MAGIC.len());
    if first[..n] != BINARY_MAGIC[..n] {
        return Negotiation::Json;
    }
    if first.len() >= BINARY_MAGIC.len() {
        Negotiation::Binary
    } else {
        Negotiation::NeedMore
    }
}

/// Try to split one binary frame off the front of `buf`: `Ok(None)`
/// means more bytes are needed; `Ok(Some(consumed))` means one complete
/// frame occupies `buf[..consumed]` with its payload at
/// `buf[4..consumed]`. An oversized declared length is an `Err` — the
/// framing cannot resync past it, so the connection must close (after
/// answering with the error).
pub fn split_binary_frame(buf: &[u8]) -> Result<Option<usize>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(format!(
            "binary frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap"
        ));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(4 + len))
}

// ------------------------------------------------- incremental framing

/// What [`Framer::next`] produced.
#[derive(Debug, PartialEq, Eq)]
pub enum FramerStep<'a> {
    /// One complete frame payload: a JSON line without its newline (and
    /// without a trailing `\r`), or a binary payload without its length
    /// prefix. `wire` is the connection's negotiated format.
    Frame {
        /// the connection's negotiated wire format
        wire: WireMode,
        /// the frame payload (borrows the framer's buffer; consumed)
        payload: &'a [u8],
    },
    /// An unrecoverable framing error (over-cap line, over-cap declared
    /// binary length, or a binary frame truncated by EOF). The caller
    /// must answer once with an error envelope in `wire`'s format and
    /// close after flushing; the framer yields nothing further.
    Fatal {
        /// format to encode the final error envelope in
        wire: WireMode,
        /// what broke the framing
        msg: String,
    },
    /// No complete frame buffered; push more bytes (or, after
    /// [`Framer::push_eof`], the stream is fully drained).
    Pending,
}

/// The single incremental framer both server runtimes consume: push raw
/// socket bytes in, pull complete frames out.
///
/// Owns the whole per-connection framing state machine — wire-mode
/// negotiation (`Probe` → JSON/binary on the first bytes), the resumable
/// newline scan, the binary length-prefix split, and the
/// [`MAX_FRAME_BYTES`] caps — so exactly one copy of these rules exists.
///
/// Contract:
///
/// * [`Framer::push`] appends bytes; [`Framer::push_eof`] marks the end
///   of the stream (a final unterminated JSON line is still a frame; a
///   binary frame cut off by EOF is a [`FramerStep::Fatal`]).
/// * [`Framer::next`] yields each complete frame exactly once, in order,
///   independent of how the bytes were chunked across `push` calls —
///   byte-at-a-time and whole-buffer feeding decode identically (see
///   `tests/framer_properties.rs`).
/// * After a `Fatal` the framer is poisoned: `next` returns `Pending`
///   forever (the framing cannot resync past the error).
/// * [`Framer::compact`] drops the consumed prefix; call it once per
///   read burst, not per frame, so a pipelined burst is memmoved once.
#[derive(Debug, Default)]
pub struct Framer {
    buf: Vec<u8>,
    /// first byte not yet consumed by a returned frame
    start: usize,
    /// resume offset of the newline scan (JSON mode; never rescans)
    scan_from: usize,
    /// negotiated mode (`None` until the first bytes decide)
    mode: Option<WireMode>,
    fatal: bool,
    eof: bool,
}

impl Framer {
    /// Fresh framer in the probe (pre-negotiation) state.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw stream bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Mark end-of-stream: unlocks the EOF tail rules (an unterminated
    /// JSON line becomes a frame; a partial binary frame becomes fatal).
    pub fn push_eof(&mut self) {
        self.eof = true;
    }

    /// The negotiated wire mode, once the first bytes have decided it.
    pub fn negotiated(&self) -> Option<WireMode> {
        self.mode
    }

    /// The format to encode responses in: the negotiated mode, or JSON
    /// while still probing (an unfinished negotiation can only be JSON
    /// garbage — a proper prefix of the magic never completes a frame).
    pub fn wire_mode(&self) -> WireMode {
        self.mode.unwrap_or(WireMode::Json)
    }

    /// Whether a [`FramerStep::Fatal`] has been emitted.
    pub fn is_fatal(&self) -> bool {
        self.fatal
    }

    /// Bytes buffered but not yet consumed by a returned frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Drop the consumed prefix in one move. Call once per read burst.
    pub fn compact(&mut self) {
        if self.start > 0 {
            self.buf.drain(..self.start);
            // scan_from only tracks the JSON newline scan; on a binary
            // connection it lags at the negotiation offset while frames
            // advance `start` past it, so clamp instead of subtracting
            // blindly (a bare subtraction underflows in debug builds)
            self.scan_from = self.scan_from.max(self.start) - self.start;
            self.start = 0;
        }
    }

    /// Pull the next complete frame (or fatal framing error) out of the
    /// buffered bytes.
    pub fn next(&mut self) -> FramerStep<'_> {
        if self.fatal {
            return FramerStep::Pending;
        }
        if self.mode.is_none() {
            match negotiate(&self.buf[self.start..]) {
                Negotiation::NeedMore if !self.eof => return FramerStep::Pending,
                // an unfinished negotiation at EOF can only be JSON
                // garbage — fall through to the JSON tail handling
                Negotiation::NeedMore | Negotiation::Json => self.mode = Some(WireMode::Json),
                Negotiation::Binary => {
                    self.start += BINARY_MAGIC.len();
                    self.mode = Some(WireMode::Binary);
                }
            }
            self.scan_from = self.start;
        }
        match self.mode.expect("negotiated above") {
            WireMode::Json => self.next_json(),
            WireMode::Binary => self.next_binary(),
        }
    }

    fn fatal_step(&mut self, wire: WireMode, msg: String) -> FramerStep<'_> {
        self.fatal = true;
        FramerStep::Fatal { wire, msg }
    }

    fn next_json(&mut self) -> FramerStep<'_> {
        if let Some(rel) = self.buf[self.scan_from..].iter().position(|&b| b == b'\n') {
            let end = self.scan_from + rel;
            let line_start = self.start;
            let mut line_end = end;
            if line_end > line_start && self.buf[line_end - 1] == b'\r' {
                line_end -= 1;
            }
            if line_end - line_start > MAX_LINE_BYTES {
                return self.fatal_step(WireMode::Json, "request line too long".into());
            }
            self.start = end + 1;
            self.scan_from = self.start;
            return FramerStep::Frame {
                wire: WireMode::Json,
                payload: &self.buf[line_start..line_end],
            };
        }
        self.scan_from = self.buf.len();
        if self.buf.len() - self.start > MAX_LINE_BYTES {
            // a frame that drips past the cap without its newline can
            // never be served
            return self.fatal_step(WireMode::Json, "request line too long".into());
        }
        if self.eof && self.start < self.buf.len() {
            // a final unterminated line is still a frame (clients may
            // write-all then half-close)
            let line_start = self.start;
            self.start = self.buf.len();
            return FramerStep::Frame {
                wire: WireMode::Json,
                payload: &self.buf[line_start..],
            };
        }
        FramerStep::Pending
    }

    fn next_binary(&mut self) -> FramerStep<'_> {
        match split_binary_frame(&self.buf[self.start..]) {
            // oversized declared length: the framing cannot resync
            Err(msg) => self.fatal_step(WireMode::Binary, msg),
            Ok(Some(consumed)) => {
                let payload_start = self.start + 4;
                let payload_end = self.start + consumed;
                self.start = payload_end;
                FramerStep::Frame {
                    wire: WireMode::Binary,
                    payload: &self.buf[payload_start..payload_end],
                }
            }
            Ok(None) => {
                if self.eof && self.start < self.buf.len() {
                    return self.fatal_step(
                        WireMode::Binary,
                        "truncated binary frame before eof".into(),
                    );
                }
                FramerStep::Pending
            }
        }
    }
}

/// Blocking-read one reply frame payload off a buffered stream in
/// `wire`'s format — the client-side mirror of the server's [`Framer`]
/// (clients read exactly one frame per outstanding request, so the
/// push-based machine is unnecessary there). `Ok(None)` is EOF before a
/// frame; an over-cap line/length is an `InvalidData` error. JSON
/// payloads include the terminating newline (the decoder trims).
pub fn read_frame<R: std::io::BufRead>(
    reader: &mut R,
    wire: WireMode,
) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::{BufRead, ErrorKind, Read};
    match wire {
        WireMode::Json => {
            // cap the reply line like the binary path caps its frames: a
            // buggy/hostile peer streaming bytes without a newline must
            // not grow this buffer without bound. The cap applies to the
            // payload (the line without its newline) — a maximum-size
            // reply the server is allowed to send must not be rejected
            // here — so the take window is payload cap + newline + one
            // over-cap sentinel byte
            let mut line = String::new();
            let n = (&mut *reader)
                .take((MAX_FRAME_BYTES + 2) as u64)
                .read_line(&mut line)?;
            if n == 0 {
                return Ok(None);
            }
            let payload_len = line.len() - usize::from(line.ends_with('\n'));
            if payload_len > MAX_FRAME_BYTES {
                return Err(std::io::Error::new(
                    ErrorKind::InvalidData,
                    format!("reply line exceeds the {MAX_FRAME_BYTES}-byte cap"),
                ));
            }
            Ok(Some(line.into_bytes()))
        }
        WireMode::Binary => {
            let mut len4 = [0u8; 4];
            match reader.read_exact(&mut len4) {
                Ok(()) => {}
                Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
                Err(e) => return Err(e),
            }
            let len = u32::from_le_bytes(len4) as usize;
            if len > MAX_FRAME_BYTES {
                return Err(std::io::Error::new(
                    ErrorKind::InvalidData,
                    format!("reply frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap"),
                ));
            }
            let mut payload = vec![0u8; len];
            reader.read_exact(&mut payload)?;
            Ok(Some(payload))
        }
    }
}

// binary request op tags
const OP_HASH: u8 = 1;
const OP_INSERT: u8 = 2;
const OP_QUERY: u8 = 3;
const OP_REMOVE: u8 = 4;
const OP_METRICS: u8 = 5;
const OP_SNAPSHOT: u8 = 6;
const OP_PING: u8 = 7;
const OP_POINTS: u8 = 8;
const OP_SHUTDOWN: u8 = 9;
const OP_HASH_BATCH: u8 = 10;
const OP_INSERT_BATCH: u8 = 11;
const OP_QUERY_BATCH: u8 = 12;
const OP_STATS: u8 = 13;
// inter-node ops (shard-to-shard / router-to-shard migration plumbing)
const OP_MIGRATE_PULL: u8 = 14;
const OP_ENTRIES_PUSH: u8 = 15;
const OP_ENTRIES_DISCARD: u8 = 16;

// binary reply type tags
const REPLY_SIGNATURE: u8 = 1;
const REPLY_INSERTED: u8 = 2;
const REPLY_HITS: u8 = 3;
const REPLY_REMOVED: u8 = 4;
const REPLY_METRICS: u8 = 5;
const REPLY_SNAPSHOT: u8 = 6;
const REPLY_PONG: u8 = 7;
const REPLY_POINTS: u8 = 8;
const REPLY_SHUTTING_DOWN: u8 = 9;
const REPLY_BATCH: u8 = 10;
const REPLY_STATS: u8 = 11;
const REPLY_BATCH_PART: u8 = 12;
/// top-level-only wrapper: `missing` shard ranges + one inner reply —
/// handled in [`decode_reply_binary`] (never inside a batch or another
/// degraded wrapper, so hostile nesting cannot recurse the decoder)
const REPLY_DEGRADED: u8 = 13;
const REPLY_ENTRIES: u8 = 14;
const REPLY_INGESTED: u8 = 15;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// Trailing machine-readable code byte of an `overloaded` binary error
/// envelope, appended after the length-prefixed message. Absent on every
/// other error — and older decoders stop at the message — so the byte is
/// purely additive.
const ERR_CODE_OVERLOADED: u8 = 1;

/// Trailing code byte of a `degraded` binary error envelope (a cluster
/// request that failed entirely because its owning shard is down past
/// the retry budget). Same additive discipline as
/// [`ERR_CODE_OVERLOADED`].
const ERR_CODE_DEGRADED: u8 = 2;

/// Header flag: a `u64` `req_id` follows the flags byte.
const FLAG_REQ_ID: u8 = 1;

/// A decoded request frame.
#[derive(Debug, Clone)]
pub struct Request {
    /// client correlation id, echoed verbatim in the response
    pub req_id: Option<u64>,
    /// what the client asked for
    pub body: RequestBody,
}

/// The request payload: either a coordinator op (routed through the
/// dynamic batcher) or one of the transport-level ops the server answers
/// directly.
#[derive(Debug, Clone)]
pub enum RequestBody {
    /// a coordinator operation
    Op(Op),
    /// a batched set of coordinator operations decoded from one
    /// `hash_batch` / `insert_batch` / `query_batch` frame; per-item
    /// decode failures ride as `Err` entries, so one bad row fails that
    /// row's slot in the batch envelope, not the frame. Never empty
    /// (an empty batch is a frame-level error).
    Batch(Vec<Result<Op, String>>),
    /// the service's published sample points
    Points,
    /// graceful server shutdown
    Shutdown,
}

fn f32_row(v: &Value) -> Result<Vec<f32>, String> {
    let arr = v.as_array().ok_or("`samples` must be an array")?;
    arr.iter()
        .enumerate()
        .map(|(i, x)| {
            let f = x
                .as_f64()
                .ok_or_else(|| "`samples` must contain only numbers".to_string())?;
            let v = f as f32;
            if !v.is_finite() {
                // a JSON f64 that overflows f32 casts to ±inf; letting it
                // through would poison the index and every re-rank
                // distance it touches
                return Err(format!(
                    "`samples[{i}]` = {f} is not a finite f32 (non-finite samples are rejected)"
                ));
            }
            Ok(v)
        })
        .collect()
}

fn need<'v>(v: &'v Value, key: &str) -> Result<&'v Value, String> {
    v.get(key).ok_or_else(|| format!("missing field `{key}`"))
}

/// The `rows` field of a JSON batch frame: a non-empty array of sample
/// rows, yielded one `Result` per row so a bad row (non-numeric or
/// non-finite entries) becomes that row's `Err` slot instead of failing
/// the frame.
fn batch_rows_json<'v>(
    v: &'v Value,
) -> Result<impl Iterator<Item = Result<Vec<f32>, String>> + 'v, String> {
    let rows = need(v, "rows")?
        .as_array()
        .ok_or("`rows` must be an array")?;
    if rows.is_empty() {
        return Err("batch must carry at least one row".into());
    }
    Ok(rows.iter().map(f32_row))
}

/// The `entries` field of a JSON `entries_push` frame: a non-empty
/// array of `{id, emb, sig}` records. Embedding values are validated
/// finite at the wire — the same doctrine as sample rows — so a
/// poisoned migration chunk is rejected before it can touch the store.
fn entry_records_json(v: &Value, allow_empty: bool) -> Result<Vec<EntryRecord>, String> {
    let entries = need(v, "entries")?
        .as_array()
        .ok_or("`entries` must be an array")?;
    if entries.is_empty() && !allow_empty {
        return Err("entries_push must carry at least one entry".into());
    }
    entries
        .iter()
        .enumerate()
        .map(|(i, e)| -> Result<EntryRecord, String> {
            let id = need(e, "id")?
                .as_u64()
                .ok_or_else(|| format!("entry[{i}]: `id` must be a u64"))?;
            let emb = need(e, "emb")?
                .as_array()
                .ok_or_else(|| format!("entry[{i}]: `emb` must be an array"))?
                .iter()
                .map(|x| {
                    let f = x
                        .as_f64()
                        .ok_or_else(|| format!("entry[{i}]: `emb` must contain numbers"))?;
                    if !f.is_finite() {
                        return Err(format!(
                            "entry[{i}]: `emb` contains a non-finite value \
                             (non-finite embeddings are rejected)"
                        ));
                    }
                    Ok(f)
                })
                .collect::<Result<_, _>>()?;
            let sig = need(e, "sig")?
                .as_array()
                .ok_or_else(|| format!("entry[{i}]: `sig` must be an array"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .and_then(json_sig_i32)
                        .ok_or_else(|| format!("entry[{i}]: `sig` must contain i32 bucket ids"))
                })
                .collect::<Result<_, _>>()?;
            Ok(EntryRecord { id, emb, sig })
        })
        .collect()
}

/// Decode a JSON number as an exact `i32` bucket id. The seed decoder
/// lowered with a bare `as i32`, which *saturates*: a corrupt or hostile
/// `1e99` silently became `i32::MAX` and `NaN` became `0`, landing the
/// entry in wrong buckets forever. Non-integral, out-of-range, and
/// non-finite values are decode errors instead.
fn json_sig_i32(f: f64) -> Option<i32> {
    // in-range integral f64s convert exactly; NaN fails every comparison
    if f.fract() == 0.0 && f >= i32::MIN as f64 && f <= i32::MAX as f64 {
        Some(f as i32)
    } else {
        None
    }
}

/// A rejected request frame. Carries the `req_id` recovered from the
/// frame (when it parsed far enough to have one), so the error envelope
/// can still correlate — a pipelined client must get a per-request
/// error, not a connection-level failure.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestError {
    /// the frame's correlation id, if it was recoverable
    pub req_id: Option<u64>,
    /// what was wrong with the frame
    pub msg: String,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

/// Parse one JSON request line.
pub fn parse_request(line: &str) -> Result<Request, RequestError> {
    let v = json::parse(line.trim()).map_err(|e| RequestError {
        req_id: None,
        msg: format!("bad json: {e}"),
    })?;
    let req_id = v.get("req_id").and_then(Value::as_u64);
    let body = (|| -> Result<RequestBody, String> {
        let op = v
            .get("op")
            .and_then(Value::as_str)
            .ok_or("missing string field `op`")?;
        Ok(match op {
            "hash" => RequestBody::Op(Op::Hash {
                samples: f32_row(need(&v, "samples")?)?,
            }),
            "insert" => RequestBody::Op(Op::Insert {
                id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
                samples: f32_row(need(&v, "samples")?)?,
            }),
            "query" => RequestBody::Op(Op::Query {
                samples: f32_row(need(&v, "samples")?)?,
                k: need(&v, "k")?.as_usize().ok_or("`k` must be a usize")?,
            }),
            "remove" => RequestBody::Op(Op::Remove {
                id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
            }),
            "metrics" => RequestBody::Op(Op::Metrics),
            "snapshot" => RequestBody::Op(Op::Snapshot {
                path: need(&v, "path")?
                    .as_str()
                    .ok_or("`path` must be a string")?
                    .to_string(),
            }),
            "ping" => RequestBody::Op(Op::Ping),
            "stats" => {
                let detail = match v.get("detail") {
                    None => StatsDetail::Summary,
                    Some(d) => {
                        let d = d.as_str().ok_or("`detail` must be a string")?;
                        StatsDetail::parse(d).ok_or_else(|| {
                            format!(
                                "unknown stats detail `{d}` (expected summary, stages, \
                                 index, slow, or cluster)"
                            )
                        })?
                    }
                };
                RequestBody::Op(Op::Stats { detail })
            }
            "points" => RequestBody::Points,
            "shutdown" => RequestBody::Shutdown,
            "hash_batch" => RequestBody::Batch(
                batch_rows_json(&v)?
                    .map(|row| row.map(|samples| Op::Hash { samples }))
                    .collect(),
            ),
            "insert_batch" => {
                let ids = need(&v, "ids")?
                    .as_array()
                    .ok_or("`ids` must be an array")?;
                let rows = need(&v, "rows")?
                    .as_array()
                    .ok_or("`rows` must be an array")?;
                if ids.len() != rows.len() {
                    return Err(format!(
                        "batch declares {} ids but {} rows",
                        ids.len(),
                        rows.len()
                    ));
                }
                if rows.is_empty() {
                    return Err("batch must carry at least one row".into());
                }
                RequestBody::Batch(
                    ids.iter()
                        .zip(rows)
                        .map(|(id, row)| {
                            let id = id
                                .as_u64()
                                .ok_or_else(|| "`ids` must contain u64s".to_string())?;
                            Ok(Op::Insert {
                                id,
                                samples: f32_row(row)?,
                            })
                        })
                        .collect(),
                )
            }
            "query_batch" => {
                let k = need(&v, "k")?.as_usize().ok_or("`k` must be a usize")?;
                RequestBody::Batch(
                    batch_rows_json(&v)?
                        .map(|row| row.map(|samples| Op::Query { samples, k }))
                        .collect(),
                )
            }
            "migrate_pull" => RequestBody::Op(Op::MigratePull {
                from_id: need(&v, "from_id")?
                    .as_u64()
                    .ok_or("`from_id` must be a u64")?,
                max: need(&v, "max")?.as_usize().ok_or("`max` must be a usize")?,
            }),
            "entries_push" => RequestBody::Op(Op::EntriesPush {
                entries: entry_records_json(&v, false)?,
            }),
            "entries_discard" => RequestBody::Op(Op::EntriesDiscard {
                ids: need(&v, "ids")?
                    .as_array()
                    .ok_or("`ids` must be an array")?
                    .iter()
                    .map(|id| {
                        id.as_u64()
                            .ok_or_else(|| "`ids` must contain u64s".to_string())
                    })
                    .collect::<Result<_, _>>()?,
            }),
            other => return Err(format!("unknown op `{other}`")),
        })
    })()
    .map_err(|msg| RequestError { req_id, msg })?;
    Ok(Request { req_id, body })
}

// ---------------------------------------------------- binary primitives

/// Little-endian reader over a binary payload; every accessor reports
/// truncation as a typed message instead of panicking.
struct BinReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn finished(&self) -> bool {
        self.pos == self.b.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated frame: need {n} more bytes, have {}",
                self.remaining()
            ));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// The next byte without consuming it (`None` at the end).
    fn peek_u8(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, String> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str_(&mut self) -> Result<&'a str, String> {
        let n = self.u32()? as usize;
        std::str::from_utf8(self.take(n)?).map_err(|_| "invalid utf-8 in string field".into())
    }

    /// `u32` count + raw `f32` samples, with the declared count checked
    /// against the remaining bytes *before* any allocation is sized from
    /// it, and every value checked finite (the binary twin of
    /// [`f32_row`]'s rejection rule).
    fn samples(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        if self.remaining() < n.saturating_mul(4) {
            return Err(format!(
                "declared {n} samples but only {} payload bytes remain",
                self.remaining()
            ));
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let v = self.f32()?;
            if !v.is_finite() {
                return Err(format!(
                    "sample[{i}] is not a finite f32 (non-finite samples are rejected)"
                ));
            }
            out.push(v);
        }
        Ok(out)
    }

    /// `count:u32, dim:u32` header of a batch op body. Both must be
    /// positive — a zero count (or a zero dim, which would let a huge
    /// count size allocations from nothing) is a frame-level error.
    fn batch_header(&mut self) -> Result<(usize, usize), String> {
        let count = self.u32()? as usize;
        let dim = self.u32()? as usize;
        if count == 0 {
            return Err("batch count must be positive".into());
        }
        if dim == 0 {
            return Err("batch dim must be positive".into());
        }
        Ok((count, dim))
    }

    /// `count` contiguous rows of `dim` raw `f32`s. The declared
    /// `count×dim` extent is checked against the remaining payload
    /// *before* any allocation is sized from it (an extent past the
    /// frame cap therefore always fails here, never allocates); a row
    /// containing a non-finite value becomes that row's `Err` slot —
    /// its bytes are still consumed so the following rows decode.
    fn batch_rows(
        &mut self,
        count: usize,
        dim: usize,
    ) -> Result<Vec<Result<Vec<f32>, String>>, String> {
        let bytes = count.saturating_mul(dim).saturating_mul(4);
        if self.remaining() < bytes {
            return Err(format!(
                "batch declares {count}x{dim} samples ({bytes} bytes) but only {} \
                 payload bytes remain",
                self.remaining()
            ));
        }
        let mut rows = Vec::with_capacity(count);
        for r in 0..count {
            let mut row = Vec::with_capacity(dim);
            let mut bad: Option<String> = None;
            for i in 0..dim {
                let v = self.f32()?;
                if !v.is_finite() && bad.is_none() {
                    bad = Some(format!(
                        "row {r}: sample[{i}] is not a finite f32 \
                         (non-finite samples are rejected)"
                    ));
                }
                row.push(v);
            }
            rows.push(match bad {
                Some(msg) => Err(msg),
                None => Ok(row),
            });
        }
        Ok(rows)
    }

    /// One migration entry record: `id:u64`, `emb_len:u32` + raw `f64`s
    /// (checked finite), `sig_len:u32` + raw `i32`s — declared extents
    /// checked against the remaining payload before any allocation is
    /// sized from them.
    fn entry_record(&mut self) -> Result<EntryRecord, String> {
        let id = self.u64()?;
        let emb_len = self.u32()? as usize;
        if self.remaining() < emb_len.saturating_mul(8) {
            return Err(format!(
                "entry {id} declares {emb_len} embedding values but only {} \
                 payload bytes remain",
                self.remaining()
            ));
        }
        let mut emb = Vec::with_capacity(emb_len);
        for i in 0..emb_len {
            let v = self.f64()?;
            if !v.is_finite() {
                return Err(format!(
                    "entry {id}: emb[{i}] is not finite \
                     (non-finite embeddings are rejected)"
                ));
            }
            emb.push(v);
        }
        let sig_len = self.u32()? as usize;
        if self.remaining() < sig_len.saturating_mul(4) {
            return Err(format!(
                "entry {id} declares {sig_len} signature values but only {} \
                 payload bytes remain",
                self.remaining()
            ));
        }
        let mut sig = Vec::with_capacity(sig_len);
        for _ in 0..sig_len {
            sig.push(self.i32()?);
        }
        Ok(EntryRecord { id, emb, sig })
    }
}

/// Append one migration entry record in the layout [`BinReader::entry_record`]
/// decodes — shared by the `entries_push` request body and the `entries`
/// reply body, so the two directions can never drift.
fn put_entry_record(b: &mut Vec<u8>, e: &EntryRecord) {
    b.extend_from_slice(&e.id.to_le_bytes());
    b.extend_from_slice(&(e.emb.len() as u32).to_le_bytes());
    for &v in &e.emb {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b.extend_from_slice(&(e.sig.len() as u32).to_le_bytes());
    for &v in &e.sig {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

/// Build one binary frame: 4-byte LE length prefix + the payload written
/// by `build`.
fn bin_frame(build: impl FnOnce(&mut Vec<u8>)) -> Vec<u8> {
    let mut b = vec![0u8; 4];
    build(&mut b);
    let len = (b.len() - 4) as u32;
    b[..4].copy_from_slice(&len.to_le_bytes());
    b
}

/// Leading tag byte (request op / response status) + flags (+ `req_id`).
fn put_tag_and_req_id(b: &mut Vec<u8>, tag: u8, req_id: Option<u64>) {
    b.push(tag);
    match req_id {
        Some(id) => {
            b.push(FLAG_REQ_ID);
            b.extend_from_slice(&id.to_le_bytes());
        }
        None => b.push(0),
    }
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    b.extend_from_slice(&(s.len() as u32).to_le_bytes());
    b.extend_from_slice(s.as_bytes());
}

fn put_samples(b: &mut Vec<u8>, samples: &[f32]) {
    b.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    for &s in samples {
        b.extend_from_slice(&s.to_le_bytes());
    }
}

/// Parse one binary request payload (the bytes after the length prefix).
/// The header (op tag, flags, `req_id`) parses first, so body-level
/// failures still correlate to their request.
pub fn parse_request_binary(payload: &[u8]) -> Result<Request, RequestError> {
    let mut rd = BinReader::new(payload);
    let head = |msg: String| RequestError { req_id: None, msg };
    let op = rd.u8().map_err(head)?;
    let flags = rd.u8().map_err(head)?;
    if flags & !FLAG_REQ_ID != 0 {
        return Err(head(format!("unknown header flags {flags:#04x}")));
    }
    let req_id = if flags & FLAG_REQ_ID != 0 {
        Some(rd.u64().map_err(head)?)
    } else {
        None
    };
    let body = (|| -> Result<RequestBody, String> {
        let body = match op {
            OP_HASH => RequestBody::Op(Op::Hash {
                samples: rd.samples()?,
            }),
            OP_INSERT => {
                let id = rd.u64()?;
                RequestBody::Op(Op::Insert {
                    id,
                    samples: rd.samples()?,
                })
            }
            OP_QUERY => {
                let samples = rd.samples()?;
                let k = rd.u64()? as usize;
                RequestBody::Op(Op::Query { samples, k })
            }
            OP_REMOVE => RequestBody::Op(Op::Remove { id: rd.u64()? }),
            OP_METRICS => RequestBody::Op(Op::Metrics),
            OP_SNAPSHOT => RequestBody::Op(Op::Snapshot {
                path: rd.str_()?.to_string(),
            }),
            OP_PING => RequestBody::Op(Op::Ping),
            OP_STATS => {
                let d = rd.u8()?;
                let detail = StatsDetail::from_u8(d)
                    .ok_or_else(|| format!("unknown stats detail tag {d}"))?;
                RequestBody::Op(Op::Stats { detail })
            }
            OP_POINTS => RequestBody::Points,
            OP_SHUTDOWN => RequestBody::Shutdown,
            OP_HASH_BATCH => {
                let (count, dim) = rd.batch_header()?;
                RequestBody::Batch(
                    rd.batch_rows(count, dim)?
                        .into_iter()
                        .map(|row| row.map(|samples| Op::Hash { samples }))
                        .collect(),
                )
            }
            OP_INSERT_BATCH => {
                let (count, dim) = rd.batch_header()?;
                if rd.remaining() < count.saturating_mul(8) {
                    return Err(format!(
                        "batch declares {count} ids but only {} payload bytes remain",
                        rd.remaining()
                    ));
                }
                let mut ids = Vec::with_capacity(count);
                for _ in 0..count {
                    ids.push(rd.u64()?);
                }
                RequestBody::Batch(
                    ids.into_iter()
                        .zip(rd.batch_rows(count, dim)?)
                        .map(|(id, row)| row.map(|samples| Op::Insert { id, samples }))
                        .collect(),
                )
            }
            OP_QUERY_BATCH => {
                let (count, dim) = rd.batch_header()?;
                let rows = rd.batch_rows(count, dim)?;
                let k = rd.u64()? as usize;
                RequestBody::Batch(
                    rows.into_iter()
                        .map(|row| row.map(|samples| Op::Query { samples, k }))
                        .collect(),
                )
            }
            OP_MIGRATE_PULL => {
                let from_id = rd.u64()?;
                let max = rd.u64()? as usize;
                RequestBody::Op(Op::MigratePull { from_id, max })
            }
            OP_ENTRIES_PUSH => {
                let count = rd.u32()? as usize;
                if count == 0 {
                    return Err("entries_push must carry at least one entry".into());
                }
                // each entry carries at least id + two length words
                if rd.remaining() < count.saturating_mul(16) {
                    return Err(format!(
                        "entries_push declares {count} entries, frame truncated"
                    ));
                }
                let mut entries = Vec::with_capacity(count);
                for _ in 0..count {
                    entries.push(rd.entry_record()?);
                }
                RequestBody::Op(Op::EntriesPush { entries })
            }
            OP_ENTRIES_DISCARD => {
                let count = rd.u32()? as usize;
                if rd.remaining() < count.saturating_mul(8) {
                    return Err(format!(
                        "entries_discard declares {count} ids but only {} \
                         payload bytes remain",
                        rd.remaining()
                    ));
                }
                let mut ids = Vec::with_capacity(count);
                for _ in 0..count {
                    ids.push(rd.u64()?);
                }
                RequestBody::Op(Op::EntriesDiscard { ids })
            }
            other => return Err(format!("unknown binary op tag {other}")),
        };
        if !rd.finished() {
            return Err(format!(
                "{} trailing bytes after the request body",
                rd.remaining()
            ));
        }
        Ok(body)
    })()
    .map_err(|msg| RequestError { req_id, msg })?;
    Ok(Request { req_id, body })
}

/// Decode one framed request payload in `wire`'s format — the shared
/// step immediately after framing (UTF-8 and empty-line checks for
/// JSON, then the per-format parser), so both runtimes keep **one**
/// copy of the malformed-payload rules just as they share one
/// [`Framer`] for the bytes themselves.
pub fn parse_frame_payload(wire: WireMode, payload: &[u8]) -> Result<Request, RequestError> {
    match wire {
        WireMode::Json => {
            let line = std::str::from_utf8(payload).map_err(|_| RequestError {
                req_id: None,
                msg: "invalid utf-8".into(),
            })?;
            if line.trim().is_empty() {
                return Err(RequestError {
                    req_id: None,
                    msg: "empty request".into(),
                });
            }
            parse_request(line)
        }
        WireMode::Binary => parse_request_binary(payload),
    }
}

// -------------------------------------------------------- JSON encoders

/// The largest integer the JSON wire carries exactly (the f64 mantissa
/// limit; the binary format has no such bound).
const MAX_JSON_SAFE_INT: u64 = 1 << 53;

/// An id in `resp` that the JSON number carrier would silently round,
/// if any. Full-width ids enter the corpus over the binary wire; a JSON
/// connection must get a correlated error for such a response instead
/// of a corrupted number its own decoder would then reject.
fn json_unrepresentable_id(resp: &Response) -> Option<u64> {
    match resp {
        Response::Inserted { id } | Response::Removed { id } if *id > MAX_JSON_SAFE_INT => {
            Some(*id)
        }
        Response::Hits(hits) => hits
            .iter()
            .map(|h| h.id)
            .find(|&id| id > MAX_JSON_SAFE_INT),
        Response::Entries { entries, .. } => entries
            .iter()
            .map(|e| e.id)
            .find(|&id| id > MAX_JSON_SAFE_INT),
        _ => None,
    }
}

fn json_id_error(id: u64) -> String {
    format!(
        "response carries id {id}, which exceeds 2^53 and cannot ride a JSON number \
         exactly; use the binary (FBIN1) wire format for full-width ids"
    )
}

fn envelope(req_id: Option<u64>, mut fields: Vec<(&str, Value)>) -> String {
    fields.push(("ok", true.into()));
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

/// Canonical message prefix of an admission-control shed. Kept stable so
/// [`error_is_overloaded`] classifies sheds on both ends of the wire;
/// the JSON envelope additionally carries `"code":"overloaded"` and the
/// binary envelope a trailing [`ERR_CODE_OVERLOADED`] byte.
const OVERLOADED_PREFIX: &str = "overloaded: ";

/// Build the canonical `overloaded` shed message for `scope` — which
/// budget tripped (`"connection in-flight byte budget"`, `"server
/// in-flight byte budget"`, `"write queue limit for a slow-reading
/// client"`, …).
pub fn overloaded_msg(scope: &str) -> String {
    format!("{OVERLOADED_PREFIX}{scope}; retry with backoff")
}

/// Whether a server-side error message is a typed `overloaded` shed.
/// Clients use this to separate retry-with-backoff sheds from real
/// request errors; the load generator counts sheds with it.
pub fn error_is_overloaded(msg: &str) -> bool {
    msg.starts_with(OVERLOADED_PREFIX)
}

/// Encode a typed `overloaded` shed envelope as complete wire bytes for
/// `mode` — the one way both runtimes answer a request refused by
/// admission control.
pub fn encode_overloaded_frame(mode: WireMode, req_id: Option<u64>, scope: &str) -> Vec<u8> {
    encode_error_frame(mode, req_id, &overloaded_msg(scope))
}

/// Canonical message prefix of a cluster `degraded` failure: a request
/// whose owning shard(s) stayed down past the router's retry budget.
/// Kept stable so [`error_is_degraded`] classifies on both ends of the
/// wire; the JSON envelope additionally carries `"code":"degraded"` and
/// the binary envelope a trailing [`ERR_CODE_DEGRADED`] byte.
const DEGRADED_PREFIX: &str = "degraded: ";

/// Build the canonical `degraded` failure message for a request that
/// could not be served at all (e.g. an insert whose owning shard is
/// down): `what` names the unavailable shard range(s).
pub fn degraded_msg(what: &str) -> String {
    format!("{DEGRADED_PREFIX}{what}; retry with backoff")
}

/// Whether a server-side error message is a typed cluster `degraded`
/// failure. Clients use this to separate down-shard unavailability
/// (retryable once the shard heals) from real request errors.
pub fn error_is_degraded(msg: &str) -> bool {
    msg.starts_with(DEGRADED_PREFIX)
}

/// Encode an error response line (JSON). An `overloaded` shed
/// additionally carries the machine-readable `"code":"overloaded"`
/// field, and a cluster `degraded` failure `"code":"degraded"`, so
/// clients need not parse the message to classify either.
pub fn encode_error(req_id: Option<u64>, msg: &str) -> String {
    let mut fields: Vec<(&str, Value)> = vec![("ok", false.into()), ("error", msg.into())];
    if error_is_overloaded(msg) {
        fields.push(("code", "overloaded".into()));
    } else if error_is_degraded(msg) {
        fields.push(("code", "degraded".into()));
    }
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

/// The `type` + body fields of a successful coordinator response —
/// shared by the single-op envelope and the per-item entries of a batch
/// envelope (so batch items serialize byte-identically to single ops).
fn response_fields(resp: &Response) -> Vec<(&'static str, Value)> {
    match resp {
        Response::Signature(sig) => vec![
            ("type", "signature".into()),
            (
                "signature",
                // serialized straight from the shared flat block — no
                // per-response Vec<i32> clone on this path; iter_i32
                // widens narrow-width blocks on the fly, so the wire
                // format is identical at every storage width
                Value::Array(
                    sig.iter_i32().map(|x| Value::Number(x as f64)).collect(),
                ),
            ),
        ],
        Response::Inserted { id } => {
            vec![("type", "inserted".into()), ("id", (*id as usize).into())]
        }
        Response::Hits(hits) => vec![
            ("type", "hits".into()),
            (
                "hits",
                Value::Array(
                    hits.iter()
                        .map(|h| {
                            object(vec![
                                ("id", (h.id as usize).into()),
                                ("distance", h.distance.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ],
        Response::Removed { id } => {
            vec![("type", "removed".into()), ("id", (*id as usize).into())]
        }
        Response::Metrics(m) => vec![("type", "metrics".into()), ("metrics", m.to_value())],
        Response::Stats(v) => vec![("type", "stats".into()), ("stats", v.clone())],
        Response::Snapshotted { path, bytes } => vec![
            ("type", "snapshot".into()),
            ("path", path.as_str().into()),
            ("bytes", (*bytes as usize).into()),
        ],
        Response::Pong { indexed } => vec![
            ("type", "pong".into()),
            ("indexed", (*indexed as usize).into()),
        ],
        Response::Entries { entries, done } => vec![
            ("type", "entries".into()),
            ("done", Value::Bool(*done)),
            (
                "entries",
                Value::Array(entries.iter().map(entry_record_value).collect()),
            ),
        ],
        Response::Ingested { count } => vec![
            ("type", "ingested".into()),
            ("count", (*count as usize).into()),
        ],
        Response::Error(_) => unreachable!("error envelopes are encoded by the callers"),
    }
}

/// One migration entry record as a JSON object — the JSON twin of
/// [`put_entry_record`].
fn entry_record_value(e: &EntryRecord) -> Value {
    object(vec![
        ("id", (e.id as usize).into()),
        (
            "emb",
            Value::Array(e.emb.iter().map(|&x| Value::Number(x)).collect()),
        ),
        (
            "sig",
            Value::Array(e.sig.iter().map(|&x| Value::Number(x as f64)).collect()),
        ),
    ])
}

/// Encode a coordinator response line (JSON).
pub fn encode_response(req_id: Option<u64>, resp: &Response) -> String {
    match resp {
        Response::Error(e) => encode_error(req_id, e),
        _ => envelope(req_id, response_fields(resp)),
    }
}

/// The per-item envelope of a JSON batch reply: `{"ok":true, …}` with
/// the same body as the single-op response, or `{"ok":false,"error":…}`
/// — shared by the one-frame `batch` envelope and the `batch_part`
/// continuation frames, so items serialize identically either way.
fn json_batch_item(resp: &Response) -> Value {
    match resp {
        Response::Error(e) => object(vec![
            ("ok", false.into()),
            ("error", e.as_str().into()),
        ]),
        _ => {
            let mut fields = response_fields(resp);
            fields.push(("ok", true.into()));
            object(fields)
        }
    }
}

/// Encode a batch response line (JSON): one envelope whose `results`
/// array holds a per-item envelope (`{"ok":true, …}` with the same body
/// as the single-op response, or `{"ok":false,"error":…}`) in request
/// row order.
pub fn encode_batch_response(req_id: Option<u64>, items: &[Response]) -> String {
    let results = items.iter().map(json_batch_item).collect();
    envelope(
        req_id,
        vec![("type", "batch".into()), ("results", Value::Array(results))],
    )
}

/// Encode one continuation frame of a streamed batch reply (JSON): the
/// same per-item envelopes as a `batch` reply under
/// `type = "batch_part"`, plus a `more` flag — `true` on every part but
/// the last.
fn encode_batch_part(req_id: Option<u64>, more: bool, results: Vec<Value>) -> String {
    envelope(
        req_id,
        vec![
            ("type", "batch_part".into()),
            ("more", Value::Bool(more)),
            ("results", Value::Array(results)),
        ],
    )
}

/// Encode the transport-level `points` response (JSON).
pub fn encode_points(req_id: Option<u64>, points: &[f64]) -> String {
    envelope(
        req_id,
        vec![
            ("type", "points".into()),
            (
                "points",
                Value::Array(points.iter().map(|&x| Value::Number(x)).collect()),
            ),
        ],
    )
}

/// Encode the transport-level `shutdown` acknowledgement (JSON).
pub fn encode_shutting_down(req_id: Option<u64>) -> String {
    envelope(req_id, vec![("type", "shutting_down".into())])
}

// ------------------------------------------------------ binary encoders

/// Encode an error response frame (binary, length-prefixed). An
/// `overloaded` shed appends the [`ERR_CODE_OVERLOADED`] code byte after
/// the message — additive, since decoders stop at the message.
pub fn encode_error_binary(req_id: Option<u64>, msg: &str) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_ERR, req_id);
        put_str(b, msg);
        if error_is_overloaded(msg) {
            b.push(ERR_CODE_OVERLOADED);
        } else if error_is_degraded(msg) {
            b.push(ERR_CODE_DEGRADED);
        }
    })
}

/// Append a successful reply's `type:u8` + body (everything after the
/// status/flags/`req_id` header) — shared by the single-op frame and the
/// per-item entries of a batch frame, so batch items serialize
/// byte-identically to single ops.
fn put_reply_body(b: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Signature(sig) => {
            b.push(REPLY_SIGNATURE);
            // straight off the shared [B×K] block: count + i32 values
            // (narrow-width blocks widen per element, so the wire bytes
            // are identical at every storage width)
            b.extend_from_slice(&(sig.len() as u32).to_le_bytes());
            for v in sig.iter_i32() {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        Response::Inserted { id } => {
            b.push(REPLY_INSERTED);
            b.extend_from_slice(&id.to_le_bytes());
        }
        Response::Hits(hits) => {
            b.push(REPLY_HITS);
            b.extend_from_slice(&(hits.len() as u32).to_le_bytes());
            for h in hits {
                b.extend_from_slice(&h.id.to_le_bytes());
                b.extend_from_slice(&h.distance.to_le_bytes());
            }
        }
        Response::Removed { id } => {
            b.push(REPLY_REMOVED);
            b.extend_from_slice(&id.to_le_bytes());
        }
        Response::Metrics(m) => {
            // metrics stay a JSON object inside the binary carrier:
            // they are diagnostic, schema-fluid, and tiny
            b.push(REPLY_METRICS);
            put_str(b, &m.to_value().to_json());
        }
        Response::Stats(v) => {
            // same discipline as metrics: stats views stay a JSON object
            // inside the binary carrier — diagnostic, schema-fluid, small
            b.push(REPLY_STATS);
            put_str(b, &v.to_json());
        }
        Response::Snapshotted { path, bytes } => {
            b.push(REPLY_SNAPSHOT);
            put_str(b, path);
            b.extend_from_slice(&bytes.to_le_bytes());
        }
        Response::Pong { indexed } => {
            b.push(REPLY_PONG);
            b.extend_from_slice(&indexed.to_le_bytes());
        }
        Response::Entries { entries, done } => {
            b.push(REPLY_ENTRIES);
            b.push(*done as u8);
            b.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for e in entries {
                put_entry_record(b, e);
            }
        }
        Response::Ingested { count } => {
            b.push(REPLY_INGESTED);
            b.extend_from_slice(&count.to_le_bytes());
        }
        Response::Error(_) => unreachable!("error envelopes are encoded by the callers"),
    }
}

/// Encode a coordinator response frame (binary, length-prefixed).
pub fn encode_response_binary(req_id: Option<u64>, resp: &Response) -> Vec<u8> {
    if let Response::Error(e) = resp {
        return encode_error_binary(req_id, e);
    }
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        put_reply_body(b, resp);
    })
}

/// Append one batch item — `status:u8` followed by either the single-op
/// reply body (ok) or a length-prefixed message (err) — the binary twin
/// of [`json_batch_item`], shared by the one-frame `batch` reply and the
/// `batch_part` continuation frames.
fn put_batch_item(b: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Error(e) => {
            b.push(STATUS_ERR);
            put_str(b, e);
        }
        _ => {
            b.push(STATUS_OK);
            put_reply_body(b, resp);
        }
    }
}

/// Encode a batch response frame (binary): `type:u8 = batch`,
/// `count:u32`, then per item a `status:u8` followed by either the
/// single-op reply body (ok) or a length-prefixed message (err), in
/// request row order.
pub fn encode_batch_response_binary(req_id: Option<u64>, items: &[Response]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        b.push(REPLY_BATCH);
        b.extend_from_slice(&(items.len() as u32).to_le_bytes());
        for resp in items {
            put_batch_item(b, resp);
        }
    })
}

/// Encode one continuation frame of a streamed batch reply (binary):
/// `type:u8 = batch_part`, `more:u8` (1 while further parts follow),
/// `count:u32`, then `count` items in the same per-item layout as a
/// `batch` reply. `body` must hold the `count` already-encoded items.
fn encode_batch_part_binary(
    req_id: Option<u64>,
    more: bool,
    count: usize,
    body: &[u8],
) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        b.push(REPLY_BATCH_PART);
        b.push(more as u8);
        b.extend_from_slice(&(count as u32).to_le_bytes());
        b.extend_from_slice(body);
    })
}

/// Encode the transport-level `points` response (binary).
pub fn encode_points_binary(req_id: Option<u64>, points: &[f64]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        b.push(REPLY_POINTS);
        b.extend_from_slice(&(points.len() as u32).to_le_bytes());
        for &p in points {
            b.extend_from_slice(&p.to_le_bytes());
        }
    })
}

/// Encode the transport-level `shutdown` acknowledgement (binary).
pub fn encode_shutting_down_binary(req_id: Option<u64>) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        b.push(REPLY_SHUTTING_DOWN);
    })
}

// --------------------------------------------- mode-dispatching framing

/// Wrap a JSON line as wire bytes (the line plus its newline).
fn json_frame(line: String) -> Vec<u8> {
    let mut b = line.into_bytes();
    b.push(b'\n');
    b
}

/// Per-frame framing-overhead bytes `mode` adds on the wire around a
/// payload: the JSON newline terminator or the binary `u32` length
/// prefix. (A binary connection additionally spends the 5
/// [`BINARY_MAGIC`] bytes once at negotiation.) The traffic counters
/// and `bench-wire`'s per-row byte columns use this so counted bytes
/// reconcile against bytes actually on the wire (tcpdump).
pub fn frame_overhead_bytes(mode: WireMode) -> usize {
    match mode {
        WireMode::Json => 1,
        WireMode::Binary => 4,
    }
}

/// Payload length of an already-framed response (JSON line without its
/// newline, binary payload without its prefix).
fn framed_payload_len(mode: WireMode, frame: &[u8]) -> usize {
    match mode {
        WireMode::Json => frame.len().saturating_sub(1),
        WireMode::Binary => frame.len().saturating_sub(4),
    }
}

/// A safe *lower bound* on a response's encoded payload size: never
/// larger than the real encoding, so it can veto serialization early
/// without ever rejecting a response that would have fit. Binary element
/// sizes are exact; JSON per-element floors are the shortest possible
/// renderings.
fn response_payload_min(mode: WireMode, resp: &Response) -> usize {
    let per_elem = |bin: usize, json_min: usize| match mode {
        WireMode::Binary => bin,
        WireMode::Json => json_min,
    };
    match resp {
        // binary: 16 B/hit; JSON: >= len(r#"{"distance":0,"id":0}"#) + comma
        Response::Hits(h) => h.len() * per_elem(16, 22),
        // binary: 4 B/entry; JSON: >= one digit + comma
        Response::Signature(s) => s.len() * per_elem(4, 2),
        // binary: id + two length words + raw values; JSON: the shortest
        // possible record shell + one char per value
        Response::Entries { entries, .. } => entries
            .iter()
            .map(|e| {
                per_elem(
                    16 + e.emb.len() * 8 + e.sig.len() * 4,
                    24 + 2 * e.emb.len() + 2 * e.sig.len(),
                )
            })
            .sum(),
        _ => 0,
    }
}

/// Encode a coordinator response as complete wire bytes for `mode`, with
/// the oversize guard: a response the peer could never frame (payload >
/// [`MAX_FRAME_BYTES`], e.g. a `query` with a huge `k` against a dense
/// bucket) is replaced by a *correlated per-request error envelope*
/// instead of killing the connection — every other in-flight pipelined
/// request keeps its answer. Provably-oversized responses are vetoed by
/// an exact size bound *before* serialization, so the hostile path never
/// builds the tens-of-MB frame it is about to discard.
pub fn encode_response_frame(mode: WireMode, req_id: Option<u64>, resp: &Response) -> Vec<u8> {
    // a full-width id (inserted over the binary wire) cannot ride a
    // JSON number without rounding — degrade to a correlated error
    // rather than corrupt the id on the wire
    if mode == WireMode::Json {
        if let Some(id) = json_unrepresentable_id(resp) {
            return encode_error_frame(mode, req_id, &json_id_error(id));
        }
    }
    let floor = response_payload_min(mode, resp);
    if floor > MAX_FRAME_BYTES {
        return encode_error_frame(
            mode,
            req_id,
            &format!(
                "response too large (at least {floor} bytes > {MAX_FRAME_BYTES}-byte frame \
                 cap); request fewer results per op"
            ),
        );
    }
    let frame = match mode {
        WireMode::Json => json_frame(encode_response(req_id, resp)),
        WireMode::Binary => encode_response_binary(req_id, resp),
    };
    let payload = framed_payload_len(mode, &frame);
    if payload > MAX_FRAME_BYTES {
        return encode_error_frame(
            mode,
            req_id,
            &format!(
                "response too large ({payload} bytes > {MAX_FRAME_BYTES}-byte frame cap); \
                 request fewer results per op"
            ),
        );
    }
    frame
}

/// Encode a batch response as complete wire bytes for `mode`. A batch
/// whose envelope fits one frame is emitted exactly as before — one
/// `batch` envelope, byte-identical to the pre-streaming wire. A batch
/// whose payload would exceed [`MAX_FRAME_BYTES`] no longer degrades to
/// a retry-with-fewer-rows error: it **streams** as a sequence of
/// continuation frames (`batch_part` / [`REPLY_BATCH_PART`]), each
/// itself under the cap and carrying the shared `req_id`, with
/// `more = false` marking the final part — the effective batch-reply
/// size is unbounded while every individual frame still respects the
/// cap. Only an *individual item* too large for a frame of its own
/// still degrades, to that item's per-item "response too large" error
/// slot (its neighbours answer). The returned bytes may therefore hold
/// several complete frames; the runtimes write them as one in-order
/// blob and [`read_reply_frame`](crate::server::client) reassembles the
/// parts into one [`Reply::Batch`] transparently.
pub fn encode_batch_response_frame(
    mode: WireMode,
    req_id: Option<u64>,
    items: &[Response],
) -> Vec<u8> {
    // per-item JSON-representability guard: an item carrying a
    // full-width id fails only its own slot (same discipline as every
    // other per-item error), the neighbours still answer
    let safe: Vec<Response>;
    let items = if mode == WireMode::Json
        && items.iter().any(|r| json_unrepresentable_id(r).is_some())
    {
        safe = items
            .iter()
            .map(|r| match json_unrepresentable_id(r) {
                Some(id) => Response::Error(json_id_error(id)),
                None => r.clone(),
            })
            .collect();
        &safe
    } else {
        items
    };
    // provably-oversized batches skip straight to streaming without
    // building (and discarding) the single tens-of-MB envelope
    let floor: usize = items.iter().map(|r| response_payload_min(mode, r)).sum();
    if floor <= MAX_FRAME_BYTES {
        let frame = match mode {
            WireMode::Json => json_frame(encode_batch_response(req_id, items)),
            WireMode::Binary => encode_batch_response_binary(req_id, items),
        };
        if framed_payload_len(mode, &frame) <= MAX_FRAME_BYTES {
            return frame;
        }
    }
    match mode {
        WireMode::Json => stream_batch_json(req_id, items),
        WireMode::Binary => stream_batch_binary(req_id, items),
    }
}

/// The per-item error slot of a batch item whose own encoding exceeds
/// the frame cap even alone in a continuation frame.
fn oversize_item_error(bytes: usize) -> Response {
    Response::Error(format!(
        "response too large ({bytes} bytes > {MAX_FRAME_BYTES}-byte frame cap); \
         request fewer results per op"
    ))
}

/// Greedily pack batch items into `batch_part` continuation frames
/// (JSON). Each item is serialized once and measured exactly; the part
/// envelope overhead and the commas between items are accounted, so
/// every emitted frame's payload is provably under the cap.
fn stream_batch_json(req_id: Option<u64>, items: &[Response]) -> Vec<u8> {
    // fixed per-part overhead: the part envelope around an empty results
    // array ("more":false is the longer spelling, so it bounds both)
    let overhead = encode_batch_part(req_id, false, Vec::new()).len();
    let item_budget = MAX_FRAME_BYTES - overhead;
    let mut vals: Vec<(Value, usize)> = Vec::with_capacity(items.len());
    for resp in items {
        let v = json_batch_item(resp);
        let n = v.to_json().len();
        if n > item_budget {
            let v = json_batch_item(&oversize_item_error(n));
            let n = v.to_json().len();
            vals.push((v, n));
        } else {
            vals.push((v, n));
        }
    }
    let mut parts: Vec<Vec<Value>> = vec![Vec::new()];
    let mut part_bytes = 0usize;
    for (v, n) in vals {
        let sep = usize::from(!parts.last().expect("non-empty").is_empty());
        if part_bytes + sep + n > item_budget && sep == 1 {
            parts.push(Vec::new());
            part_bytes = 0;
        }
        part_bytes += usize::from(!parts.last().expect("non-empty").is_empty()) + n;
        parts.last_mut().expect("non-empty").push(v);
    }
    let last = parts.len() - 1;
    let mut out = Vec::new();
    for (i, part) in parts.into_iter().enumerate() {
        out.extend_from_slice(&json_frame(encode_batch_part(req_id, i < last, part)));
    }
    out
}

/// Greedily pack batch items into `batch_part` continuation frames
/// (binary). Items are encoded once into their exact wire bytes; the
/// fixed part header is accounted, so every emitted frame's payload is
/// provably under the cap.
fn stream_batch_binary(req_id: Option<u64>, items: &[Response]) -> Vec<u8> {
    // fixed per-part overhead: status + flags (+ req_id) + type + more
    // + count
    let overhead = 2 + if req_id.is_some() { 8 } else { 0 } + 1 + 1 + 4;
    let item_budget = MAX_FRAME_BYTES - overhead;
    let mut encoded: Vec<Vec<u8>> = Vec::with_capacity(items.len());
    for resp in items {
        let mut b = Vec::new();
        put_batch_item(&mut b, resp);
        if b.len() > item_budget {
            let n = b.len();
            b.clear();
            put_batch_item(&mut b, &oversize_item_error(n));
        }
        encoded.push(b);
    }
    let mut parts: Vec<(usize, Vec<u8>)> = vec![(0, Vec::new())];
    for b in encoded {
        let needs_new = {
            let (count, body) = parts.last().expect("non-empty");
            *count > 0 && body.len() + b.len() > item_budget
        };
        if needs_new {
            parts.push((0, Vec::new()));
        }
        let (count, body) = parts.last_mut().expect("non-empty");
        *count += 1;
        body.extend_from_slice(&b);
    }
    let last = parts.len() - 1;
    let mut out = Vec::new();
    for (i, (count, body)) in parts.into_iter().enumerate() {
        out.extend_from_slice(&encode_batch_part_binary(req_id, i < last, count, &body));
    }
    out
}

/// Encode an error envelope as complete wire bytes for `mode`.
pub fn encode_error_frame(mode: WireMode, req_id: Option<u64>, msg: &str) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_error(req_id, msg)),
        WireMode::Binary => encode_error_binary(req_id, msg),
    }
}

/// Encode the `points` response as complete wire bytes for `mode`.
pub fn encode_points_frame(mode: WireMode, req_id: Option<u64>, points: &[f64]) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_points(req_id, points)),
        WireMode::Binary => encode_points_binary(req_id, points),
    }
}

/// Encode the `shutting_down` acknowledgement as complete wire bytes.
pub fn encode_shutting_down_frame(mode: WireMode, req_id: Option<u64>) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_shutting_down(req_id)),
        WireMode::Binary => encode_shutting_down_binary(req_id),
    }
}

// -------------------------------------------------- degraded envelopes

/// The JSON degraded wrapper around an inner result object.
fn encode_degraded_json(req_id: Option<u64>, missing: &[String], result: Value) -> String {
    envelope(
        req_id,
        vec![
            ("type", "degraded".into()),
            (
                "missing",
                Value::Array(missing.iter().map(|m| m.as_str().into()).collect()),
            ),
            ("result", result),
        ],
    )
}

/// The binary degraded wrapper header: `type:u8 = degraded`, `count:u32`,
/// then the missing range strings; the caller appends the inner body.
fn put_degraded_header(b: &mut Vec<u8>, missing: &[String]) {
    b.push(REPLY_DEGRADED);
    b.extend_from_slice(&(missing.len() as u32).to_le_bytes());
    for m in missing {
        put_str(b, m);
    }
}

/// Encode a cluster scatter-gather reply that is missing one or more
/// shard ranges: the partial result from the live shards wrapped in a
/// `degraded` envelope naming the gaps (`missing`, as `"lo-hi@addr"`
/// strings). Partial data plus an explicit marker — never a silent gap.
///
/// Degraded envelopes never stream: an inner result past the frame cap
/// degrades to a correlated "response too large" error (the router's
/// merged results are bounded by `k`, so this is a hostile-input path,
/// not a normal one).
pub fn encode_degraded_response_frame(
    mode: WireMode,
    req_id: Option<u64>,
    missing: &[String],
    resp: &Response,
) -> Vec<u8> {
    if mode == WireMode::Json {
        if let Some(id) = json_unrepresentable_id(resp) {
            return encode_error_frame(mode, req_id, &json_id_error(id));
        }
    }
    let frame = match mode {
        WireMode::Json => json_frame(encode_degraded_json(
            req_id,
            missing,
            object(response_fields(resp)),
        )),
        WireMode::Binary => bin_frame(|b| {
            put_tag_and_req_id(b, STATUS_OK, req_id);
            put_degraded_header(b, missing);
            put_reply_body(b, resp);
        }),
    };
    if framed_payload_len(mode, &frame) > MAX_FRAME_BYTES {
        let payload = framed_payload_len(mode, &frame);
        return encode_error_frame(
            mode,
            req_id,
            &format!(
                "response too large ({payload} bytes > {MAX_FRAME_BYTES}-byte frame cap); \
                 request fewer results per op"
            ),
        );
    }
    frame
}

/// Encode a degraded batch reply: the per-item results from the live
/// shards (row order preserved) wrapped in one `degraded` envelope. Same
/// no-streaming rule as [`encode_degraded_response_frame`].
pub fn encode_degraded_batch_frame(
    mode: WireMode,
    req_id: Option<u64>,
    missing: &[String],
    items: &[Response],
) -> Vec<u8> {
    // per-item JSON-representability guard, same discipline as
    // [`encode_batch_response_frame`]: a full-width id fails only its slot
    let safe: Vec<Response>;
    let items = if mode == WireMode::Json
        && items.iter().any(|r| json_unrepresentable_id(r).is_some())
    {
        safe = items
            .iter()
            .map(|r| match json_unrepresentable_id(r) {
                Some(id) => Response::Error(json_id_error(id)),
                None => r.clone(),
            })
            .collect();
        &safe
    } else {
        items
    };
    let frame = match mode {
        WireMode::Json => {
            let results = items.iter().map(json_batch_item).collect();
            json_frame(encode_degraded_json(
                req_id,
                missing,
                object(vec![
                    ("type", "batch".into()),
                    ("results", Value::Array(results)),
                ]),
            ))
        }
        WireMode::Binary => bin_frame(|b| {
            put_tag_and_req_id(b, STATUS_OK, req_id);
            put_degraded_header(b, missing);
            b.push(REPLY_BATCH);
            b.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for resp in items {
                put_batch_item(b, resp);
            }
        }),
    };
    if framed_payload_len(mode, &frame) > MAX_FRAME_BYTES {
        let payload = framed_payload_len(mode, &frame);
        return encode_error_frame(
            mode,
            req_id,
            &format!(
                "response too large ({payload} bytes > {MAX_FRAME_BYTES}-byte frame cap); \
                 request fewer results per op"
            ),
        );
    }
    frame
}

// ---------------------------------------------------------------- client

/// A decoded server reply (the client-side mirror of
/// [`encode_response`] / [`encode_response_binary`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// `hash` result
    Signature(Vec<i32>),
    /// `insert` ack
    Inserted {
        /// inserted id
        id: u64,
    },
    /// `query` result
    Hits(Vec<Hit>),
    /// `remove` ack
    Removed {
        /// removed id
        id: u64,
    },
    /// `metrics` result (kept as a JSON object)
    Metrics(Value),
    /// `stats` result (kept as a JSON object; shape follows the
    /// requested detail and always carries a `"detail"` key)
    Stats(Value),
    /// `snapshot` ack
    Snapshotted {
        /// snapshot destination
        path: String,
        /// bytes written
        bytes: u64,
    },
    /// `ping` ack
    Pong {
        /// entries indexed server-side
        indexed: u64,
    },
    /// `points` result
    Points(Vec<f64>),
    /// `shutdown` ack
    ShuttingDown,
    /// `hash_batch` / `insert_batch` / `query_batch` result: one entry
    /// per request row, in row order — a typed reply or that row's
    /// server-side error
    Batch(Vec<Result<Reply, String>>),
    /// one continuation frame of a streamed (over-cap) batch reply; the
    /// client transports reassemble consecutive parts into a single
    /// [`Reply::Batch`], so callers above `server::client` never see
    /// this variant
    BatchPart {
        /// whether further parts of the same reply follow
        more: bool,
        /// this part's slice of the batch results, in row order
        items: Vec<Result<Reply, String>>,
    },
    /// a cluster scatter-gather reply served while one or more owning
    /// shard ranges were unavailable past the router's retry budget:
    /// `missing` names them (`"lo-hi@addr"`), `reply` carries the
    /// partial result assembled from the live shards — partial data
    /// plus an explicit gap marker, never a silent gap
    Degraded {
        /// the unavailable shard ranges this reply is missing
        missing: Vec<String>,
        /// the partial result from the shards that answered
        reply: Box<Reply>,
    },
    /// `migrate_pull` result: one ordered chunk of the source shard's
    /// store, `done` when no entries above the requested cursor remain
    Entries {
        /// the pulled entry records, id-ascending
        entries: Vec<EntryRecord>,
        /// whether the pull reached the end of the source store
        done: bool,
    },
    /// `entries_push` ack
    Ingested {
        /// entries applied (overwrite-idempotent)
        count: u64,
    },
}

/// Decode one JSON reply line into `(req_id, server result)`. The outer
/// `Err` is a protocol violation (unparseable frame); the inner
/// `Err(String)` is a well-formed server-side error envelope.
#[allow(clippy::type_complexity)]
pub fn decode_reply(line: &str) -> Result<(Option<u64>, Result<Reply, String>), String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad reply json: {e}"))?;
    let req_id = v.get("req_id").and_then(Value::as_u64);
    let ok = v
        .get("ok")
        .and_then(|b| match b {
            Value::Bool(b) => Some(*b),
            _ => None,
        })
        .ok_or("reply missing bool field `ok`")?;
    if !ok {
        let msg = v
            .get("error")
            .and_then(Value::as_str)
            .unwrap_or("unspecified server error")
            .to_string();
        return Ok((req_id, Err(msg)));
    }
    // the degraded wrapper is a top-level-only envelope: handled here,
    // unknown to [`decode_reply_value`], so a hostile nested wrapper
    // (inside a batch item or another wrapper) cannot recurse the decoder
    if v.get("type").and_then(Value::as_str) == Some("degraded") {
        let missing = need(&v, "missing")?
            .as_array()
            .ok_or("`missing` must be an array")?
            .iter()
            .map(|m| {
                m.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "`missing` must contain strings".to_string())
            })
            .collect::<Result<_, _>>()?;
        let inner = decode_reply_value(need(&v, "result")?, true)?;
        return Ok((
            req_id,
            Ok(Reply::Degraded {
                missing,
                reply: Box::new(inner),
            }),
        ));
    }
    Ok((req_id, Ok(decode_reply_value(&v, true)?)))
}

/// Decode the typed body of a successful JSON reply — shared by the
/// top-level envelope and batch items. `allow_batch` is false inside a
/// batch, so a malformed/hostile nested batch cannot recurse the
/// decoder.
fn decode_reply_value(v: &Value, allow_batch: bool) -> Result<Reply, String> {
    let ty = v
        .get("type")
        .and_then(Value::as_str)
        .ok_or("reply missing string field `type`")?;
    let reply = match ty {
        "signature" => Reply::Signature(
            need(v, "signature")?
                .as_array()
                .ok_or("`signature` must be an array")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .and_then(json_sig_i32)
                        .ok_or_else(|| "`signature` must contain i32 bucket ids".to_string())
                })
                .collect::<Result<_, _>>()?,
        ),
        "inserted" => Reply::Inserted {
            id: need(v, "id")?.as_u64().ok_or("`id` must be a u64")?,
        },
        "hits" => Reply::Hits(
            need(v, "hits")?
                .as_array()
                .ok_or("`hits` must be an array")?
                .iter()
                .map(|h| -> Result<Hit, String> {
                    Ok(Hit {
                        id: need(h, "id")?.as_u64().ok_or("hit `id` must be a u64")?,
                        distance: need(h, "distance")?
                            .as_f64()
                            .ok_or("hit `distance` must be a number")?,
                    })
                })
                .collect::<Result<_, _>>()?,
        ),
        "removed" => Reply::Removed {
            id: need(v, "id")?.as_u64().ok_or("`id` must be a u64")?,
        },
        "metrics" => Reply::Metrics(need(v, "metrics")?.clone()),
        "stats" => Reply::Stats(need(v, "stats")?.clone()),
        "snapshot" => Reply::Snapshotted {
            path: need(v, "path")?
                .as_str()
                .ok_or("`path` must be a string")?
                .to_string(),
            bytes: need(v, "bytes")?.as_u64().ok_or("`bytes` must be a u64")?,
        },
        "pong" => Reply::Pong {
            indexed: need(v, "indexed")?
                .as_u64()
                .ok_or("`indexed` must be a u64")?,
        },
        "points" => Reply::Points(
            need(v, "points")?
                .as_array()
                .ok_or("`points` must be an array")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| "`points` must contain numbers".to_string())
                })
                .collect::<Result<_, _>>()?,
        ),
        "shutting_down" => Reply::ShuttingDown,
        "entries" => Reply::Entries {
            done: match need(v, "done")? {
                Value::Bool(b) => *b,
                _ => return Err("`done` must be a bool".into()),
            },
            entries: entry_records_json(v, true)?,
        },
        "ingested" => Reply::Ingested {
            count: need(v, "count")?.as_u64().ok_or("`count` must be a u64")?,
        },
        "batch" if allow_batch => Reply::Batch(decode_batch_items_json(v)?),
        "batch_part" if allow_batch => Reply::BatchPart {
            more: match need(v, "more")? {
                Value::Bool(b) => *b,
                _ => return Err("`more` must be a bool".into()),
            },
            items: decode_batch_items_json(v)?,
        },
        other => return Err(format!("unknown reply type `{other}`")),
    };
    Ok(reply)
}

/// Decode the `results` array shared by `batch` and `batch_part` JSON
/// replies: one per-item envelope per entry, nested batches rejected.
fn decode_batch_items_json(v: &Value) -> Result<Vec<Result<Reply, String>>, String> {
    need(v, "results")?
        .as_array()
        .ok_or("`results` must be an array")?
        .iter()
        .map(|item| -> Result<Result<Reply, String>, String> {
            let ok = item
                .get("ok")
                .and_then(|b| match b {
                    Value::Bool(b) => Some(*b),
                    _ => None,
                })
                .ok_or("batch item missing bool field `ok`")?;
            if !ok {
                return Ok(Err(item
                    .get("error")
                    .and_then(Value::as_str)
                    .unwrap_or("unspecified server error")
                    .to_string()));
            }
            Ok(Ok(decode_reply_value(item, false)?))
        })
        .collect::<Result<_, _>>()
}

/// Decode one binary reply payload into `(req_id, server result)` — the
/// binary mirror of [`decode_reply`].
#[allow(clippy::type_complexity)]
pub fn decode_reply_binary(
    payload: &[u8],
) -> Result<(Option<u64>, Result<Reply, String>), String> {
    let mut rd = BinReader::new(payload);
    let status = rd.u8()?;
    let flags = rd.u8()?;
    if flags & !FLAG_REQ_ID != 0 {
        return Err(format!("unknown reply flags {flags:#04x}"));
    }
    let req_id = if flags & FLAG_REQ_ID != 0 {
        Some(rd.u64()?)
    } else {
        None
    };
    if status == STATUS_ERR {
        let msg = rd.str_()?.to_string();
        // optional machine-readable code byte (overloaded sheds append
        // [`ERR_CODE_OVERLOADED`]); skipped here — the stable `overloaded:`
        // message prefix classifies — so coded and plain errors both decode
        if !rd.finished() {
            let _ = rd.u8()?;
        }
        if !rd.finished() {
            return Err(format!(
                "{} trailing bytes after the reply body",
                rd.remaining()
            ));
        }
        return Ok((req_id, Err(msg)));
    }
    if status != STATUS_OK {
        return Err(format!("unknown reply status {status}"));
    }
    // the degraded wrapper is a top-level-only envelope: handled here,
    // unknown to [`decode_reply_body`], so a hostile nested wrapper
    // (inside a batch item or another wrapper) cannot recurse the decoder
    let reply = if rd.peek_u8() == Some(REPLY_DEGRADED) {
        let _ = rd.u8()?;
        let n = rd.u32()? as usize;
        // each missing range carries at least its length word
        if rd.remaining() < n.saturating_mul(4) {
            return Err(format!(
                "degraded reply declares {n} missing ranges, frame truncated"
            ));
        }
        let mut missing = Vec::with_capacity(n);
        for _ in 0..n {
            missing.push(rd.str_()?.to_string());
        }
        Reply::Degraded {
            missing,
            reply: Box::new(decode_reply_body(&mut rd, true)?),
        }
    } else {
        decode_reply_body(&mut rd, true)?
    };
    if !rd.finished() {
        return Err(format!(
            "{} trailing bytes after the reply body",
            rd.remaining()
        ));
    }
    Ok((req_id, Ok(reply)))
}

/// Decode one binary reply `type:u8` + body — shared by the top-level
/// frame and batch items. `allow_batch` is false inside a batch, so a
/// malformed/hostile nested batch cannot recurse the decoder.
fn decode_reply_body(rd: &mut BinReader<'_>, allow_batch: bool) -> Result<Reply, String> {
    let ty = rd.u8()?;
    let reply = match ty {
        REPLY_SIGNATURE => {
            let n = rd.u32()? as usize;
            if rd.remaining() < n.saturating_mul(4) {
                return Err(format!("signature declares {n} entries, frame truncated"));
            }
            let mut s = Vec::with_capacity(n);
            for _ in 0..n {
                s.push(rd.i32()?);
            }
            Reply::Signature(s)
        }
        REPLY_INSERTED => Reply::Inserted { id: rd.u64()? },
        REPLY_HITS => {
            let n = rd.u32()? as usize;
            if rd.remaining() < n.saturating_mul(16) {
                return Err(format!("hits declare {n} entries, frame truncated"));
            }
            let mut hits = Vec::with_capacity(n);
            for _ in 0..n {
                let id = rd.u64()?;
                let distance = rd.f64()?;
                hits.push(Hit { id, distance });
            }
            Reply::Hits(hits)
        }
        REPLY_REMOVED => Reply::Removed { id: rd.u64()? },
        REPLY_METRICS => Reply::Metrics(
            json::parse(rd.str_()?).map_err(|e| format!("bad metrics json: {e}"))?,
        ),
        REPLY_STATS => Reply::Stats(
            json::parse(rd.str_()?).map_err(|e| format!("bad stats json: {e}"))?,
        ),
        REPLY_SNAPSHOT => {
            let path = rd.str_()?.to_string();
            let bytes = rd.u64()?;
            Reply::Snapshotted { path, bytes }
        }
        REPLY_PONG => Reply::Pong { indexed: rd.u64()? },
        REPLY_POINTS => {
            let n = rd.u32()? as usize;
            if rd.remaining() < n.saturating_mul(8) {
                return Err(format!("points declare {n} entries, frame truncated"));
            }
            let mut p = Vec::with_capacity(n);
            for _ in 0..n {
                p.push(rd.f64()?);
            }
            Reply::Points(p)
        }
        REPLY_SHUTTING_DOWN => Reply::ShuttingDown,
        REPLY_ENTRIES => {
            let done = match rd.u8()? {
                0 => false,
                1 => true,
                other => return Err(format!("unknown entries done flag {other}")),
            };
            let n = rd.u32()? as usize;
            // each entry carries at least id + two length words
            if rd.remaining() < n.saturating_mul(16) {
                return Err(format!("entries declare {n} records, frame truncated"));
            }
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                entries.push(rd.entry_record()?);
            }
            Reply::Entries { entries, done }
        }
        REPLY_INGESTED => Reply::Ingested { count: rd.u64()? },
        REPLY_BATCH if allow_batch => Reply::Batch(decode_batch_items_binary(rd)?),
        REPLY_BATCH_PART if allow_batch => {
            let more = match rd.u8()? {
                0 => false,
                1 => true,
                other => return Err(format!("unknown batch_part more flag {other}")),
            };
            Reply::BatchPart {
                more,
                items: decode_batch_items_binary(rd)?,
            }
        }
        other => return Err(format!("unknown binary reply type {other}")),
    };
    Ok(reply)
}

/// Decode the `count:u32` + items block shared by `batch` and
/// `batch_part` binary replies, nested batches rejected.
fn decode_batch_items_binary(
    rd: &mut BinReader<'_>,
) -> Result<Vec<Result<Reply, String>>, String> {
    let n = rd.u32()? as usize;
    // each item carries at least a status byte + one body byte
    if rd.remaining() < n.saturating_mul(2) {
        return Err(format!("batch declares {n} items, frame truncated"));
    }
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let status = rd.u8()?;
        match status {
            STATUS_ERR => items.push(Err(rd.str_()?.to_string())),
            STATUS_OK => items.push(Ok(decode_reply_body(rd, false)?)),
            other => return Err(format!("unknown batch item status {other}")),
        }
    }
    Ok(items)
}

// ------------------------------------------------ JSON request builders

fn request_envelope(req_id: Option<u64>, mut fields: Vec<(&str, Value)>) -> String {
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

fn samples_value(samples: &[f32]) -> Value {
    Value::Array(samples.iter().map(|&x| Value::Number(x as f64)).collect())
}

/// Encode a `hash` request line (JSON).
pub fn encode_hash(req_id: Option<u64>, samples: &[f32]) -> String {
    request_envelope(
        req_id,
        vec![("op", "hash".into()), ("samples", samples_value(samples))],
    )
}

/// Encode an `insert` request line (JSON).
pub fn encode_insert(req_id: Option<u64>, id: u64, samples: &[f32]) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "insert".into()),
            ("id", (id as usize).into()),
            ("samples", samples_value(samples)),
        ],
    )
}

/// Encode a `query` request line (JSON).
pub fn encode_query(req_id: Option<u64>, samples: &[f32], k: usize) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "query".into()),
            ("samples", samples_value(samples)),
            ("k", k.into()),
        ],
    )
}

/// Encode a `remove` request line (JSON).
pub fn encode_remove(req_id: Option<u64>, id: u64) -> String {
    request_envelope(
        req_id,
        vec![("op", "remove".into()), ("id", (id as usize).into())],
    )
}

/// Encode a bare admin/transport request line (`metrics`, `ping`,
/// `points`, `shutdown`) (JSON).
pub fn encode_bare(req_id: Option<u64>, op: &str) -> String {
    request_envelope(req_id, vec![("op", op.into())])
}

/// Encode a `stats` request line (JSON).
pub fn encode_stats(req_id: Option<u64>, detail: StatsDetail) -> String {
    request_envelope(
        req_id,
        vec![("op", "stats".into()), ("detail", detail.as_str().into())],
    )
}

/// Encode a `snapshot` request line (JSON).
pub fn encode_snapshot(req_id: Option<u64>, path: &str) -> String {
    request_envelope(
        req_id,
        vec![("op", "snapshot".into()), ("path", path.into())],
    )
}

/// `rows.len()/dim` nested sample arrays from one contiguous buffer.
fn rows_value(rows: &[f32], dim: usize) -> Value {
    Value::Array(rows.chunks(dim.max(1)).map(samples_value).collect())
}

fn ids_value(ids: &[u64]) -> Value {
    Value::Array(ids.iter().map(|&id| Value::Number(id as f64)).collect())
}

/// Encode a `hash_batch` request line (JSON). `rows` is
/// `rows.len()/dim` contiguous sample rows.
pub fn encode_hash_batch(req_id: Option<u64>, rows: &[f32], dim: usize) -> String {
    request_envelope(
        req_id,
        vec![("op", "hash_batch".into()), ("rows", rows_value(rows, dim))],
    )
}

/// Encode an `insert_batch` request line (JSON). Ids ride JSON numbers,
/// so the 2^53 precision limit applies (use binary for full-width ids).
pub fn encode_insert_batch(
    req_id: Option<u64>,
    ids: &[u64],
    rows: &[f32],
    dim: usize,
) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "insert_batch".into()),
            ("ids", ids_value(ids)),
            ("rows", rows_value(rows, dim)),
        ],
    )
}

/// Encode a `query_batch` request line (JSON); one `k` for every row.
pub fn encode_query_batch(req_id: Option<u64>, rows: &[f32], dim: usize, k: usize) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "query_batch".into()),
            ("rows", rows_value(rows, dim)),
            ("k", k.into()),
        ],
    )
}

/// Encode a `migrate_pull` request line (JSON). `from_id` is inclusive;
/// ids above 2^53 need the binary format (JSON number carrier).
pub fn encode_migrate_pull(req_id: Option<u64>, from_id: u64, max: usize) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "migrate_pull".into()),
            ("from_id", (from_id as usize).into()),
            ("max", max.into()),
        ],
    )
}

/// Encode an `entries_push` request line (JSON). Ids ride JSON numbers,
/// so the 2^53 precision limit applies (use binary for full-width ids).
pub fn encode_entries_push(req_id: Option<u64>, entries: &[EntryRecord]) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "entries_push".into()),
            (
                "entries",
                Value::Array(entries.iter().map(entry_record_value).collect()),
            ),
        ],
    )
}

/// Encode an `entries_discard` request line (JSON).
pub fn encode_entries_discard(req_id: Option<u64>, ids: &[u64]) -> String {
    request_envelope(
        req_id,
        vec![("op", "entries_discard".into()), ("ids", ids_value(ids))],
    )
}

// ---------------------------------------------- binary request builders

/// Encode a `hash` request frame (binary).
pub fn encode_hash_binary(req_id: Option<u64>, samples: &[f32]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_HASH, req_id);
        put_samples(b, samples);
    })
}

/// Encode an `insert` request frame (binary; the id is a native `u64` —
/// no 2^53 precision limit).
pub fn encode_insert_binary(req_id: Option<u64>, id: u64, samples: &[f32]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_INSERT, req_id);
        b.extend_from_slice(&id.to_le_bytes());
        put_samples(b, samples);
    })
}

/// Encode a `query` request frame (binary). `k` travels as a `u64` so
/// no `usize` value can silently truncate on the wire (JSON/binary
/// parity: both formats carry the caller's `k` intact).
pub fn encode_query_binary(req_id: Option<u64>, samples: &[f32], k: usize) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_QUERY, req_id);
        put_samples(b, samples);
        b.extend_from_slice(&(k as u64).to_le_bytes());
    })
}

/// Encode a `remove` request frame (binary).
pub fn encode_remove_binary(req_id: Option<u64>, id: u64) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_REMOVE, req_id);
        b.extend_from_slice(&id.to_le_bytes());
    })
}

/// Encode a bare admin/transport request frame (binary). An unknown op
/// name encodes as the reserved tag 0, which the server answers with its
/// unknown-op error envelope — the same outcome the JSON format gives an
/// unknown `"op"` string, so the two modes never diverge into a panic.
pub fn encode_bare_binary(req_id: Option<u64>, op: &str) -> Vec<u8> {
    let tag = match op {
        "metrics" => OP_METRICS,
        "ping" => OP_PING,
        "points" => OP_POINTS,
        "shutdown" => OP_SHUTDOWN,
        _ => 0,
    };
    bin_frame(|b| put_tag_and_req_id(b, tag, req_id))
}

/// Encode a `stats` request frame (binary): op tag + detail byte.
pub fn encode_stats_binary(req_id: Option<u64>, detail: StatsDetail) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_STATS, req_id);
        b.push(detail.as_u8());
    })
}

/// Encode a `snapshot` request frame (binary).
pub fn encode_snapshot_binary(req_id: Option<u64>, path: &str) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_SNAPSHOT, req_id);
        put_str(b, path);
    })
}

/// `count:u32, dim:u32` + the contiguous `f32` rows of a batch body.
fn put_batch_rows(b: &mut Vec<u8>, rows: &[f32], dim: usize) {
    let count = if dim == 0 { 0 } else { rows.len() / dim };
    b.extend_from_slice(&(count as u32).to_le_bytes());
    b.extend_from_slice(&(dim as u32).to_le_bytes());
    for &s in rows {
        b.extend_from_slice(&s.to_le_bytes());
    }
}

/// Encode a `hash_batch` request frame (binary): op, count, dim, then
/// `count×dim` contiguous raw `f32` samples.
pub fn encode_hash_batch_binary(req_id: Option<u64>, rows: &[f32], dim: usize) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_HASH_BATCH, req_id);
        put_batch_rows(b, rows, dim);
    })
}

/// Encode an `insert_batch` request frame (binary): op, count, dim,
/// `count` native `u64` ids, then the contiguous rows. Full-width ids —
/// no 2^53 limit.
pub fn encode_insert_batch_binary(
    req_id: Option<u64>,
    ids: &[u64],
    rows: &[f32],
    dim: usize,
) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_INSERT_BATCH, req_id);
        b.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        b.extend_from_slice(&(dim as u32).to_le_bytes());
        for id in ids {
            b.extend_from_slice(&id.to_le_bytes());
        }
        for &s in rows {
            b.extend_from_slice(&s.to_le_bytes());
        }
    })
}

/// Encode a `query_batch` request frame (binary): op, count, dim, the
/// contiguous rows, then one `k:u64` for every row.
pub fn encode_query_batch_binary(
    req_id: Option<u64>,
    rows: &[f32],
    dim: usize,
    k: usize,
) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_QUERY_BATCH, req_id);
        put_batch_rows(b, rows, dim);
        b.extend_from_slice(&(k as u64).to_le_bytes());
    })
}

/// Encode a `migrate_pull` request frame (binary): op, `from_id:u64`
/// (inclusive), `max:u64`. Full-width cursor — no 2^53 limit.
pub fn encode_migrate_pull_binary(req_id: Option<u64>, from_id: u64, max: usize) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_MIGRATE_PULL, req_id);
        b.extend_from_slice(&from_id.to_le_bytes());
        b.extend_from_slice(&(max as u64).to_le_bytes());
    })
}

/// Encode an `entries_push` request frame (binary): op, `count:u32`,
/// then `count` entry records in the [`put_entry_record`] layout.
pub fn encode_entries_push_binary(req_id: Option<u64>, entries: &[EntryRecord]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_ENTRIES_PUSH, req_id);
        b.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for e in entries {
            put_entry_record(b, e);
        }
    })
}

/// Encode an `entries_discard` request frame (binary): op, `count:u32`,
/// then `count` native `u64` ids.
pub fn encode_entries_discard_binary(req_id: Option<u64>, ids: &[u64]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_ENTRIES_DISCARD, req_id);
        b.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in ids {
            b.extend_from_slice(&id.to_le_bytes());
        }
    })
}

// --------------------------------------- mode-dispatch request builders

/// Encode a `hash` request as complete wire bytes for `mode`.
pub fn encode_hash_frame(mode: WireMode, req_id: Option<u64>, samples: &[f32]) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_hash(req_id, samples)),
        WireMode::Binary => encode_hash_binary(req_id, samples),
    }
}

/// Encode an `insert` request as complete wire bytes for `mode`.
pub fn encode_insert_frame(
    mode: WireMode,
    req_id: Option<u64>,
    id: u64,
    samples: &[f32],
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_insert(req_id, id, samples)),
        WireMode::Binary => encode_insert_binary(req_id, id, samples),
    }
}

/// Encode a `query` request as complete wire bytes for `mode`.
pub fn encode_query_frame(
    mode: WireMode,
    req_id: Option<u64>,
    samples: &[f32],
    k: usize,
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_query(req_id, samples, k)),
        WireMode::Binary => encode_query_binary(req_id, samples, k),
    }
}

/// Encode a `remove` request as complete wire bytes for `mode`.
pub fn encode_remove_frame(mode: WireMode, req_id: Option<u64>, id: u64) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_remove(req_id, id)),
        WireMode::Binary => encode_remove_binary(req_id, id),
    }
}

/// Encode a bare admin/transport request as complete wire bytes.
pub fn encode_bare_frame(mode: WireMode, req_id: Option<u64>, op: &str) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_bare(req_id, op)),
        WireMode::Binary => encode_bare_binary(req_id, op),
    }
}

/// Encode a `stats` request as complete wire bytes for `mode`.
pub fn encode_stats_frame(mode: WireMode, req_id: Option<u64>, detail: StatsDetail) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_stats(req_id, detail)),
        WireMode::Binary => encode_stats_binary(req_id, detail),
    }
}

/// Encode a `snapshot` request as complete wire bytes for `mode`.
pub fn encode_snapshot_frame(mode: WireMode, req_id: Option<u64>, path: &str) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_snapshot(req_id, path)),
        WireMode::Binary => encode_snapshot_binary(req_id, path),
    }
}

/// Encode a `hash_batch` request as complete wire bytes for `mode`.
pub fn encode_hash_batch_frame(
    mode: WireMode,
    req_id: Option<u64>,
    rows: &[f32],
    dim: usize,
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_hash_batch(req_id, rows, dim)),
        WireMode::Binary => encode_hash_batch_binary(req_id, rows, dim),
    }
}

/// Encode an `insert_batch` request as complete wire bytes for `mode`.
pub fn encode_insert_batch_frame(
    mode: WireMode,
    req_id: Option<u64>,
    ids: &[u64],
    rows: &[f32],
    dim: usize,
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_insert_batch(req_id, ids, rows, dim)),
        WireMode::Binary => encode_insert_batch_binary(req_id, ids, rows, dim),
    }
}

/// Encode a `migrate_pull` request as complete wire bytes for `mode`.
pub fn encode_migrate_pull_frame(
    mode: WireMode,
    req_id: Option<u64>,
    from_id: u64,
    max: usize,
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_migrate_pull(req_id, from_id, max)),
        WireMode::Binary => encode_migrate_pull_binary(req_id, from_id, max),
    }
}

/// Encode an `entries_push` request as complete wire bytes for `mode`.
pub fn encode_entries_push_frame(
    mode: WireMode,
    req_id: Option<u64>,
    entries: &[EntryRecord],
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_entries_push(req_id, entries)),
        WireMode::Binary => encode_entries_push_binary(req_id, entries),
    }
}

/// Encode an `entries_discard` request as complete wire bytes for `mode`.
pub fn encode_entries_discard_frame(mode: WireMode, req_id: Option<u64>, ids: &[u64]) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_entries_discard(req_id, ids)),
        WireMode::Binary => encode_entries_discard_binary(req_id, ids),
    }
}

/// Encode a `query_batch` request as complete wire bytes for `mode`.
pub fn encode_query_batch_frame(
    mode: WireMode,
    req_id: Option<u64>,
    rows: &[f32],
    dim: usize,
    k: usize,
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_query_batch(req_id, rows, dim, k)),
        WireMode::Binary => encode_query_batch_binary(req_id, rows, dim, k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SigView;

    #[test]
    fn request_roundtrips() {
        let line = encode_insert(Some(7), 42, &[0.5, -1.25]);
        let req = parse_request(&line).unwrap();
        assert_eq!(req.req_id, Some(7));
        match req.body {
            RequestBody::Op(Op::Insert { id, samples }) => {
                assert_eq!(id, 42);
                assert_eq!(samples, vec![0.5, -1.25]);
            }
            other => panic!("unexpected {other:?}"),
        }

        let req = parse_request(&encode_query(None, &[1.0], 5)).unwrap();
        assert_eq!(req.req_id, None);
        match req.body {
            RequestBody::Op(Op::Query { k, .. }) => assert_eq!(k, 5),
            other => panic!("unexpected {other:?}"),
        }

        match parse_request(&encode_bare(Some(1), "ping")).unwrap().body {
            RequestBody::Op(Op::Ping) => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_bare(None, "shutdown")).unwrap().body {
            RequestBody::Shutdown => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_snapshot(None, "/tmp/x")).unwrap().body {
            RequestBody::Op(Op::Snapshot { path }) => assert_eq!(path, "/tmp/x"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{}").is_err());
        assert!(parse_request(r#"{"op":"teleport"}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","id":1}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","id":-1,"samples":[]}"#).is_err());
        assert!(parse_request(r#"{"op":"query","samples":["x"],"k":1}"#).is_err());
    }

    #[test]
    fn non_finite_samples_rejected_by_both_decoders() {
        // JSON: 1e400 parses as f64 +inf; 1e39 is a finite f64 that
        // overflows f32 to +inf — both must be refused
        for frame in [
            r#"{"op":"hash","samples":[1e400]}"#,
            r#"{"op":"hash","samples":[1e39]}"#,
            r#"{"op":"hash","samples":[-1e39]}"#,
            r#"{"op":"insert","id":1,"samples":[0.5,1e400]}"#,
            r#"{"op":"query","samples":[1e39],"k":1}"#,
        ] {
            let e = parse_request(frame).unwrap_err();
            assert!(e.msg.contains("finite"), "{frame}: {e}");
        }
        // a large-but-representable value still passes
        assert!(parse_request(r#"{"op":"hash","samples":[1e38]}"#).is_ok());

        // binary: raw NaN / inf bits in the sample block
        for bits in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut frame = encode_hash_binary(Some(3), &[0.5, 0.5]);
            // overwrite the second sample's 4 bytes (layout: 4 len + 1 op
            // + 1 flags + 8 req_id + 4 count + 4 first sample)
            frame[22..26].copy_from_slice(&bits.to_le_bytes());
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            let e = parse_request_binary(&frame[4..consumed]).unwrap_err();
            assert_eq!(e.req_id, Some(3), "error must still correlate");
            assert!(e.msg.contains("finite"), "{e}");
        }
    }

    #[test]
    fn parse_errors_recover_req_id_when_json_is_valid() {
        // field-validation failures keep the correlation id…
        let e = parse_request(r#"{"op":"teleport","req_id":7}"#).unwrap_err();
        assert_eq!(e.req_id, Some(7));
        assert!(e.msg.contains("unknown op"), "{e}");
        let e = parse_request(r#"{"op":"insert","id":1,"req_id":9}"#).unwrap_err();
        assert_eq!(e.req_id, Some(9));
        assert!(e.msg.contains("missing field"), "{e}");
        // …but a frame that is not JSON at all has none to recover
        let e = parse_request("not json").unwrap_err();
        assert_eq!(e.req_id, None);
    }

    #[test]
    fn binary_request_roundtrips() {
        // every op through encode → frame split → decode
        let frames: Vec<(Vec<u8>, &str)> = vec![
            (encode_hash_binary(Some(1), &[0.5, -1.25]), "hash"),
            (encode_insert_binary(Some(2), 42, &[1.0]), "insert"),
            (encode_query_binary(None, &[0.25], 7), "query"),
            (encode_remove_binary(Some(4), 9), "remove"),
            (encode_bare_binary(Some(5), "metrics"), "metrics"),
            (encode_snapshot_binary(None, "/tmp/s.flsh"), "snapshot"),
            (encode_bare_binary(Some(7), "ping"), "ping"),
            (encode_bare_binary(None, "points"), "points"),
            (encode_bare_binary(Some(9), "shutdown"), "shutdown"),
        ];
        for (frame, label) in frames {
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len(), "{label}: frame fully framed");
            let req = parse_request_binary(&frame[4..consumed]).unwrap();
            match (label, &req.body) {
                ("hash", RequestBody::Op(Op::Hash { samples })) => {
                    assert_eq!(req.req_id, Some(1));
                    assert_eq!(samples, &vec![0.5, -1.25]);
                }
                ("insert", RequestBody::Op(Op::Insert { id, samples })) => {
                    assert_eq!(req.req_id, Some(2));
                    assert_eq!(*id, 42);
                    assert_eq!(samples, &vec![1.0]);
                }
                ("query", RequestBody::Op(Op::Query { samples, k })) => {
                    assert_eq!(req.req_id, None);
                    assert_eq!(samples, &vec![0.25]);
                    assert_eq!(*k, 7);
                }
                ("remove", RequestBody::Op(Op::Remove { id })) => assert_eq!(*id, 9),
                ("metrics", RequestBody::Op(Op::Metrics)) => {}
                ("snapshot", RequestBody::Op(Op::Snapshot { path })) => {
                    assert_eq!(path, "/tmp/s.flsh")
                }
                ("ping", RequestBody::Op(Op::Ping)) => {}
                ("points", RequestBody::Points) => {}
                ("shutdown", RequestBody::Shutdown) => {}
                (label, other) => panic!("{label}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn binary_ids_above_2_53_survive_where_json_rejects() {
        let big = (1u64 << 60) + 12345; // unrepresentable in f64 exactly
        let frame = encode_insert_binary(Some(1), big, &[0.5]);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::Insert { id, .. }) => assert_eq!(id, big),
            other => panic!("unexpected {other:?}"),
        }
        // the JSON carrier cannot: as_u64 refuses values above 2^53
        let line = format!(r#"{{"op":"remove","id":{big}}}"#);
        assert!(parse_request(&line).is_err());
        // …and the binary remove roundtrips it
        let frame = encode_remove_binary(None, big);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::Remove { id }) => assert_eq!(id, big),
            other => panic!("unexpected {other:?}"),
        }
        // response direction too
        let frame = encode_response_binary(Some(2), &Response::Inserted { id: big });
        let (rid, reply) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(rid, Some(2));
        assert_eq!(reply.unwrap(), Reply::Inserted { id: big });
    }

    #[test]
    fn binary_unknown_bare_op_gets_server_side_error_not_panic() {
        // parity with JSON: an unknown bare-op name reaches the server
        // and comes back as a typed error envelope in both formats
        let frame = encode_bare_binary(Some(9), "status");
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        let e = parse_request_binary(&frame[4..consumed]).unwrap_err();
        assert_eq!(e.req_id, Some(9));
        assert!(e.msg.contains("unknown binary op tag"), "{e}");
    }

    #[test]
    fn binary_query_k_does_not_truncate() {
        // k rides a u64 on the binary wire: a value past u32::MAX must
        // arrive intact, matching the JSON format's behavior
        let big_k = (1usize << 33) + 5;
        let frame = encode_query_binary(Some(1), &[0.5], big_k);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::Query { k, .. }) => assert_eq!(k, big_k),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn binary_request_errors_are_typed_and_correlated() {
        // unknown op tag, with req_id still recovered
        let frame = bin_frame(|b| put_tag_and_req_id(b, 200, Some(17)));
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(17));
        assert!(e.msg.contains("unknown binary op tag"), "{e}");
        // truncated body: insert with no id
        let frame = bin_frame(|b| put_tag_and_req_id(b, OP_INSERT, Some(3)));
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(3));
        assert!(e.msg.contains("truncated"), "{e}");
        // declared sample count larger than the payload
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, OP_HASH, Some(4));
            b.extend_from_slice(&1000u32.to_le_bytes());
            b.extend_from_slice(&0.5f32.to_le_bytes());
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(4));
        assert!(e.msg.contains("1000 samples"), "{e}");
        // trailing garbage after a well-formed body
        let mut frame = encode_remove_binary(Some(5), 1);
        frame.extend_from_slice(b"junk");
        let len = (frame.len() - 4) as u32;
        frame[..4].copy_from_slice(&len.to_le_bytes());
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(5));
        assert!(e.msg.contains("trailing"), "{e}");
        // unknown header flags
        let frame = bin_frame(|b| {
            b.push(OP_PING);
            b.push(0x80);
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert!(e.msg.contains("flags"), "{e}");
        // empty payload
        let e = parse_request_binary(&[]).unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
    }

    #[test]
    fn negotiation_and_framing() {
        assert_eq!(negotiate(b""), Negotiation::NeedMore);
        assert_eq!(negotiate(b"F"), Negotiation::NeedMore);
        assert_eq!(negotiate(b"FBIN"), Negotiation::NeedMore);
        assert_eq!(negotiate(b"FBIN1"), Negotiation::Binary);
        assert_eq!(negotiate(b"FBIN1\x01\x02"), Negotiation::Binary);
        assert_eq!(negotiate(b"{\"op\":\"ping\"}"), Negotiation::Json);
        assert_eq!(negotiate(b"FBINX"), Negotiation::Json);
        assert_eq!(negotiate(b"false"), Negotiation::Json);

        // split: need-more, complete, oversized
        assert_eq!(split_binary_frame(&[1, 0]).unwrap(), None);
        assert_eq!(split_binary_frame(&[2, 0, 0, 0, 9]).unwrap(), None);
        assert_eq!(split_binary_frame(&[2, 0, 0, 0, 9, 9]).unwrap(), Some(6));
        assert_eq!(
            split_binary_frame(&[2, 0, 0, 0, 9, 9, 77]).unwrap(),
            Some(6),
            "extra buffered bytes belong to the next frame"
        );
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        let e = split_binary_frame(&huge).unwrap_err();
        assert!(e.contains("cap"), "{e}");
    }

    #[test]
    fn stats_requests_roundtrip_both_formats() {
        for d in [
            StatsDetail::Summary,
            StatsDetail::Stages,
            StatsDetail::Index,
            StatsDetail::Slow,
            StatsDetail::Cluster,
        ] {
            let line = encode_stats(Some(3), d);
            let req = parse_request(&line).unwrap();
            assert_eq!(req.req_id, Some(3));
            match req.body {
                RequestBody::Op(Op::Stats { detail }) => assert_eq!(detail, d),
                other => panic!("unexpected {other:?}"),
            }
            let frame = encode_stats_binary(Some(4), d);
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len());
            let req = parse_request_binary(&frame[4..consumed]).unwrap();
            assert_eq!(req.req_id, Some(4));
            match req.body {
                RequestBody::Op(Op::Stats { detail }) => assert_eq!(detail, d),
                other => panic!("unexpected {other:?}"),
            }
        }
        // the detail field is optional on the JSON wire
        match parse_request(r#"{"op":"stats"}"#).unwrap().body {
            RequestBody::Op(Op::Stats { detail }) => {
                assert_eq!(detail, StatsDetail::Summary)
            }
            other => panic!("unexpected {other:?}"),
        }
        // unknown details are correlated per-request errors, not typos
        // silently mapped to a default view
        let e = parse_request(r#"{"op":"stats","detail":"everything","req_id":9}"#)
            .unwrap_err();
        assert_eq!(e.req_id, Some(9));
        assert!(e.msg.contains("stats detail"), "{e}");
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, OP_STATS, Some(10));
            b.push(9);
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(10));
        assert!(e.msg.contains("stats detail"), "{e}");
    }

    fn response_cases() -> Vec<Response> {
        vec![
            Response::Signature(SigView::from_vec(vec![-3, 0, 7])),
            Response::Inserted { id: 9 },
            Response::Hits(vec![Hit {
                id: 4,
                distance: 0.125,
            }]),
            Response::Removed { id: 2 },
            Response::Pong { indexed: 11 },
            Response::Snapshotted {
                path: "/tmp/s.flsh".into(),
                bytes: 640,
            },
            Response::Stats(object(vec![
                ("detail", "summary".into()),
                ("entries", 12.0.into()),
            ])),
            Response::Entries {
                entries: vec![
                    EntryRecord {
                        id: 3,
                        emb: vec![0.25, -1.5],
                        sig: vec![7, -2, 0],
                    },
                    EntryRecord {
                        id: 9,
                        emb: vec![2.0, 4.0],
                        sig: vec![1, 1, 1],
                    },
                ],
                done: false,
            },
            Response::Entries {
                entries: Vec::new(),
                done: true,
            },
            Response::Ingested { count: 17 },
        ]
    }

    fn check_reply(decoded: Reply, want: &Response) {
        match (decoded, want) {
            (Reply::Signature(s), Response::Signature(want)) => {
                assert_eq!(s.as_slice(), want.as_slice())
            }
            (Reply::Inserted { id }, Response::Inserted { id: want }) => {
                assert_eq!(id, *want)
            }
            (Reply::Hits(h), Response::Hits(want)) => assert_eq!(&h, want),
            (Reply::Removed { id }, Response::Removed { id: want }) => assert_eq!(id, *want),
            (Reply::Pong { indexed }, Response::Pong { indexed: want }) => {
                assert_eq!(indexed, *want)
            }
            (
                Reply::Snapshotted { path, bytes },
                Response::Snapshotted {
                    path: wp,
                    bytes: wb,
                },
            ) => {
                assert_eq!(&path, wp);
                assert_eq!(bytes, *wb);
            }
            (Reply::Stats(v), Response::Stats(want)) => assert_eq!(&v, want),
            (
                Reply::Entries { entries, done },
                Response::Entries {
                    entries: we,
                    done: wd,
                },
            ) => {
                assert_eq!(&entries, we);
                assert_eq!(done, *wd);
            }
            (Reply::Ingested { count }, Response::Ingested { count: want }) => {
                assert_eq!(count, *want)
            }
            (got, want) => panic!("mismatch: {got:?} vs {want:?}"),
        }
    }

    #[test]
    fn response_roundtrips() {
        for resp in response_cases() {
            let line = encode_response(Some(3), &resp);
            let (req_id, decoded) = decode_reply(&line).unwrap();
            assert_eq!(req_id, Some(3));
            check_reply(decoded.unwrap(), &resp);
        }
    }

    #[test]
    fn binary_response_roundtrips() {
        for resp in response_cases() {
            let frame = encode_response_binary(Some(3), &resp);
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len());
            let (req_id, decoded) = decode_reply_binary(&frame[4..consumed]).unwrap();
            assert_eq!(req_id, Some(3), "{resp:?}");
            check_reply(decoded.unwrap(), &resp);
        }
        // without a req_id
        let frame = encode_response_binary(None, &Response::Pong { indexed: 5 });
        let (req_id, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(req_id, None);
        assert_eq!(decoded.unwrap(), Reply::Pong { indexed: 5 });
    }

    #[test]
    fn error_envelope_roundtrips() {
        let line = encode_response(Some(5), &Response::Error("duplicate id 7".into()));
        let (req_id, decoded) = decode_reply(&line).unwrap();
        assert_eq!(req_id, Some(5));
        assert_eq!(decoded.unwrap_err(), "duplicate id 7");
        let (_, decoded) = decode_reply(&encode_error(None, "bad request")).unwrap();
        assert!(decoded.unwrap_err().contains("bad request"));

        // binary error envelopes carry the message and the correlation id
        let frame = encode_response_binary(Some(6), &Response::Error("duplicate id 8".into()));
        let (req_id, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(req_id, Some(6));
        assert_eq!(decoded.unwrap_err(), "duplicate id 8");
    }

    #[test]
    fn points_and_shutdown_roundtrip() {
        let (_, decoded) = decode_reply(&encode_points(None, &[0.25, 0.75])).unwrap();
        assert_eq!(decoded.unwrap(), Reply::Points(vec![0.25, 0.75]));
        let (_, decoded) = decode_reply(&encode_shutting_down(Some(1))).unwrap();
        assert_eq!(decoded.unwrap(), Reply::ShuttingDown);

        let frame = encode_points_binary(Some(2), &[0.25, 0.75]);
        let (rid, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(rid, Some(2));
        assert_eq!(decoded.unwrap(), Reply::Points(vec![0.25, 0.75]));
        let frame = encode_shutting_down_binary(None);
        let (_, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(decoded.unwrap(), Reply::ShuttingDown);
    }

    #[test]
    fn metrics_reply_carries_object() {
        let m = crate::coordinator::ServiceMetrics::new();
        let line = encode_response(None, &Response::Metrics(m.snapshot()));
        let (_, decoded) = decode_reply(&line).unwrap();
        match decoded.unwrap() {
            Reply::Metrics(v) => assert_eq!(v.get("requests").unwrap().as_usize(), Some(0)),
            other => panic!("unexpected {other:?}"),
        }
        let frame = encode_response_binary(Some(1), &Response::Metrics(m.snapshot()));
        let (_, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        match decoded.unwrap() {
            Reply::Metrics(v) => assert_eq!(v.get("requests").unwrap().as_usize(), Some(0)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn oversized_response_degrades_to_correlated_error() {
        // a hits payload past the frame cap (8 MiB): JSON needs ~26 bytes
        // per hit, binary exactly 16 — 600k hits overflows both
        let hits: Vec<Hit> = (0..600_000)
            .map(|i| Hit {
                id: i,
                distance: i as f64 * 0.001,
            })
            .collect();
        let resp = Response::Hits(hits);
        for mode in [WireMode::Json, WireMode::Binary] {
            let frame = encode_response_frame(mode, Some(42), &resp);
            assert!(
                framed_payload_len(mode, &frame) <= MAX_FRAME_BYTES,
                "{mode:?}: replacement frame must itself fit"
            );
            let (req_id, decoded) = match mode {
                WireMode::Json => {
                    decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap()
                }
                WireMode::Binary => decode_reply_binary(&frame[4..]).unwrap(),
            };
            assert_eq!(req_id, Some(42), "{mode:?}: error must correlate");
            let msg = decoded.unwrap_err();
            assert!(msg.contains("response too large"), "{mode:?}: {msg}");
        }
        // a normal-sized response is passed through untouched
        let small = encode_response_frame(WireMode::Json, Some(1), &Response::Pong { indexed: 3 });
        let (_, decoded) = decode_reply(std::str::from_utf8(&small).unwrap()).unwrap();
        assert_eq!(decoded.unwrap(), Reply::Pong { indexed: 3 });
    }

    #[test]
    fn frame_builders_match_modes() {
        // JSON frame bytes end in newline and parse as the bare line
        let f = encode_hash_frame(WireMode::Json, Some(1), &[0.5]);
        assert_eq!(*f.last().unwrap(), b'\n');
        assert!(parse_request(std::str::from_utf8(&f).unwrap().trim_end()).is_ok());
        // binary frame bytes split and parse
        let f = encode_hash_frame(WireMode::Binary, Some(1), &[0.5]);
        let consumed = split_binary_frame(&f).unwrap().unwrap();
        assert!(parse_request_binary(&f[4..consumed]).is_ok());
        // wire-cost sanity: at dim 256 the binary hash frame is much
        // smaller than the JSON one (the whole point of FBIN1)
        let row: Vec<f32> = (0..256).map(|i| (i as f32) * 0.001 - 0.1).collect();
        let j = encode_hash_frame(WireMode::Json, Some(1), &row).len();
        let b = encode_hash_frame(WireMode::Binary, Some(1), &row).len();
        assert!(b < j / 2, "binary {b} bytes vs json {j} bytes");
    }

    #[test]
    fn wire_mode_parses() {
        assert_eq!(WireMode::parse("json"), Some(WireMode::Json));
        assert_eq!(WireMode::parse("binary"), Some(WireMode::Binary));
        assert_eq!(WireMode::parse("fbin1"), Some(WireMode::Binary));
        assert_eq!(WireMode::parse("carrier-pigeon"), None);
        assert_eq!(WireMode::Json.as_str(), "json");
        assert_eq!(WireMode::Binary.as_str(), "binary");
    }

    /// Drain every pending frame/fatal out of a framer.
    fn drain(f: &mut Framer) -> (Vec<(WireMode, Vec<u8>)>, Option<String>) {
        let mut frames = Vec::new();
        loop {
            match f.next() {
                FramerStep::Frame { wire, payload } => frames.push((wire, payload.to_vec())),
                FramerStep::Fatal { msg, .. } => return (frames, Some(msg)),
                FramerStep::Pending => return (frames, None),
            }
        }
    }

    #[test]
    fn framer_json_basics() {
        let mut f = Framer::new();
        f.push(b"{\"op\":\"ping\"}\r\n{\"op\":\"points\"}\n tail");
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].0, WireMode::Json);
        assert_eq!(frames[0].1, b"{\"op\":\"ping\"}".to_vec(), "CR stripped");
        assert_eq!(frames[1].1, b"{\"op\":\"points\"}".to_vec());
        assert_eq!(f.negotiated(), Some(WireMode::Json));
        assert_eq!(f.buffered(), 5);
        // the unterminated tail becomes a frame only at EOF
        f.push_eof();
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(frames, vec![(WireMode::Json, b" tail".to_vec())]);
        assert_eq!(drain(&mut f).0, vec![]);
    }

    #[test]
    fn framer_binary_basics() {
        let mut f = Framer::new();
        let mut stream = BINARY_MAGIC.to_vec();
        stream.extend_from_slice(&encode_bare_binary(Some(1), "ping"));
        stream.extend_from_slice(&encode_hash_binary(Some(2), &[0.5]));
        f.push(&stream);
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(frames.len(), 2);
        assert!(frames.iter().all(|(w, _)| *w == WireMode::Binary));
        assert_eq!(f.negotiated(), Some(WireMode::Binary));
        // payloads parse back
        let req = parse_request_binary(&frames[1].1).unwrap();
        assert_eq!(req.req_id, Some(2));
        // a partial frame at EOF is fatal
        f.push(&[3, 0, 0, 0, 9]);
        assert_eq!(drain(&mut f), (vec![], None));
        f.push_eof();
        let (frames, fatal) = drain(&mut f);
        assert!(frames.is_empty());
        assert!(fatal.unwrap().contains("truncated"), "binary eof tail");
    }

    #[test]
    fn framer_negotiation_edges() {
        // proper magic prefix: stays pending until decidable
        let mut f = Framer::new();
        f.push(b"FBIN");
        assert_eq!(drain(&mut f), (vec![], None));
        assert_eq!(f.negotiated(), None);
        assert_eq!(f.wire_mode(), WireMode::Json, "probe answers default to JSON");
        f.push(b"1");
        let _ = drain(&mut f);
        assert_eq!(f.negotiated(), Some(WireMode::Binary));

        // near-magic garbage falls through to JSON
        let mut f = Framer::new();
        f.push(b"FBINX junk\n");
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(frames, vec![(WireMode::Json, b"FBINX junk".to_vec())]);

        // a partial magic cut off by EOF is a JSON tail frame
        let mut f = Framer::new();
        f.push(b"FBI");
        f.push_eof();
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(frames, vec![(WireMode::Json, b"FBI".to_vec())]);
    }

    #[test]
    fn framer_fatal_paths_poison() {
        // oversized unterminated JSON line
        let mut f = Framer::new();
        f.push(&vec![b'a'; MAX_LINE_BYTES + 2]);
        let (frames, fatal) = drain(&mut f);
        assert!(frames.is_empty());
        assert!(fatal.unwrap().contains("too long"));
        assert!(f.is_fatal());
        f.push(b"{\"op\":\"ping\"}\n");
        assert_eq!(drain(&mut f), (vec![], None), "poisoned framer yields nothing");

        // oversized declared binary length
        let mut f = Framer::new();
        f.push(BINARY_MAGIC);
        f.push(&((MAX_FRAME_BYTES + 1) as u32).to_le_bytes());
        let (frames, fatal) = drain(&mut f);
        assert!(frames.is_empty());
        assert!(fatal.unwrap().contains("cap"));
    }

    #[test]
    fn framer_compact_preserves_state() {
        let mut f = Framer::new();
        let frame = encode_hash_binary(Some(7), &[0.25, 0.5]);
        f.push(BINARY_MAGIC);
        f.push(&frame[..frame.len() - 3]);
        let _ = drain(&mut f);
        f.compact();
        f.push(&frame[frame.len() - 3..]);
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(frames.len(), 1);
        let req = parse_request_binary(&frames[0].1).unwrap();
        assert_eq!(req.req_id, Some(7));
    }

    #[test]
    fn framer_compact_after_complete_binary_frames() {
        // regression: on a binary connection the JSON scan offset lags
        // at the negotiation point while frames advance the consumed
        // prefix past it — compact() after a *completed* frame must not
        // underflow (debug builds panic on a bare subtraction)
        let mut f = Framer::new();
        f.push(BINARY_MAGIC);
        f.push(&encode_bare_binary(Some(1), "ping"));
        let (frames, fatal) = drain(&mut f);
        assert_eq!((frames.len(), fatal), (1, None));
        f.compact();
        assert_eq!(f.buffered(), 0);
        // the compacted framer keeps decoding
        f.push(&encode_bare_binary(Some(2), "ping"));
        let (frames, fatal) = drain(&mut f);
        assert_eq!(fatal, None);
        assert_eq!(parse_request_binary(&frames[0].1).unwrap().req_id, Some(2));
        f.compact();
        f.push(&encode_remove_binary(Some(3), 4));
        let (frames, _) = drain(&mut f);
        assert_eq!(frames.len(), 1);
    }

    #[test]
    fn read_frame_mirrors_framer() {
        use std::io::BufReader;
        // JSON replies, then EOF
        let mut bytes = encode_response(Some(1), &Response::Pong { indexed: 2 }).into_bytes();
        bytes.push(b'\n');
        let mut r = BufReader::new(bytes.as_slice());
        let line = read_frame(&mut r, WireMode::Json).unwrap().unwrap();
        let (rid, reply) = decode_reply(std::str::from_utf8(&line).unwrap()).unwrap();
        assert_eq!(rid, Some(1));
        assert_eq!(reply.unwrap(), Reply::Pong { indexed: 2 });
        assert_eq!(read_frame(&mut r, WireMode::Json).unwrap(), None);

        // binary replies, then EOF
        let frame = encode_response_binary(Some(3), &Response::Inserted { id: 4 });
        let mut r = BufReader::new(frame.as_slice());
        let payload = read_frame(&mut r, WireMode::Binary).unwrap().unwrap();
        let (rid, reply) = decode_reply_binary(&payload).unwrap();
        assert_eq!(rid, Some(3));
        assert_eq!(reply.unwrap(), Reply::Inserted { id: 4 });
        assert_eq!(read_frame(&mut r, WireMode::Binary).unwrap(), None);

        // an over-cap declared length is InvalidData
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        let mut r = BufReader::new(huge.as_slice());
        let e = read_frame(&mut r, WireMode::Binary).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn batch_requests_roundtrip_both_formats() {
        let rows: Vec<f32> = vec![0.5, -1.0, 0.25, 2.0]; // 2 rows, dim 2
        let ids = [9u64, (1 << 60) + 3];
        for mode in [WireMode::Json, WireMode::Binary] {
            let parse = |frame: Vec<u8>| -> Request {
                match mode {
                    WireMode::Json => {
                        parse_request(std::str::from_utf8(&frame).unwrap().trim_end()).unwrap()
                    }
                    WireMode::Binary => {
                        let consumed = split_binary_frame(&frame).unwrap().unwrap();
                        parse_request_binary(&frame[4..consumed]).unwrap()
                    }
                }
            };
            let req = parse(encode_hash_batch_frame(mode, Some(5), &rows, 2));
            assert_eq!(req.req_id, Some(5));
            match req.body {
                RequestBody::Batch(items) => {
                    assert_eq!(items.len(), 2, "{mode:?}");
                    match &items[1] {
                        Ok(Op::Hash { samples }) => assert_eq!(samples, &vec![0.25, 2.0]),
                        other => panic!("{mode:?}: unexpected {other:?}"),
                    }
                }
                other => panic!("{mode:?}: unexpected {other:?}"),
            }
            // full-width ids only survive the binary carrier
            if mode == WireMode::Binary {
                let req = parse(encode_insert_batch_frame(mode, None, &ids, &rows, 2));
                match req.body {
                    RequestBody::Batch(items) => match &items[1] {
                        Ok(Op::Insert { id, .. }) => assert_eq!(*id, ids[1]),
                        other => panic!("unexpected {other:?}"),
                    },
                    other => panic!("unexpected {other:?}"),
                }
            }
            let req = parse(encode_query_batch_frame(mode, Some(6), &rows, 2, 7));
            match req.body {
                RequestBody::Batch(items) => match &items[0] {
                    Ok(Op::Query { k, samples }) => {
                        assert_eq!(*k, 7, "{mode:?}");
                        assert_eq!(samples, &vec![0.5, -1.0]);
                    }
                    other => panic!("{mode:?}: unexpected {other:?}"),
                },
                other => panic!("{mode:?}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn batch_bad_rows_fail_per_item_not_per_frame() {
        // binary: NaN bits in row 1 of 3 — rows 0 and 2 still decode
        let mut rows = vec![0.5f32; 6]; // 3 rows, dim 2
        rows[2] = f32::NAN;
        let frame = encode_hash_batch_binary(Some(8), &rows, 2);
        let req = parse_request_binary(&frame[4..]).unwrap();
        match req.body {
            RequestBody::Batch(items) => {
                assert_eq!(items.len(), 3);
                assert!(items[0].is_ok());
                let e = items[1].as_ref().unwrap_err();
                assert!(e.contains("finite"), "{e}");
                assert!(items[2].is_ok());
            }
            other => panic!("unexpected {other:?}"),
        }
        // JSON: a non-numeric row fails only its own slot
        let line = r#"{"op":"hash_batch","rows":[[0.5],["x"],[0.25]],"req_id":4}"#;
        match parse_request(line).unwrap().body {
            RequestBody::Batch(items) => {
                assert!(items[0].is_ok() && items[2].is_ok());
                assert!(items[1].is_err());
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn batch_frame_level_errors_are_correlated() {
        // count = 0
        let frame = encode_hash_batch_binary(Some(11), &[], 4);
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(11));
        assert!(e.msg.contains("count must be positive"), "{e}");
        // dim = 0 with a huge count must not size an allocation
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, OP_HASH_BATCH, Some(12));
            b.extend_from_slice(&u32::MAX.to_le_bytes());
            b.extend_from_slice(&0u32.to_le_bytes());
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(12));
        assert!(e.msg.contains("dim must be positive"), "{e}");
        // count×dim overflowing the cap / the payload
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, OP_HASH_BATCH, Some(13));
            b.extend_from_slice(&u32::MAX.to_le_bytes());
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(13));
        assert!(e.msg.contains("payload bytes remain"), "{e}");
        // truncation mid-row: 2×4 declared, 6 samples present
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, OP_HASH_BATCH, Some(14));
            b.extend_from_slice(&2u32.to_le_bytes());
            b.extend_from_slice(&4u32.to_le_bytes());
            for _ in 0..6 {
                b.extend_from_slice(&0.5f32.to_le_bytes());
            }
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(14));
        assert!(e.msg.contains("payload bytes remain"), "{e}");
        // JSON: empty rows array, id/row count mismatch
        let e = parse_request(r#"{"op":"hash_batch","rows":[],"req_id":15}"#).unwrap_err();
        assert_eq!(e.req_id, Some(15));
        assert!(e.msg.contains("at least one row"), "{e}");
        let e = parse_request(r#"{"op":"insert_batch","ids":[1],"rows":[[0.5],[0.5]],"req_id":16}"#)
            .unwrap_err();
        assert_eq!(e.req_id, Some(16));
        assert!(e.msg.contains("1 ids but 2 rows"), "{e}");
    }

    #[test]
    fn batch_responses_roundtrip_both_formats() {
        let items = vec![
            Response::Signature(SigView::from_vec(vec![1, -2, 3])),
            Response::Error("row 1: bad".into()),
            Response::Inserted { id: 77 },
            Response::Hits(vec![Hit {
                id: 5,
                distance: 0.5,
            }]),
        ];
        // JSON
        let line = encode_batch_response(Some(9), &items);
        let (rid, decoded) = decode_reply(&line).unwrap();
        assert_eq!(rid, Some(9));
        let got = match decoded.unwrap() {
            Reply::Batch(g) => g,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(got.len(), 4);
        assert_eq!(got[0], Ok(Reply::Signature(vec![1, -2, 3])));
        assert_eq!(got[1], Err("row 1: bad".to_string()));
        assert_eq!(got[2], Ok(Reply::Inserted { id: 77 }));
        // binary
        let frame = encode_batch_response_binary(Some(9), &items);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        assert_eq!(consumed, frame.len());
        let (rid, decoded) = decode_reply_binary(&frame[4..consumed]).unwrap();
        assert_eq!(rid, Some(9));
        match decoded.unwrap() {
            Reply::Batch(g) => {
                assert_eq!(g.len(), 4);
                assert_eq!(g[0], Ok(Reply::Signature(vec![1, -2, 3])));
                assert_eq!(g[1], Err("row 1: bad".to_string()));
                match &g[3] {
                    Ok(Reply::Hits(h)) => assert_eq!(h[0].id, 5),
                    other => panic!("unexpected {other:?}"),
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    /// Split a blob of concatenated wire frames into frame payloads.
    fn split_frames(mode: WireMode, mut blob: &[u8]) -> Vec<Vec<u8>> {
        let mut frames = Vec::new();
        while !blob.is_empty() {
            match mode {
                WireMode::Json => {
                    let nl = blob.iter().position(|&b| b == b'\n').expect("newline");
                    frames.push(blob[..nl].to_vec());
                    blob = &blob[nl + 1..];
                }
                WireMode::Binary => {
                    let consumed = split_binary_frame(blob).unwrap().expect("complete frame");
                    frames.push(blob[4..consumed].to_vec());
                    blob = &blob[consumed..];
                }
            }
        }
        frames
    }

    #[test]
    fn oversized_batch_response_streams_continuation_frames() {
        let hits: Vec<Hit> = (0..200_000)
            .map(|i| Hit {
                id: i,
                distance: 0.001 * i as f64,
            })
            .collect();
        let items = vec![
            Response::Hits(hits.clone()),
            Response::Hits(hits.clone()),
            Response::Hits(hits),
        ];
        for mode in [WireMode::Json, WireMode::Binary] {
            let blob = encode_batch_response_frame(mode, Some(21), &items);
            let frames = split_frames(mode, &blob);
            assert!(frames.len() >= 2, "{mode:?}: an over-cap batch must stream");
            let mut all = Vec::new();
            for (i, payload) in frames.iter().enumerate() {
                assert!(
                    payload.len() <= MAX_FRAME_BYTES,
                    "{mode:?}: part {i} over the cap"
                );
                let (rid, decoded) = match mode {
                    WireMode::Json => {
                        decode_reply(std::str::from_utf8(payload).unwrap()).unwrap()
                    }
                    WireMode::Binary => decode_reply_binary(payload).unwrap(),
                };
                assert_eq!(rid, Some(21), "{mode:?}: every part must correlate");
                match decoded.unwrap() {
                    Reply::BatchPart { more, items } => {
                        assert_eq!(more, i + 1 < frames.len(), "{mode:?}: part {i}");
                        all.extend(items);
                    }
                    other => panic!("{mode:?}: unexpected {other:?}"),
                }
            }
            assert_eq!(all.len(), 3, "{mode:?}: every item arrives exactly once");
            for item in &all {
                match item {
                    Ok(Reply::Hits(h)) => {
                        assert_eq!(h.len(), 200_000, "{mode:?}");
                        assert_eq!(h[199_999].id, 199_999, "{mode:?}");
                    }
                    other => panic!("{mode:?}: unexpected {other:?}"),
                }
            }
        }
        // a small batch passes through as one plain batch envelope
        let small = encode_batch_response_frame(
            WireMode::Binary,
            Some(1),
            &[Response::Pong { indexed: 0 }],
        );
        let (_, decoded) = decode_reply_binary(&small[4..]).unwrap();
        assert!(matches!(decoded.unwrap(), Reply::Batch(v) if v.len() == 1));
    }

    #[test]
    fn single_oversized_batch_item_degrades_only_its_slot() {
        // one item that cannot fit a frame even alone (600k hits: 9.6 MB
        // binary, ~14 MB JSON) next to a small neighbour
        let big: Vec<Hit> = (0..600_000)
            .map(|i| Hit {
                id: i,
                distance: 0.5,
            })
            .collect();
        let items = vec![Response::Hits(big), Response::Pong { indexed: 7 }];
        for mode in [WireMode::Json, WireMode::Binary] {
            let blob = encode_batch_response_frame(mode, Some(9), &items);
            let mut all = Vec::new();
            for payload in split_frames(mode, &blob) {
                let (_, decoded) = match mode {
                    WireMode::Json => {
                        decode_reply(std::str::from_utf8(&payload).unwrap()).unwrap()
                    }
                    WireMode::Binary => decode_reply_binary(&payload).unwrap(),
                };
                match decoded.unwrap() {
                    Reply::BatchPart { items, .. } => all.extend(items),
                    other => panic!("{mode:?}: unexpected {other:?}"),
                }
            }
            assert_eq!(all.len(), 2, "{mode:?}");
            let e = all[0].as_ref().unwrap_err();
            assert!(e.contains("response too large"), "{mode:?}: {e}");
            assert_eq!(all[1], Ok(Reply::Pong { indexed: 7 }), "{mode:?}");
        }
    }

    #[test]
    fn overloaded_envelopes_are_typed_in_both_formats() {
        let msg = overloaded_msg("connection in-flight byte budget");
        assert!(error_is_overloaded(&msg));
        assert!(!error_is_overloaded("bad request: nope"));
        for mode in [WireMode::Json, WireMode::Binary] {
            let frame =
                encode_overloaded_frame(mode, Some(33), "connection in-flight byte budget");
            let (rid, decoded) = match mode {
                WireMode::Json => decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap(),
                WireMode::Binary => decode_reply_binary(&frame[4..]).unwrap(),
            };
            assert_eq!(rid, Some(33), "{mode:?}: sheds must correlate");
            let e = decoded.unwrap_err();
            assert!(error_is_overloaded(&e), "{mode:?}: {e}");
        }
        // the JSON envelope carries the machine-readable code field…
        let line = encode_error(Some(1), &msg);
        assert!(line.contains(r#""code":"overloaded""#), "{line}");
        // …and plain errors carry no code byte/field and still roundtrip
        let plain = encode_error_binary(Some(2), "duplicate id 7");
        let (_, decoded) = decode_reply_binary(&plain[4..]).unwrap();
        assert_eq!(decoded.unwrap_err(), "duplicate id 7");
        assert!(!encode_error(Some(2), "duplicate id 7").contains("code"));
    }

    #[test]
    fn frame_overhead_matches_wire_layout() {
        for mode in [WireMode::Json, WireMode::Binary] {
            let f = encode_hash_frame(mode, Some(1), &[0.5]);
            assert_eq!(
                framed_payload_len(mode, &f) + frame_overhead_bytes(mode),
                f.len(),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn parse_frame_payload_shares_the_malformed_rules() {
        // utf-8 and empty rules live in the one shared entry point
        let e = parse_frame_payload(WireMode::Json, &[0xff, 0xfe]).unwrap_err();
        assert!(e.msg.contains("utf-8"), "{e}");
        let e = parse_frame_payload(WireMode::Json, b"   ").unwrap_err();
        assert!(e.msg.contains("empty"), "{e}");
        let e = parse_frame_payload(WireMode::Json, b"").unwrap_err();
        assert!(e.msg.contains("empty"), "{e}");
        // and it dispatches to the right per-format parser
        let req = parse_frame_payload(WireMode::Json, b"{\"op\":\"ping\",\"req_id\":3}").unwrap();
        assert_eq!(req.req_id, Some(3));
        let frame = encode_bare_binary(Some(4), "ping");
        let req = parse_frame_payload(WireMode::Binary, &frame[4..]).unwrap();
        assert_eq!(req.req_id, Some(4));
    }

    #[test]
    fn full_width_ids_degrade_to_errors_on_the_json_response_path() {
        let big = (1u64 << 60) + 7;
        let cases = [
            Response::Inserted { id: big },
            Response::Removed { id: big },
            Response::Hits(vec![
                Hit {
                    id: 1,
                    distance: 0.5,
                },
                Hit {
                    id: big,
                    distance: 0.75,
                },
            ]),
        ];
        for resp in &cases {
            // JSON: correlated error instead of a silently rounded id
            let frame = encode_response_frame(WireMode::Json, Some(9), resp);
            let (rid, decoded) =
                decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap();
            assert_eq!(rid, Some(9), "{resp:?}");
            let msg = decoded.unwrap_err();
            assert!(msg.contains("2^53"), "{resp:?}: {msg}");
            // binary: passes through intact
            let frame = encode_response_frame(WireMode::Binary, Some(9), resp);
            let (_, decoded) = decode_reply_binary(&frame[4..]).unwrap();
            assert!(decoded.is_ok(), "{resp:?}");
        }
        // batch envelope: only the offending item degrades
        let items = vec![
            Response::Inserted { id: 5 },
            Response::Inserted { id: big },
            Response::Inserted { id: 6 },
        ];
        let frame = encode_batch_response_frame(WireMode::Json, Some(2), &items);
        let (rid, decoded) = decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap();
        assert_eq!(rid, Some(2));
        match decoded.unwrap() {
            Reply::Batch(got) => {
                assert_eq!(got[0], Ok(Reply::Inserted { id: 5 }));
                assert!(got[1].as_ref().unwrap_err().contains("2^53"));
                assert_eq!(got[2], Ok(Reply::Inserted { id: 6 }));
            }
            other => panic!("unexpected {other:?}"),
        }
        // a just-representable id still rides the JSON wire
        let frame = encode_response_frame(
            WireMode::Json,
            Some(1),
            &Response::Inserted { id: 1 << 53 },
        );
        let (_, decoded) = decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap();
        assert_eq!(decoded.unwrap(), Reply::Inserted { id: 1 << 53 });
    }

    #[test]
    fn nested_batch_replies_rejected() {
        // a hostile server nesting batch-in-batch must not recurse the
        // client decoder: status ok, type batch, 1 item: ok + type batch
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, STATUS_OK, Some(1));
            b.push(REPLY_BATCH);
            b.extend_from_slice(&1u32.to_le_bytes());
            b.push(STATUS_OK);
            b.push(REPLY_BATCH);
            b.extend_from_slice(&0u32.to_le_bytes());
        });
        let e = decode_reply_binary(&frame[4..]).unwrap_err();
        assert!(e.contains("unknown binary reply type"), "{e}");
    }

    #[test]
    fn nested_batch_part_replies_rejected() {
        // a batch_part nested inside a batch item must not recurse the
        // client decoder either
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, STATUS_OK, Some(1));
            b.push(REPLY_BATCH);
            b.extend_from_slice(&1u32.to_le_bytes());
            b.push(STATUS_OK);
            b.push(REPLY_BATCH_PART);
            b.push(0);
            b.extend_from_slice(&0u32.to_le_bytes());
        });
        let e = decode_reply_binary(&frame[4..]).unwrap_err();
        assert!(e.contains("unknown binary reply type"), "{e}");
    }

    #[test]
    fn migration_requests_roundtrip_both_formats() {
        let entries = vec![EntryRecord {
            id: 42,
            emb: vec![0.5, -2.25],
            sig: vec![3, -1],
        }];
        // JSON
        match parse_request(&encode_migrate_pull(Some(1), 100, 64))
            .unwrap()
            .body
        {
            RequestBody::Op(Op::MigratePull { from_id, max }) => {
                assert_eq!(from_id, 100);
                assert_eq!(max, 64);
            }
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_entries_push(Some(2), &entries))
            .unwrap()
            .body
        {
            RequestBody::Op(Op::EntriesPush { entries: got }) => assert_eq!(got, entries),
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_entries_discard(None, &[7, 9]))
            .unwrap()
            .body
        {
            RequestBody::Op(Op::EntriesDiscard { ids }) => assert_eq!(ids, vec![7, 9]),
            other => panic!("unexpected {other:?}"),
        }
        // binary — full-width ids survive
        let big = (1u64 << 60) + 3;
        let big_entries = vec![EntryRecord {
            id: big,
            emb: vec![1.0],
            sig: vec![0],
        }];
        let frame = encode_migrate_pull_binary(Some(3), big, 128);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::MigratePull { from_id, max }) => {
                assert_eq!(from_id, big);
                assert_eq!(max, 128);
            }
            other => panic!("unexpected {other:?}"),
        }
        let frame = encode_entries_push_binary(Some(4), &big_entries);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::EntriesPush { entries: got }) => assert_eq!(got, big_entries),
            other => panic!("unexpected {other:?}"),
        }
        let frame = encode_entries_discard_binary(None, &[big]);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::EntriesDiscard { ids }) => assert_eq!(ids, vec![big]),
            other => panic!("unexpected {other:?}"),
        }
        // an empty push is a frame-level error in both formats
        assert!(parse_request(r#"{"op":"entries_push","entries":[]}"#).is_err());
        let frame = encode_entries_push_binary(Some(5), &[]);
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(5));
        assert!(e.msg.contains("at least one entry"), "{e}");
        // non-finite embeddings are rejected at the wire
        let bad = vec![EntryRecord {
            id: 1,
            emb: vec![f64::NAN],
            sig: vec![0],
        }];
        let frame = encode_entries_push_binary(Some(6), &bad);
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(6));
        assert!(e.msg.contains("finite"), "{e}");
    }

    #[test]
    fn degraded_envelopes_roundtrip_both_formats() {
        let missing = vec!["0000000000000000-7fffffffffffffff@127.0.0.1:4801".to_string()];
        let hits = Response::Hits(vec![Hit {
            id: 4,
            distance: 0.125,
        }]);
        // single-op wrapper, JSON
        let frame = encode_degraded_response_frame(WireMode::Json, Some(7), &missing, &hits);
        let (rid, decoded) = decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap();
        assert_eq!(rid, Some(7));
        match decoded.unwrap() {
            Reply::Degraded { missing: m, reply } => {
                assert_eq!(m, missing);
                check_reply(*reply, &hits);
            }
            other => panic!("unexpected {other:?}"),
        }
        // single-op wrapper, binary
        let frame = encode_degraded_response_frame(WireMode::Binary, Some(8), &missing, &hits);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        assert_eq!(consumed, frame.len());
        let (rid, decoded) = decode_reply_binary(&frame[4..consumed]).unwrap();
        assert_eq!(rid, Some(8));
        match decoded.unwrap() {
            Reply::Degraded { missing: m, reply } => {
                assert_eq!(m, missing);
                check_reply(*reply, &hits);
            }
            other => panic!("unexpected {other:?}"),
        }
        // batch wrapper: per-item results survive alongside the gap marker
        let items = vec![
            Response::Hits(vec![]),
            Response::Error("row 1 failed".into()),
        ];
        for mode in [WireMode::Json, WireMode::Binary] {
            let frame = encode_degraded_batch_frame(mode, Some(9), &missing, &items);
            let (rid, decoded) = match mode {
                WireMode::Json => {
                    decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap()
                }
                WireMode::Binary => decode_reply_binary(&frame[4..]).unwrap(),
            };
            assert_eq!(rid, Some(9));
            match decoded.unwrap() {
                Reply::Degraded { missing: m, reply } => {
                    assert_eq!(m, missing);
                    match *reply {
                        Reply::Batch(got) => {
                            assert_eq!(got.len(), 2);
                            assert_eq!(got[0], Ok(Reply::Hits(vec![])));
                            assert_eq!(got[1], Err("row 1 failed".into()));
                        }
                        other => panic!("unexpected inner {other:?}"),
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn nested_degraded_wrappers_rejected() {
        // degraded is top-level-only: a wrapper nested inside another
        // wrapper's inner body must not recurse the decoder
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, STATUS_OK, Some(1));
            b.push(REPLY_DEGRADED);
            b.extend_from_slice(&0u32.to_le_bytes());
            b.push(REPLY_DEGRADED);
            b.extend_from_slice(&0u32.to_le_bytes());
            b.push(REPLY_PONG);
            b.extend_from_slice(&5u64.to_le_bytes());
        });
        let e = decode_reply_binary(&frame[4..]).unwrap_err();
        assert!(e.contains("unknown binary reply type"), "{e}");
        // …and inside a batch item
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, STATUS_OK, Some(2));
            b.push(REPLY_BATCH);
            b.extend_from_slice(&1u32.to_le_bytes());
            b.push(STATUS_OK);
            b.push(REPLY_DEGRADED);
            b.extend_from_slice(&0u32.to_le_bytes());
        });
        let e = decode_reply_binary(&frame[4..]).unwrap_err();
        assert!(e.contains("unknown binary reply type"), "{e}");
    }

    #[test]
    fn degraded_errors_are_typed_in_both_formats() {
        let msg = degraded_msg("shard range 0-7 at 127.0.0.1:4801 unavailable");
        assert!(error_is_degraded(&msg));
        assert!(!error_is_overloaded(&msg));
        // JSON carries the machine-readable code field
        let line = encode_error(Some(3), &msg);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").and_then(Value::as_str), Some("degraded"));
        let (rid, decoded) = decode_reply(&line).unwrap();
        assert_eq!(rid, Some(3));
        assert!(error_is_degraded(&decoded.unwrap_err()));
        // binary appends the additive code byte after the message
        let frame = encode_error_binary(Some(4), &msg);
        assert_eq!(*frame.last().unwrap(), ERR_CODE_DEGRADED);
        let (rid, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(rid, Some(4));
        assert!(error_is_degraded(&decoded.unwrap_err()));
    }
}
