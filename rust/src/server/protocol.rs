//! Wire-format encode/decode for both frame formats the server speaks on
//! one port (see the [`crate::server`] module doc for the full frame
//! reference):
//!
//! * **newline-delimited JSON** — one UTF-8 JSON object per line; the
//!   original format, kept as the default and the debugging-friendly
//!   option (`nc` works).
//! * **`FBIN1` length-prefixed binary** — negotiated by a connection
//!   whose first five bytes are [`BINARY_MAGIC`]; every subsequent frame
//!   in *both* directions is a little-endian `u32` payload length
//!   followed by the payload. Sample rows travel as raw `f32` bits and
//!   ids as native `u64`s, so bulk rows cost 4 bytes/sample instead of
//!   ~9–13 bytes of decimal text, and the JSON carrier's 2^53 id
//!   precision limit does not apply.
//!
//! Both directions are symmetric: the server uses [`parse_request`] /
//! [`parse_request_binary`] + the `encode_*_frame` response builders; the
//! client uses the `encode_*_frame` request builders + [`decode_reply`] /
//! [`decode_reply_binary`]. JSON round-trips through [`crate::json`]; the
//! binary codec is hand-rolled little-endian — no external serialization
//! crates in either path.
//!
//! Sample values are validated at the wire: a non-finite sample — or a
//! JSON number that overflows `f32` to `±inf` — is rejected with a
//! per-request error envelope before it can poison the index or the
//! re-rank distances.

use crate::coordinator::{Op, Response};
use crate::json::{self, object, Value};
use crate::search::Hit;

/// Hard cap on one request/response frame (the JSON line without its
/// newline, or the binary payload without its length prefix); longer
/// frames are a protocol error (protects both sides from unbounded
/// buffering).
///
/// Note on integer width: in the JSON format ids and `req_id`s travel as
/// JSON numbers, which this crate's [`crate::json`] (like most JSON
/// stacks) carries as `f64` — values ≥ 2^53 lose precision on the wire
/// and `Value::as_u64` rejects them server-side. The binary format
/// carries ids as native little-endian `u64`s and has no such limit.
pub const MAX_LINE_BYTES: usize = 8 << 20;

/// Alias of [`MAX_LINE_BYTES`] for the binary framing (one cap, two
/// formats).
pub const MAX_FRAME_BYTES: usize = MAX_LINE_BYTES;

/// First bytes of a binary-mode connection. A connection that opens with
/// anything else speaks newline-delimited JSON.
pub const BINARY_MAGIC: &[u8; 5] = b"FBIN1";

/// Which frame format a connection (or client) speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireMode {
    /// newline-delimited JSON (the default)
    Json,
    /// `FBIN1` length-prefixed binary
    Binary,
}

impl WireMode {
    /// The CLI/config spelling (inverse of [`WireMode::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            WireMode::Json => "json",
            WireMode::Binary => "binary",
        }
    }

    /// Parse the CLI spelling (`funclsh load --wire …` goes through
    /// here).
    pub fn parse(s: &str) -> Option<WireMode> {
        match s {
            "json" | "jsonl" => Some(WireMode::Json),
            "binary" | "bin" | "fbin1" => Some(WireMode::Binary),
            _ => None,
        }
    }
}

/// Outcome of sniffing the first bytes of a connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Negotiation {
    /// the bytes so far are a proper prefix of [`BINARY_MAGIC`]; read
    /// more before deciding
    NeedMore,
    /// JSON mode — no bytes consumed
    Json,
    /// binary mode — the caller must consume the 5 magic bytes
    Binary,
}

/// Decide a connection's wire mode from its first buffered bytes. Any
/// first byte that cannot begin [`BINARY_MAGIC`] selects JSON (a valid
/// JSON frame starts with `{` or whitespace, so garbage that *almost*
/// spells the magic falls through to the JSON parser's error envelope).
pub fn negotiate(first: &[u8]) -> Negotiation {
    let n = first.len().min(BINARY_MAGIC.len());
    if first[..n] != BINARY_MAGIC[..n] {
        return Negotiation::Json;
    }
    if first.len() >= BINARY_MAGIC.len() {
        Negotiation::Binary
    } else {
        Negotiation::NeedMore
    }
}

/// Try to split one binary frame off the front of `buf`: `Ok(None)`
/// means more bytes are needed; `Ok(Some(consumed))` means one complete
/// frame occupies `buf[..consumed]` with its payload at
/// `buf[4..consumed]`. An oversized declared length is an `Err` — the
/// framing cannot resync past it, so the connection must close (after
/// answering with the error).
pub fn split_binary_frame(buf: &[u8]) -> Result<Option<usize>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(format!(
            "binary frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap"
        ));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(4 + len))
}

// binary request op tags
const OP_HASH: u8 = 1;
const OP_INSERT: u8 = 2;
const OP_QUERY: u8 = 3;
const OP_REMOVE: u8 = 4;
const OP_METRICS: u8 = 5;
const OP_SNAPSHOT: u8 = 6;
const OP_PING: u8 = 7;
const OP_POINTS: u8 = 8;
const OP_SHUTDOWN: u8 = 9;

// binary reply type tags
const REPLY_SIGNATURE: u8 = 1;
const REPLY_INSERTED: u8 = 2;
const REPLY_HITS: u8 = 3;
const REPLY_REMOVED: u8 = 4;
const REPLY_METRICS: u8 = 5;
const REPLY_SNAPSHOT: u8 = 6;
const REPLY_PONG: u8 = 7;
const REPLY_POINTS: u8 = 8;
const REPLY_SHUTTING_DOWN: u8 = 9;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// Header flag: a `u64` `req_id` follows the flags byte.
const FLAG_REQ_ID: u8 = 1;

/// A decoded request frame.
#[derive(Debug, Clone)]
pub struct Request {
    /// client correlation id, echoed verbatim in the response
    pub req_id: Option<u64>,
    /// what the client asked for
    pub body: RequestBody,
}

/// The request payload: either a coordinator op (routed through the
/// dynamic batcher) or one of the transport-level ops the server answers
/// directly.
#[derive(Debug, Clone)]
pub enum RequestBody {
    /// a coordinator operation
    Op(Op),
    /// the service's published sample points
    Points,
    /// graceful server shutdown
    Shutdown,
}

fn f32_row(v: &Value) -> Result<Vec<f32>, String> {
    let arr = v.as_array().ok_or("`samples` must be an array")?;
    arr.iter()
        .enumerate()
        .map(|(i, x)| {
            let f = x
                .as_f64()
                .ok_or_else(|| "`samples` must contain only numbers".to_string())?;
            let v = f as f32;
            if !v.is_finite() {
                // a JSON f64 that overflows f32 casts to ±inf; letting it
                // through would poison the index and every re-rank
                // distance it touches
                return Err(format!(
                    "`samples[{i}]` = {f} is not a finite f32 (non-finite samples are rejected)"
                ));
            }
            Ok(v)
        })
        .collect()
}

fn need<'v>(v: &'v Value, key: &str) -> Result<&'v Value, String> {
    v.get(key).ok_or_else(|| format!("missing field `{key}`"))
}

/// A rejected request frame. Carries the `req_id` recovered from the
/// frame (when it parsed far enough to have one), so the error envelope
/// can still correlate — a pipelined client must get a per-request
/// error, not a connection-level failure.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestError {
    /// the frame's correlation id, if it was recoverable
    pub req_id: Option<u64>,
    /// what was wrong with the frame
    pub msg: String,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

/// Parse one JSON request line.
pub fn parse_request(line: &str) -> Result<Request, RequestError> {
    let v = json::parse(line.trim()).map_err(|e| RequestError {
        req_id: None,
        msg: format!("bad json: {e}"),
    })?;
    let req_id = v.get("req_id").and_then(Value::as_u64);
    let body = (|| -> Result<RequestBody, String> {
        let op = v
            .get("op")
            .and_then(Value::as_str)
            .ok_or("missing string field `op`")?;
        Ok(match op {
            "hash" => RequestBody::Op(Op::Hash {
                samples: f32_row(need(&v, "samples")?)?,
            }),
            "insert" => RequestBody::Op(Op::Insert {
                id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
                samples: f32_row(need(&v, "samples")?)?,
            }),
            "query" => RequestBody::Op(Op::Query {
                samples: f32_row(need(&v, "samples")?)?,
                k: need(&v, "k")?.as_usize().ok_or("`k` must be a usize")?,
            }),
            "remove" => RequestBody::Op(Op::Remove {
                id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
            }),
            "metrics" => RequestBody::Op(Op::Metrics),
            "snapshot" => RequestBody::Op(Op::Snapshot {
                path: need(&v, "path")?
                    .as_str()
                    .ok_or("`path` must be a string")?
                    .to_string(),
            }),
            "ping" => RequestBody::Op(Op::Ping),
            "points" => RequestBody::Points,
            "shutdown" => RequestBody::Shutdown,
            other => return Err(format!("unknown op `{other}`")),
        })
    })()
    .map_err(|msg| RequestError { req_id, msg })?;
    Ok(Request { req_id, body })
}

// ---------------------------------------------------- binary primitives

/// Little-endian reader over a binary payload; every accessor reports
/// truncation as a typed message instead of panicking.
struct BinReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn finished(&self) -> bool {
        self.pos == self.b.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated frame: need {n} more bytes, have {}",
                self.remaining()
            ));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, String> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str_(&mut self) -> Result<&'a str, String> {
        let n = self.u32()? as usize;
        std::str::from_utf8(self.take(n)?).map_err(|_| "invalid utf-8 in string field".into())
    }

    /// `u32` count + raw `f32` samples, with the declared count checked
    /// against the remaining bytes *before* any allocation is sized from
    /// it, and every value checked finite (the binary twin of
    /// [`f32_row`]'s rejection rule).
    fn samples(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        if self.remaining() < n.saturating_mul(4) {
            return Err(format!(
                "declared {n} samples but only {} payload bytes remain",
                self.remaining()
            ));
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let v = self.f32()?;
            if !v.is_finite() {
                return Err(format!(
                    "sample[{i}] is not a finite f32 (non-finite samples are rejected)"
                ));
            }
            out.push(v);
        }
        Ok(out)
    }
}

/// Build one binary frame: 4-byte LE length prefix + the payload written
/// by `build`.
fn bin_frame(build: impl FnOnce(&mut Vec<u8>)) -> Vec<u8> {
    let mut b = vec![0u8; 4];
    build(&mut b);
    let len = (b.len() - 4) as u32;
    b[..4].copy_from_slice(&len.to_le_bytes());
    b
}

/// Leading tag byte (request op / response status) + flags (+ `req_id`).
fn put_tag_and_req_id(b: &mut Vec<u8>, tag: u8, req_id: Option<u64>) {
    b.push(tag);
    match req_id {
        Some(id) => {
            b.push(FLAG_REQ_ID);
            b.extend_from_slice(&id.to_le_bytes());
        }
        None => b.push(0),
    }
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    b.extend_from_slice(&(s.len() as u32).to_le_bytes());
    b.extend_from_slice(s.as_bytes());
}

fn put_samples(b: &mut Vec<u8>, samples: &[f32]) {
    b.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    for &s in samples {
        b.extend_from_slice(&s.to_le_bytes());
    }
}

/// Parse one binary request payload (the bytes after the length prefix).
/// The header (op tag, flags, `req_id`) parses first, so body-level
/// failures still correlate to their request.
pub fn parse_request_binary(payload: &[u8]) -> Result<Request, RequestError> {
    let mut rd = BinReader::new(payload);
    let head = |msg: String| RequestError { req_id: None, msg };
    let op = rd.u8().map_err(head)?;
    let flags = rd.u8().map_err(head)?;
    if flags & !FLAG_REQ_ID != 0 {
        return Err(head(format!("unknown header flags {flags:#04x}")));
    }
    let req_id = if flags & FLAG_REQ_ID != 0 {
        Some(rd.u64().map_err(head)?)
    } else {
        None
    };
    let body = (|| -> Result<RequestBody, String> {
        let body = match op {
            OP_HASH => RequestBody::Op(Op::Hash {
                samples: rd.samples()?,
            }),
            OP_INSERT => {
                let id = rd.u64()?;
                RequestBody::Op(Op::Insert {
                    id,
                    samples: rd.samples()?,
                })
            }
            OP_QUERY => {
                let samples = rd.samples()?;
                let k = rd.u64()? as usize;
                RequestBody::Op(Op::Query { samples, k })
            }
            OP_REMOVE => RequestBody::Op(Op::Remove { id: rd.u64()? }),
            OP_METRICS => RequestBody::Op(Op::Metrics),
            OP_SNAPSHOT => RequestBody::Op(Op::Snapshot {
                path: rd.str_()?.to_string(),
            }),
            OP_PING => RequestBody::Op(Op::Ping),
            OP_POINTS => RequestBody::Points,
            OP_SHUTDOWN => RequestBody::Shutdown,
            other => return Err(format!("unknown binary op tag {other}")),
        };
        if !rd.finished() {
            return Err(format!(
                "{} trailing bytes after the request body",
                rd.remaining()
            ));
        }
        Ok(body)
    })()
    .map_err(|msg| RequestError { req_id, msg })?;
    Ok(Request { req_id, body })
}

// -------------------------------------------------------- JSON encoders

fn envelope(req_id: Option<u64>, mut fields: Vec<(&str, Value)>) -> String {
    fields.push(("ok", true.into()));
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

/// Encode an error response line (JSON).
pub fn encode_error(req_id: Option<u64>, msg: &str) -> String {
    let mut fields: Vec<(&str, Value)> = vec![("ok", false.into()), ("error", msg.into())];
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

/// Encode a coordinator response line (JSON).
pub fn encode_response(req_id: Option<u64>, resp: &Response) -> String {
    match resp {
        Response::Signature(sig) => envelope(
            req_id,
            vec![
                ("type", "signature".into()),
                (
                    "signature",
                    // serialized straight from the shared flat block —
                    // no per-response Vec<i32> clone on this path
                    Value::Array(
                        sig.as_slice()
                            .iter()
                            .map(|&x| Value::Number(x as f64))
                            .collect(),
                    ),
                ),
            ],
        ),
        Response::Inserted { id } => envelope(
            req_id,
            vec![("type", "inserted".into()), ("id", (*id as usize).into())],
        ),
        Response::Hits(hits) => envelope(
            req_id,
            vec![
                ("type", "hits".into()),
                (
                    "hits",
                    Value::Array(
                        hits.iter()
                            .map(|h| {
                                object(vec![
                                    ("id", (h.id as usize).into()),
                                    ("distance", h.distance.into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ],
        ),
        Response::Removed { id } => envelope(
            req_id,
            vec![("type", "removed".into()), ("id", (*id as usize).into())],
        ),
        Response::Metrics(m) => envelope(
            req_id,
            vec![("type", "metrics".into()), ("metrics", m.to_value())],
        ),
        Response::Snapshotted { path, bytes } => envelope(
            req_id,
            vec![
                ("type", "snapshot".into()),
                ("path", path.as_str().into()),
                ("bytes", (*bytes as usize).into()),
            ],
        ),
        Response::Pong { indexed } => envelope(
            req_id,
            vec![
                ("type", "pong".into()),
                ("indexed", (*indexed as usize).into()),
            ],
        ),
        Response::Error(e) => encode_error(req_id, e),
    }
}

/// Encode the transport-level `points` response (JSON).
pub fn encode_points(req_id: Option<u64>, points: &[f64]) -> String {
    envelope(
        req_id,
        vec![
            ("type", "points".into()),
            (
                "points",
                Value::Array(points.iter().map(|&x| Value::Number(x)).collect()),
            ),
        ],
    )
}

/// Encode the transport-level `shutdown` acknowledgement (JSON).
pub fn encode_shutting_down(req_id: Option<u64>) -> String {
    envelope(req_id, vec![("type", "shutting_down".into())])
}

// ------------------------------------------------------ binary encoders

/// Encode an error response frame (binary, length-prefixed).
pub fn encode_error_binary(req_id: Option<u64>, msg: &str) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_ERR, req_id);
        put_str(b, msg);
    })
}

/// Encode a coordinator response frame (binary, length-prefixed).
pub fn encode_response_binary(req_id: Option<u64>, resp: &Response) -> Vec<u8> {
    if let Response::Error(e) = resp {
        return encode_error_binary(req_id, e);
    }
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        match resp {
            Response::Signature(sig) => {
                b.push(REPLY_SIGNATURE);
                // straight off the shared [B×K] block: count + raw i32s
                let s = sig.as_slice();
                b.extend_from_slice(&(s.len() as u32).to_le_bytes());
                for &v in s {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            Response::Inserted { id } => {
                b.push(REPLY_INSERTED);
                b.extend_from_slice(&id.to_le_bytes());
            }
            Response::Hits(hits) => {
                b.push(REPLY_HITS);
                b.extend_from_slice(&(hits.len() as u32).to_le_bytes());
                for h in hits {
                    b.extend_from_slice(&h.id.to_le_bytes());
                    b.extend_from_slice(&h.distance.to_le_bytes());
                }
            }
            Response::Removed { id } => {
                b.push(REPLY_REMOVED);
                b.extend_from_slice(&id.to_le_bytes());
            }
            Response::Metrics(m) => {
                // metrics stay a JSON object inside the binary carrier:
                // they are diagnostic, schema-fluid, and tiny
                b.push(REPLY_METRICS);
                put_str(b, &m.to_value().to_json());
            }
            Response::Snapshotted { path, bytes } => {
                b.push(REPLY_SNAPSHOT);
                put_str(b, path);
                b.extend_from_slice(&bytes.to_le_bytes());
            }
            Response::Pong { indexed } => {
                b.push(REPLY_PONG);
                b.extend_from_slice(&indexed.to_le_bytes());
            }
            Response::Error(_) => unreachable!("handled above"),
        }
    })
}

/// Encode the transport-level `points` response (binary).
pub fn encode_points_binary(req_id: Option<u64>, points: &[f64]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        b.push(REPLY_POINTS);
        b.extend_from_slice(&(points.len() as u32).to_le_bytes());
        for &p in points {
            b.extend_from_slice(&p.to_le_bytes());
        }
    })
}

/// Encode the transport-level `shutdown` acknowledgement (binary).
pub fn encode_shutting_down_binary(req_id: Option<u64>) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, STATUS_OK, req_id);
        b.push(REPLY_SHUTTING_DOWN);
    })
}

// --------------------------------------------- mode-dispatching framing

/// Wrap a JSON line as wire bytes (the line plus its newline).
fn json_frame(line: String) -> Vec<u8> {
    let mut b = line.into_bytes();
    b.push(b'\n');
    b
}

/// Payload length of an already-framed response (JSON line without its
/// newline, binary payload without its prefix).
fn framed_payload_len(mode: WireMode, frame: &[u8]) -> usize {
    match mode {
        WireMode::Json => frame.len().saturating_sub(1),
        WireMode::Binary => frame.len().saturating_sub(4),
    }
}

/// A safe *lower bound* on a response's encoded payload size: never
/// larger than the real encoding, so it can veto serialization early
/// without ever rejecting a response that would have fit. Binary element
/// sizes are exact; JSON per-element floors are the shortest possible
/// renderings.
fn response_payload_min(mode: WireMode, resp: &Response) -> usize {
    let per_elem = |bin: usize, json_min: usize| match mode {
        WireMode::Binary => bin,
        WireMode::Json => json_min,
    };
    match resp {
        // binary: 16 B/hit; JSON: >= len(r#"{"distance":0,"id":0}"#) + comma
        Response::Hits(h) => h.len() * per_elem(16, 22),
        // binary: 4 B/entry; JSON: >= one digit + comma
        Response::Signature(s) => s.as_slice().len() * per_elem(4, 2),
        _ => 0,
    }
}

/// Encode a coordinator response as complete wire bytes for `mode`, with
/// the oversize guard: a response the peer could never frame (payload >
/// [`MAX_FRAME_BYTES`], e.g. a `query` with a huge `k` against a dense
/// bucket) is replaced by a *correlated per-request error envelope*
/// instead of killing the connection — every other in-flight pipelined
/// request keeps its answer. Provably-oversized responses are vetoed by
/// an exact size bound *before* serialization, so the hostile path never
/// builds the tens-of-MB frame it is about to discard.
pub fn encode_response_frame(mode: WireMode, req_id: Option<u64>, resp: &Response) -> Vec<u8> {
    let floor = response_payload_min(mode, resp);
    if floor > MAX_FRAME_BYTES {
        return encode_error_frame(
            mode,
            req_id,
            &format!(
                "response too large (at least {floor} bytes > {MAX_FRAME_BYTES}-byte frame \
                 cap); request fewer results per op"
            ),
        );
    }
    let frame = match mode {
        WireMode::Json => json_frame(encode_response(req_id, resp)),
        WireMode::Binary => encode_response_binary(req_id, resp),
    };
    let payload = framed_payload_len(mode, &frame);
    if payload > MAX_FRAME_BYTES {
        return encode_error_frame(
            mode,
            req_id,
            &format!(
                "response too large ({payload} bytes > {MAX_FRAME_BYTES}-byte frame cap); \
                 request fewer results per op"
            ),
        );
    }
    frame
}

/// Encode an error envelope as complete wire bytes for `mode`.
pub fn encode_error_frame(mode: WireMode, req_id: Option<u64>, msg: &str) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_error(req_id, msg)),
        WireMode::Binary => encode_error_binary(req_id, msg),
    }
}

/// Encode the `points` response as complete wire bytes for `mode`.
pub fn encode_points_frame(mode: WireMode, req_id: Option<u64>, points: &[f64]) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_points(req_id, points)),
        WireMode::Binary => encode_points_binary(req_id, points),
    }
}

/// Encode the `shutting_down` acknowledgement as complete wire bytes.
pub fn encode_shutting_down_frame(mode: WireMode, req_id: Option<u64>) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_shutting_down(req_id)),
        WireMode::Binary => encode_shutting_down_binary(req_id),
    }
}

// ---------------------------------------------------------------- client

/// A decoded server reply (the client-side mirror of
/// [`encode_response`] / [`encode_response_binary`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// `hash` result
    Signature(Vec<i32>),
    /// `insert` ack
    Inserted {
        /// inserted id
        id: u64,
    },
    /// `query` result
    Hits(Vec<Hit>),
    /// `remove` ack
    Removed {
        /// removed id
        id: u64,
    },
    /// `metrics` result (kept as a JSON object)
    Metrics(Value),
    /// `snapshot` ack
    Snapshotted {
        /// snapshot destination
        path: String,
        /// bytes written
        bytes: u64,
    },
    /// `ping` ack
    Pong {
        /// entries indexed server-side
        indexed: u64,
    },
    /// `points` result
    Points(Vec<f64>),
    /// `shutdown` ack
    ShuttingDown,
}

/// Decode one JSON reply line into `(req_id, server result)`. The outer
/// `Err` is a protocol violation (unparseable frame); the inner
/// `Err(String)` is a well-formed server-side error envelope.
#[allow(clippy::type_complexity)]
pub fn decode_reply(line: &str) -> Result<(Option<u64>, Result<Reply, String>), String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad reply json: {e}"))?;
    let req_id = v.get("req_id").and_then(Value::as_u64);
    let ok = v
        .get("ok")
        .and_then(|b| match b {
            Value::Bool(b) => Some(*b),
            _ => None,
        })
        .ok_or("reply missing bool field `ok`")?;
    if !ok {
        let msg = v
            .get("error")
            .and_then(Value::as_str)
            .unwrap_or("unspecified server error")
            .to_string();
        return Ok((req_id, Err(msg)));
    }
    let ty = v
        .get("type")
        .and_then(Value::as_str)
        .ok_or("reply missing string field `type`")?;
    let reply = match ty {
        "signature" => Reply::Signature(
            need(&v, "signature")?
                .as_array()
                .ok_or("`signature` must be an array")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as i32)
                        .ok_or_else(|| "`signature` must contain numbers".to_string())
                })
                .collect::<Result<_, _>>()?,
        ),
        "inserted" => Reply::Inserted {
            id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
        },
        "hits" => Reply::Hits(
            need(&v, "hits")?
                .as_array()
                .ok_or("`hits` must be an array")?
                .iter()
                .map(|h| -> Result<Hit, String> {
                    Ok(Hit {
                        id: need(h, "id")?.as_u64().ok_or("hit `id` must be a u64")?,
                        distance: need(h, "distance")?
                            .as_f64()
                            .ok_or("hit `distance` must be a number")?,
                    })
                })
                .collect::<Result<_, _>>()?,
        ),
        "removed" => Reply::Removed {
            id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
        },
        "metrics" => Reply::Metrics(need(&v, "metrics")?.clone()),
        "snapshot" => Reply::Snapshotted {
            path: need(&v, "path")?
                .as_str()
                .ok_or("`path` must be a string")?
                .to_string(),
            bytes: need(&v, "bytes")?.as_u64().ok_or("`bytes` must be a u64")?,
        },
        "pong" => Reply::Pong {
            indexed: need(&v, "indexed")?
                .as_u64()
                .ok_or("`indexed` must be a u64")?,
        },
        "points" => Reply::Points(
            need(&v, "points")?
                .as_array()
                .ok_or("`points` must be an array")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| "`points` must contain numbers".to_string())
                })
                .collect::<Result<_, _>>()?,
        ),
        "shutting_down" => Reply::ShuttingDown,
        other => return Err(format!("unknown reply type `{other}`")),
    };
    Ok((req_id, Ok(reply)))
}

/// Decode one binary reply payload into `(req_id, server result)` — the
/// binary mirror of [`decode_reply`].
#[allow(clippy::type_complexity)]
pub fn decode_reply_binary(
    payload: &[u8],
) -> Result<(Option<u64>, Result<Reply, String>), String> {
    let mut rd = BinReader::new(payload);
    let status = rd.u8()?;
    let flags = rd.u8()?;
    if flags & !FLAG_REQ_ID != 0 {
        return Err(format!("unknown reply flags {flags:#04x}"));
    }
    let req_id = if flags & FLAG_REQ_ID != 0 {
        Some(rd.u64()?)
    } else {
        None
    };
    if status == STATUS_ERR {
        return Ok((req_id, Err(rd.str_()?.to_string())));
    }
    if status != STATUS_OK {
        return Err(format!("unknown reply status {status}"));
    }
    let ty = rd.u8()?;
    let reply = match ty {
        REPLY_SIGNATURE => {
            let n = rd.u32()? as usize;
            if rd.remaining() < n.saturating_mul(4) {
                return Err(format!("signature declares {n} entries, frame truncated"));
            }
            let mut s = Vec::with_capacity(n);
            for _ in 0..n {
                s.push(rd.i32()?);
            }
            Reply::Signature(s)
        }
        REPLY_INSERTED => Reply::Inserted { id: rd.u64()? },
        REPLY_HITS => {
            let n = rd.u32()? as usize;
            if rd.remaining() < n.saturating_mul(16) {
                return Err(format!("hits declare {n} entries, frame truncated"));
            }
            let mut hits = Vec::with_capacity(n);
            for _ in 0..n {
                let id = rd.u64()?;
                let distance = rd.f64()?;
                hits.push(Hit { id, distance });
            }
            Reply::Hits(hits)
        }
        REPLY_REMOVED => Reply::Removed { id: rd.u64()? },
        REPLY_METRICS => Reply::Metrics(
            json::parse(rd.str_()?).map_err(|e| format!("bad metrics json: {e}"))?,
        ),
        REPLY_SNAPSHOT => {
            let path = rd.str_()?.to_string();
            let bytes = rd.u64()?;
            Reply::Snapshotted { path, bytes }
        }
        REPLY_PONG => Reply::Pong { indexed: rd.u64()? },
        REPLY_POINTS => {
            let n = rd.u32()? as usize;
            if rd.remaining() < n.saturating_mul(8) {
                return Err(format!("points declare {n} entries, frame truncated"));
            }
            let mut p = Vec::with_capacity(n);
            for _ in 0..n {
                p.push(rd.f64()?);
            }
            Reply::Points(p)
        }
        REPLY_SHUTTING_DOWN => Reply::ShuttingDown,
        other => return Err(format!("unknown binary reply type {other}")),
    };
    if !rd.finished() {
        return Err(format!(
            "{} trailing bytes after the reply body",
            rd.remaining()
        ));
    }
    Ok((req_id, Ok(reply)))
}

// ------------------------------------------------ JSON request builders

fn request_envelope(req_id: Option<u64>, mut fields: Vec<(&str, Value)>) -> String {
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

fn samples_value(samples: &[f32]) -> Value {
    Value::Array(samples.iter().map(|&x| Value::Number(x as f64)).collect())
}

/// Encode a `hash` request line (JSON).
pub fn encode_hash(req_id: Option<u64>, samples: &[f32]) -> String {
    request_envelope(
        req_id,
        vec![("op", "hash".into()), ("samples", samples_value(samples))],
    )
}

/// Encode an `insert` request line (JSON).
pub fn encode_insert(req_id: Option<u64>, id: u64, samples: &[f32]) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "insert".into()),
            ("id", (id as usize).into()),
            ("samples", samples_value(samples)),
        ],
    )
}

/// Encode a `query` request line (JSON).
pub fn encode_query(req_id: Option<u64>, samples: &[f32], k: usize) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "query".into()),
            ("samples", samples_value(samples)),
            ("k", k.into()),
        ],
    )
}

/// Encode a `remove` request line (JSON).
pub fn encode_remove(req_id: Option<u64>, id: u64) -> String {
    request_envelope(
        req_id,
        vec![("op", "remove".into()), ("id", (id as usize).into())],
    )
}

/// Encode a bare admin/transport request line (`metrics`, `ping`,
/// `points`, `shutdown`) (JSON).
pub fn encode_bare(req_id: Option<u64>, op: &str) -> String {
    request_envelope(req_id, vec![("op", op.into())])
}

/// Encode a `snapshot` request line (JSON).
pub fn encode_snapshot(req_id: Option<u64>, path: &str) -> String {
    request_envelope(
        req_id,
        vec![("op", "snapshot".into()), ("path", path.into())],
    )
}

// ---------------------------------------------- binary request builders

/// Encode a `hash` request frame (binary).
pub fn encode_hash_binary(req_id: Option<u64>, samples: &[f32]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_HASH, req_id);
        put_samples(b, samples);
    })
}

/// Encode an `insert` request frame (binary; the id is a native `u64` —
/// no 2^53 precision limit).
pub fn encode_insert_binary(req_id: Option<u64>, id: u64, samples: &[f32]) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_INSERT, req_id);
        b.extend_from_slice(&id.to_le_bytes());
        put_samples(b, samples);
    })
}

/// Encode a `query` request frame (binary). `k` travels as a `u64` so
/// no `usize` value can silently truncate on the wire (JSON/binary
/// parity: both formats carry the caller's `k` intact).
pub fn encode_query_binary(req_id: Option<u64>, samples: &[f32], k: usize) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_QUERY, req_id);
        put_samples(b, samples);
        b.extend_from_slice(&(k as u64).to_le_bytes());
    })
}

/// Encode a `remove` request frame (binary).
pub fn encode_remove_binary(req_id: Option<u64>, id: u64) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_REMOVE, req_id);
        b.extend_from_slice(&id.to_le_bytes());
    })
}

/// Encode a bare admin/transport request frame (binary). An unknown op
/// name encodes as the reserved tag 0, which the server answers with its
/// unknown-op error envelope — the same outcome the JSON format gives an
/// unknown `"op"` string, so the two modes never diverge into a panic.
pub fn encode_bare_binary(req_id: Option<u64>, op: &str) -> Vec<u8> {
    let tag = match op {
        "metrics" => OP_METRICS,
        "ping" => OP_PING,
        "points" => OP_POINTS,
        "shutdown" => OP_SHUTDOWN,
        _ => 0,
    };
    bin_frame(|b| put_tag_and_req_id(b, tag, req_id))
}

/// Encode a `snapshot` request frame (binary).
pub fn encode_snapshot_binary(req_id: Option<u64>, path: &str) -> Vec<u8> {
    bin_frame(|b| {
        put_tag_and_req_id(b, OP_SNAPSHOT, req_id);
        put_str(b, path);
    })
}

// --------------------------------------- mode-dispatch request builders

/// Encode a `hash` request as complete wire bytes for `mode`.
pub fn encode_hash_frame(mode: WireMode, req_id: Option<u64>, samples: &[f32]) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_hash(req_id, samples)),
        WireMode::Binary => encode_hash_binary(req_id, samples),
    }
}

/// Encode an `insert` request as complete wire bytes for `mode`.
pub fn encode_insert_frame(
    mode: WireMode,
    req_id: Option<u64>,
    id: u64,
    samples: &[f32],
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_insert(req_id, id, samples)),
        WireMode::Binary => encode_insert_binary(req_id, id, samples),
    }
}

/// Encode a `query` request as complete wire bytes for `mode`.
pub fn encode_query_frame(
    mode: WireMode,
    req_id: Option<u64>,
    samples: &[f32],
    k: usize,
) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_query(req_id, samples, k)),
        WireMode::Binary => encode_query_binary(req_id, samples, k),
    }
}

/// Encode a `remove` request as complete wire bytes for `mode`.
pub fn encode_remove_frame(mode: WireMode, req_id: Option<u64>, id: u64) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_remove(req_id, id)),
        WireMode::Binary => encode_remove_binary(req_id, id),
    }
}

/// Encode a bare admin/transport request as complete wire bytes.
pub fn encode_bare_frame(mode: WireMode, req_id: Option<u64>, op: &str) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_bare(req_id, op)),
        WireMode::Binary => encode_bare_binary(req_id, op),
    }
}

/// Encode a `snapshot` request as complete wire bytes for `mode`.
pub fn encode_snapshot_frame(mode: WireMode, req_id: Option<u64>, path: &str) -> Vec<u8> {
    match mode {
        WireMode::Json => json_frame(encode_snapshot(req_id, path)),
        WireMode::Binary => encode_snapshot_binary(req_id, path),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SigView;

    #[test]
    fn request_roundtrips() {
        let line = encode_insert(Some(7), 42, &[0.5, -1.25]);
        let req = parse_request(&line).unwrap();
        assert_eq!(req.req_id, Some(7));
        match req.body {
            RequestBody::Op(Op::Insert { id, samples }) => {
                assert_eq!(id, 42);
                assert_eq!(samples, vec![0.5, -1.25]);
            }
            other => panic!("unexpected {other:?}"),
        }

        let req = parse_request(&encode_query(None, &[1.0], 5)).unwrap();
        assert_eq!(req.req_id, None);
        match req.body {
            RequestBody::Op(Op::Query { k, .. }) => assert_eq!(k, 5),
            other => panic!("unexpected {other:?}"),
        }

        match parse_request(&encode_bare(Some(1), "ping")).unwrap().body {
            RequestBody::Op(Op::Ping) => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_bare(None, "shutdown")).unwrap().body {
            RequestBody::Shutdown => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_snapshot(None, "/tmp/x")).unwrap().body {
            RequestBody::Op(Op::Snapshot { path }) => assert_eq!(path, "/tmp/x"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{}").is_err());
        assert!(parse_request(r#"{"op":"teleport"}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","id":1}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","id":-1,"samples":[]}"#).is_err());
        assert!(parse_request(r#"{"op":"query","samples":["x"],"k":1}"#).is_err());
    }

    #[test]
    fn non_finite_samples_rejected_by_both_decoders() {
        // JSON: 1e400 parses as f64 +inf; 1e39 is a finite f64 that
        // overflows f32 to +inf — both must be refused
        for frame in [
            r#"{"op":"hash","samples":[1e400]}"#,
            r#"{"op":"hash","samples":[1e39]}"#,
            r#"{"op":"hash","samples":[-1e39]}"#,
            r#"{"op":"insert","id":1,"samples":[0.5,1e400]}"#,
            r#"{"op":"query","samples":[1e39],"k":1}"#,
        ] {
            let e = parse_request(frame).unwrap_err();
            assert!(e.msg.contains("finite"), "{frame}: {e}");
        }
        // a large-but-representable value still passes
        assert!(parse_request(r#"{"op":"hash","samples":[1e38]}"#).is_ok());

        // binary: raw NaN / inf bits in the sample block
        for bits in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut frame = encode_hash_binary(Some(3), &[0.5, 0.5]);
            // overwrite the second sample's 4 bytes (layout: 4 len + 1 op
            // + 1 flags + 8 req_id + 4 count + 4 first sample)
            frame[22..26].copy_from_slice(&bits.to_le_bytes());
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            let e = parse_request_binary(&frame[4..consumed]).unwrap_err();
            assert_eq!(e.req_id, Some(3), "error must still correlate");
            assert!(e.msg.contains("finite"), "{e}");
        }
    }

    #[test]
    fn parse_errors_recover_req_id_when_json_is_valid() {
        // field-validation failures keep the correlation id…
        let e = parse_request(r#"{"op":"teleport","req_id":7}"#).unwrap_err();
        assert_eq!(e.req_id, Some(7));
        assert!(e.msg.contains("unknown op"), "{e}");
        let e = parse_request(r#"{"op":"insert","id":1,"req_id":9}"#).unwrap_err();
        assert_eq!(e.req_id, Some(9));
        assert!(e.msg.contains("missing field"), "{e}");
        // …but a frame that is not JSON at all has none to recover
        let e = parse_request("not json").unwrap_err();
        assert_eq!(e.req_id, None);
    }

    #[test]
    fn binary_request_roundtrips() {
        // every op through encode → frame split → decode
        let frames: Vec<(Vec<u8>, &str)> = vec![
            (encode_hash_binary(Some(1), &[0.5, -1.25]), "hash"),
            (encode_insert_binary(Some(2), 42, &[1.0]), "insert"),
            (encode_query_binary(None, &[0.25], 7), "query"),
            (encode_remove_binary(Some(4), 9), "remove"),
            (encode_bare_binary(Some(5), "metrics"), "metrics"),
            (encode_snapshot_binary(None, "/tmp/s.flsh"), "snapshot"),
            (encode_bare_binary(Some(7), "ping"), "ping"),
            (encode_bare_binary(None, "points"), "points"),
            (encode_bare_binary(Some(9), "shutdown"), "shutdown"),
        ];
        for (frame, label) in frames {
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len(), "{label}: frame fully framed");
            let req = parse_request_binary(&frame[4..consumed]).unwrap();
            match (label, &req.body) {
                ("hash", RequestBody::Op(Op::Hash { samples })) => {
                    assert_eq!(req.req_id, Some(1));
                    assert_eq!(samples, &vec![0.5, -1.25]);
                }
                ("insert", RequestBody::Op(Op::Insert { id, samples })) => {
                    assert_eq!(req.req_id, Some(2));
                    assert_eq!(*id, 42);
                    assert_eq!(samples, &vec![1.0]);
                }
                ("query", RequestBody::Op(Op::Query { samples, k })) => {
                    assert_eq!(req.req_id, None);
                    assert_eq!(samples, &vec![0.25]);
                    assert_eq!(*k, 7);
                }
                ("remove", RequestBody::Op(Op::Remove { id })) => assert_eq!(*id, 9),
                ("metrics", RequestBody::Op(Op::Metrics)) => {}
                ("snapshot", RequestBody::Op(Op::Snapshot { path })) => {
                    assert_eq!(path, "/tmp/s.flsh")
                }
                ("ping", RequestBody::Op(Op::Ping)) => {}
                ("points", RequestBody::Points) => {}
                ("shutdown", RequestBody::Shutdown) => {}
                (label, other) => panic!("{label}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn binary_ids_above_2_53_survive_where_json_rejects() {
        let big = (1u64 << 60) + 12345; // unrepresentable in f64 exactly
        let frame = encode_insert_binary(Some(1), big, &[0.5]);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::Insert { id, .. }) => assert_eq!(id, big),
            other => panic!("unexpected {other:?}"),
        }
        // the JSON carrier cannot: as_u64 refuses values above 2^53
        let line = format!(r#"{{"op":"remove","id":{big}}}"#);
        assert!(parse_request(&line).is_err());
        // …and the binary remove roundtrips it
        let frame = encode_remove_binary(None, big);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::Remove { id }) => assert_eq!(id, big),
            other => panic!("unexpected {other:?}"),
        }
        // response direction too
        let frame = encode_response_binary(Some(2), &Response::Inserted { id: big });
        let (rid, reply) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(rid, Some(2));
        assert_eq!(reply.unwrap(), Reply::Inserted { id: big });
    }

    #[test]
    fn binary_unknown_bare_op_gets_server_side_error_not_panic() {
        // parity with JSON: an unknown bare-op name reaches the server
        // and comes back as a typed error envelope in both formats
        let frame = encode_bare_binary(Some(9), "status");
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        let e = parse_request_binary(&frame[4..consumed]).unwrap_err();
        assert_eq!(e.req_id, Some(9));
        assert!(e.msg.contains("unknown binary op tag"), "{e}");
    }

    #[test]
    fn binary_query_k_does_not_truncate() {
        // k rides a u64 on the binary wire: a value past u32::MAX must
        // arrive intact, matching the JSON format's behavior
        let big_k = (1usize << 33) + 5;
        let frame = encode_query_binary(Some(1), &[0.5], big_k);
        let consumed = split_binary_frame(&frame).unwrap().unwrap();
        match parse_request_binary(&frame[4..consumed]).unwrap().body {
            RequestBody::Op(Op::Query { k, .. }) => assert_eq!(k, big_k),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn binary_request_errors_are_typed_and_correlated() {
        // unknown op tag, with req_id still recovered
        let frame = bin_frame(|b| put_tag_and_req_id(b, 200, Some(17)));
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(17));
        assert!(e.msg.contains("unknown binary op tag"), "{e}");
        // truncated body: insert with no id
        let frame = bin_frame(|b| put_tag_and_req_id(b, OP_INSERT, Some(3)));
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(3));
        assert!(e.msg.contains("truncated"), "{e}");
        // declared sample count larger than the payload
        let frame = bin_frame(|b| {
            put_tag_and_req_id(b, OP_HASH, Some(4));
            b.extend_from_slice(&1000u32.to_le_bytes());
            b.extend_from_slice(&0.5f32.to_le_bytes());
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(4));
        assert!(e.msg.contains("1000 samples"), "{e}");
        // trailing garbage after a well-formed body
        let mut frame = encode_remove_binary(Some(5), 1);
        frame.extend_from_slice(b"junk");
        let len = (frame.len() - 4) as u32;
        frame[..4].copy_from_slice(&len.to_le_bytes());
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert_eq!(e.req_id, Some(5));
        assert!(e.msg.contains("trailing"), "{e}");
        // unknown header flags
        let frame = bin_frame(|b| {
            b.push(OP_PING);
            b.push(0x80);
        });
        let e = parse_request_binary(&frame[4..]).unwrap_err();
        assert!(e.msg.contains("flags"), "{e}");
        // empty payload
        let e = parse_request_binary(&[]).unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
    }

    #[test]
    fn negotiation_and_framing() {
        assert_eq!(negotiate(b""), Negotiation::NeedMore);
        assert_eq!(negotiate(b"F"), Negotiation::NeedMore);
        assert_eq!(negotiate(b"FBIN"), Negotiation::NeedMore);
        assert_eq!(negotiate(b"FBIN1"), Negotiation::Binary);
        assert_eq!(negotiate(b"FBIN1\x01\x02"), Negotiation::Binary);
        assert_eq!(negotiate(b"{\"op\":\"ping\"}"), Negotiation::Json);
        assert_eq!(negotiate(b"FBINX"), Negotiation::Json);
        assert_eq!(negotiate(b"false"), Negotiation::Json);

        // split: need-more, complete, oversized
        assert_eq!(split_binary_frame(&[1, 0]).unwrap(), None);
        assert_eq!(split_binary_frame(&[2, 0, 0, 0, 9]).unwrap(), None);
        assert_eq!(split_binary_frame(&[2, 0, 0, 0, 9, 9]).unwrap(), Some(6));
        assert_eq!(
            split_binary_frame(&[2, 0, 0, 0, 9, 9, 77]).unwrap(),
            Some(6),
            "extra buffered bytes belong to the next frame"
        );
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        let e = split_binary_frame(&huge).unwrap_err();
        assert!(e.contains("cap"), "{e}");
    }

    fn response_cases() -> Vec<Response> {
        vec![
            Response::Signature(SigView::from_vec(vec![-3, 0, 7])),
            Response::Inserted { id: 9 },
            Response::Hits(vec![Hit {
                id: 4,
                distance: 0.125,
            }]),
            Response::Removed { id: 2 },
            Response::Pong { indexed: 11 },
            Response::Snapshotted {
                path: "/tmp/s.flsh".into(),
                bytes: 640,
            },
        ]
    }

    fn check_reply(decoded: Reply, want: &Response) {
        match (decoded, want) {
            (Reply::Signature(s), Response::Signature(want)) => {
                assert_eq!(s.as_slice(), want.as_slice())
            }
            (Reply::Inserted { id }, Response::Inserted { id: want }) => {
                assert_eq!(id, *want)
            }
            (Reply::Hits(h), Response::Hits(want)) => assert_eq!(&h, want),
            (Reply::Removed { id }, Response::Removed { id: want }) => assert_eq!(id, *want),
            (Reply::Pong { indexed }, Response::Pong { indexed: want }) => {
                assert_eq!(indexed, *want)
            }
            (
                Reply::Snapshotted { path, bytes },
                Response::Snapshotted {
                    path: wp,
                    bytes: wb,
                },
            ) => {
                assert_eq!(&path, wp);
                assert_eq!(bytes, *wb);
            }
            (got, want) => panic!("mismatch: {got:?} vs {want:?}"),
        }
    }

    #[test]
    fn response_roundtrips() {
        for resp in response_cases() {
            let line = encode_response(Some(3), &resp);
            let (req_id, decoded) = decode_reply(&line).unwrap();
            assert_eq!(req_id, Some(3));
            check_reply(decoded.unwrap(), &resp);
        }
    }

    #[test]
    fn binary_response_roundtrips() {
        for resp in response_cases() {
            let frame = encode_response_binary(Some(3), &resp);
            let consumed = split_binary_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len());
            let (req_id, decoded) = decode_reply_binary(&frame[4..consumed]).unwrap();
            assert_eq!(req_id, Some(3), "{resp:?}");
            check_reply(decoded.unwrap(), &resp);
        }
        // without a req_id
        let frame = encode_response_binary(None, &Response::Pong { indexed: 5 });
        let (req_id, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(req_id, None);
        assert_eq!(decoded.unwrap(), Reply::Pong { indexed: 5 });
    }

    #[test]
    fn error_envelope_roundtrips() {
        let line = encode_response(Some(5), &Response::Error("duplicate id 7".into()));
        let (req_id, decoded) = decode_reply(&line).unwrap();
        assert_eq!(req_id, Some(5));
        assert_eq!(decoded.unwrap_err(), "duplicate id 7");
        let (_, decoded) = decode_reply(&encode_error(None, "bad request")).unwrap();
        assert!(decoded.unwrap_err().contains("bad request"));

        // binary error envelopes carry the message and the correlation id
        let frame = encode_response_binary(Some(6), &Response::Error("duplicate id 8".into()));
        let (req_id, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(req_id, Some(6));
        assert_eq!(decoded.unwrap_err(), "duplicate id 8");
    }

    #[test]
    fn points_and_shutdown_roundtrip() {
        let (_, decoded) = decode_reply(&encode_points(None, &[0.25, 0.75])).unwrap();
        assert_eq!(decoded.unwrap(), Reply::Points(vec![0.25, 0.75]));
        let (_, decoded) = decode_reply(&encode_shutting_down(Some(1))).unwrap();
        assert_eq!(decoded.unwrap(), Reply::ShuttingDown);

        let frame = encode_points_binary(Some(2), &[0.25, 0.75]);
        let (rid, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(rid, Some(2));
        assert_eq!(decoded.unwrap(), Reply::Points(vec![0.25, 0.75]));
        let frame = encode_shutting_down_binary(None);
        let (_, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        assert_eq!(decoded.unwrap(), Reply::ShuttingDown);
    }

    #[test]
    fn metrics_reply_carries_object() {
        let m = crate::coordinator::ServiceMetrics::new();
        let line = encode_response(None, &Response::Metrics(m.snapshot()));
        let (_, decoded) = decode_reply(&line).unwrap();
        match decoded.unwrap() {
            Reply::Metrics(v) => assert_eq!(v.get("requests").unwrap().as_usize(), Some(0)),
            other => panic!("unexpected {other:?}"),
        }
        let frame = encode_response_binary(Some(1), &Response::Metrics(m.snapshot()));
        let (_, decoded) = decode_reply_binary(&frame[4..]).unwrap();
        match decoded.unwrap() {
            Reply::Metrics(v) => assert_eq!(v.get("requests").unwrap().as_usize(), Some(0)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn oversized_response_degrades_to_correlated_error() {
        // a hits payload past the frame cap (8 MiB): JSON needs ~26 bytes
        // per hit, binary exactly 16 — 600k hits overflows both
        let hits: Vec<Hit> = (0..600_000)
            .map(|i| Hit {
                id: i,
                distance: i as f64 * 0.001,
            })
            .collect();
        let resp = Response::Hits(hits);
        for mode in [WireMode::Json, WireMode::Binary] {
            let frame = encode_response_frame(mode, Some(42), &resp);
            assert!(
                framed_payload_len(mode, &frame) <= MAX_FRAME_BYTES,
                "{mode:?}: replacement frame must itself fit"
            );
            let (req_id, decoded) = match mode {
                WireMode::Json => {
                    decode_reply(std::str::from_utf8(&frame).unwrap()).unwrap()
                }
                WireMode::Binary => decode_reply_binary(&frame[4..]).unwrap(),
            };
            assert_eq!(req_id, Some(42), "{mode:?}: error must correlate");
            let msg = decoded.unwrap_err();
            assert!(msg.contains("response too large"), "{mode:?}: {msg}");
        }
        // a normal-sized response is passed through untouched
        let small = encode_response_frame(WireMode::Json, Some(1), &Response::Pong { indexed: 3 });
        let (_, decoded) = decode_reply(std::str::from_utf8(&small).unwrap()).unwrap();
        assert_eq!(decoded.unwrap(), Reply::Pong { indexed: 3 });
    }

    #[test]
    fn frame_builders_match_modes() {
        // JSON frame bytes end in newline and parse as the bare line
        let f = encode_hash_frame(WireMode::Json, Some(1), &[0.5]);
        assert_eq!(*f.last().unwrap(), b'\n');
        assert!(parse_request(std::str::from_utf8(&f).unwrap().trim_end()).is_ok());
        // binary frame bytes split and parse
        let f = encode_hash_frame(WireMode::Binary, Some(1), &[0.5]);
        let consumed = split_binary_frame(&f).unwrap().unwrap();
        assert!(parse_request_binary(&f[4..consumed]).is_ok());
        // wire-cost sanity: at dim 256 the binary hash frame is much
        // smaller than the JSON one (the whole point of FBIN1)
        let row: Vec<f32> = (0..256).map(|i| (i as f32) * 0.001 - 0.1).collect();
        let j = encode_hash_frame(WireMode::Json, Some(1), &row).len();
        let b = encode_hash_frame(WireMode::Binary, Some(1), &row).len();
        assert!(b < j / 2, "binary {b} bytes vs json {j} bytes");
    }

    #[test]
    fn wire_mode_parses() {
        assert_eq!(WireMode::parse("json"), Some(WireMode::Json));
        assert_eq!(WireMode::parse("binary"), Some(WireMode::Binary));
        assert_eq!(WireMode::parse("fbin1"), Some(WireMode::Binary));
        assert_eq!(WireMode::parse("carrier-pigeon"), None);
        assert_eq!(WireMode::Json.as_str(), "json");
        assert_eq!(WireMode::Binary.as_str(), "binary");
    }
}
