//! Wire-format encode/decode for the newline-delimited JSON protocol
//! (see the [`crate::server`] module doc for the full frame reference).
//!
//! Both directions are symmetric: the server uses [`parse_request`] +
//! [`encode_response`]; the client uses the `encode_*` request builders +
//! [`decode_reply`]. Everything round-trips through [`crate::json`] — no
//! external serialization crates.

use crate::coordinator::{Op, Response};
use crate::json::{self, object, Value};
use crate::search::Hit;

/// Hard cap on one request/response line; longer frames are a protocol
/// error (protects the server from unbounded buffering).
///
/// Note on integer width: ids and `req_id`s travel as JSON numbers,
/// which this crate's [`crate::json`] (like most JSON stacks) carries
/// as `f64` — values ≥ 2^53 lose precision on the wire. `Value::as_u64`
/// rejects them server-side; clients must keep ids below 2^53 (the
/// ROADMAP's binary-frame follow-up lifts this).
pub const MAX_LINE_BYTES: usize = 8 << 20;

/// A decoded request frame.
#[derive(Debug, Clone)]
pub struct Request {
    /// client correlation id, echoed verbatim in the response
    pub req_id: Option<u64>,
    /// what the client asked for
    pub body: RequestBody,
}

/// The request payload: either a coordinator op (routed through the
/// dynamic batcher) or one of the transport-level ops the server answers
/// directly.
#[derive(Debug, Clone)]
pub enum RequestBody {
    /// a coordinator operation
    Op(Op),
    /// the service's published sample points
    Points,
    /// graceful server shutdown
    Shutdown,
}

fn f32_row(v: &Value) -> Result<Vec<f32>, String> {
    let arr = v.as_array().ok_or("`samples` must be an array")?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| "`samples` must contain only numbers".to_string())
        })
        .collect()
}

fn need<'v>(v: &'v Value, key: &str) -> Result<&'v Value, String> {
    v.get(key).ok_or_else(|| format!("missing field `{key}`"))
}

/// A rejected request line. Carries the `req_id` recovered from the
/// frame (when the JSON parsed far enough to have one), so the error
/// envelope can still correlate — a pipelined client must get a
/// per-request error, not a connection-level failure.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestError {
    /// the frame's correlation id, if it was recoverable
    pub req_id: Option<u64>,
    /// what was wrong with the frame
    pub msg: String,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, RequestError> {
    let v = json::parse(line.trim()).map_err(|e| RequestError {
        req_id: None,
        msg: format!("bad json: {e}"),
    })?;
    let req_id = v.get("req_id").and_then(Value::as_u64);
    let body = (|| -> Result<RequestBody, String> {
        let op = v
            .get("op")
            .and_then(Value::as_str)
            .ok_or("missing string field `op`")?;
        Ok(match op {
            "hash" => RequestBody::Op(Op::Hash {
                samples: f32_row(need(&v, "samples")?)?,
            }),
            "insert" => RequestBody::Op(Op::Insert {
                id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
                samples: f32_row(need(&v, "samples")?)?,
            }),
            "query" => RequestBody::Op(Op::Query {
                samples: f32_row(need(&v, "samples")?)?,
                k: need(&v, "k")?.as_usize().ok_or("`k` must be a usize")?,
            }),
            "remove" => RequestBody::Op(Op::Remove {
                id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
            }),
            "metrics" => RequestBody::Op(Op::Metrics),
            "snapshot" => RequestBody::Op(Op::Snapshot {
                path: need(&v, "path")?
                    .as_str()
                    .ok_or("`path` must be a string")?
                    .to_string(),
            }),
            "ping" => RequestBody::Op(Op::Ping),
            "points" => RequestBody::Points,
            "shutdown" => RequestBody::Shutdown,
            other => return Err(format!("unknown op `{other}`")),
        })
    })()
    .map_err(|msg| RequestError { req_id, msg })?;
    Ok(Request { req_id, body })
}

fn envelope(req_id: Option<u64>, mut fields: Vec<(&str, Value)>) -> String {
    fields.push(("ok", true.into()));
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

/// Encode an error response line.
pub fn encode_error(req_id: Option<u64>, msg: &str) -> String {
    let mut fields: Vec<(&str, Value)> = vec![("ok", false.into()), ("error", msg.into())];
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

/// Encode a coordinator response line.
pub fn encode_response(req_id: Option<u64>, resp: &Response) -> String {
    match resp {
        Response::Signature(sig) => envelope(
            req_id,
            vec![
                ("type", "signature".into()),
                (
                    "signature",
                    Value::Array(sig.iter().map(|&x| Value::Number(x as f64)).collect()),
                ),
            ],
        ),
        Response::Inserted { id } => envelope(
            req_id,
            vec![("type", "inserted".into()), ("id", (*id as usize).into())],
        ),
        Response::Hits(hits) => envelope(
            req_id,
            vec![
                ("type", "hits".into()),
                (
                    "hits",
                    Value::Array(
                        hits.iter()
                            .map(|h| {
                                object(vec![
                                    ("id", (h.id as usize).into()),
                                    ("distance", h.distance.into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ],
        ),
        Response::Removed { id } => envelope(
            req_id,
            vec![("type", "removed".into()), ("id", (*id as usize).into())],
        ),
        Response::Metrics(m) => envelope(
            req_id,
            vec![("type", "metrics".into()), ("metrics", m.to_value())],
        ),
        Response::Snapshotted { path, bytes } => envelope(
            req_id,
            vec![
                ("type", "snapshot".into()),
                ("path", path.as_str().into()),
                ("bytes", (*bytes as usize).into()),
            ],
        ),
        Response::Pong { indexed } => envelope(
            req_id,
            vec![
                ("type", "pong".into()),
                ("indexed", (*indexed as usize).into()),
            ],
        ),
        Response::Error(e) => encode_error(req_id, e),
    }
}

/// Encode the transport-level `points` response.
pub fn encode_points(req_id: Option<u64>, points: &[f64]) -> String {
    envelope(
        req_id,
        vec![
            ("type", "points".into()),
            (
                "points",
                Value::Array(points.iter().map(|&x| Value::Number(x)).collect()),
            ),
        ],
    )
}

/// Encode the transport-level `shutdown` acknowledgement.
pub fn encode_shutting_down(req_id: Option<u64>) -> String {
    envelope(req_id, vec![("type", "shutting_down".into())])
}

// ---------------------------------------------------------------- client

/// A decoded server reply (the client-side mirror of
/// [`encode_response`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// `hash` result
    Signature(Vec<i32>),
    /// `insert` ack
    Inserted {
        /// inserted id
        id: u64,
    },
    /// `query` result
    Hits(Vec<Hit>),
    /// `remove` ack
    Removed {
        /// removed id
        id: u64,
    },
    /// `metrics` result (kept as a JSON object)
    Metrics(Value),
    /// `snapshot` ack
    Snapshotted {
        /// snapshot destination
        path: String,
        /// bytes written
        bytes: u64,
    },
    /// `ping` ack
    Pong {
        /// entries indexed server-side
        indexed: u64,
    },
    /// `points` result
    Points(Vec<f64>),
    /// `shutdown` ack
    ShuttingDown,
}

/// Decode one reply line into `(req_id, server result)`. The outer
/// `Err` is a protocol violation (unparseable frame); the inner
/// `Err(String)` is a well-formed server-side error envelope.
#[allow(clippy::type_complexity)]
pub fn decode_reply(line: &str) -> Result<(Option<u64>, Result<Reply, String>), String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad reply json: {e}"))?;
    let req_id = v.get("req_id").and_then(Value::as_u64);
    let ok = v
        .get("ok")
        .and_then(|b| match b {
            Value::Bool(b) => Some(*b),
            _ => None,
        })
        .ok_or("reply missing bool field `ok`")?;
    if !ok {
        let msg = v
            .get("error")
            .and_then(Value::as_str)
            .unwrap_or("unspecified server error")
            .to_string();
        return Ok((req_id, Err(msg)));
    }
    let ty = v
        .get("type")
        .and_then(Value::as_str)
        .ok_or("reply missing string field `type`")?;
    let reply = match ty {
        "signature" => Reply::Signature(
            need(&v, "signature")?
                .as_array()
                .ok_or("`signature` must be an array")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as i32)
                        .ok_or_else(|| "`signature` must contain numbers".to_string())
                })
                .collect::<Result<_, _>>()?,
        ),
        "inserted" => Reply::Inserted {
            id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
        },
        "hits" => Reply::Hits(
            need(&v, "hits")?
                .as_array()
                .ok_or("`hits` must be an array")?
                .iter()
                .map(|h| -> Result<Hit, String> {
                    Ok(Hit {
                        id: need(h, "id")?.as_u64().ok_or("hit `id` must be a u64")?,
                        distance: need(h, "distance")?
                            .as_f64()
                            .ok_or("hit `distance` must be a number")?,
                    })
                })
                .collect::<Result<_, _>>()?,
        ),
        "removed" => Reply::Removed {
            id: need(&v, "id")?.as_u64().ok_or("`id` must be a u64")?,
        },
        "metrics" => Reply::Metrics(need(&v, "metrics")?.clone()),
        "snapshot" => Reply::Snapshotted {
            path: need(&v, "path")?
                .as_str()
                .ok_or("`path` must be a string")?
                .to_string(),
            bytes: need(&v, "bytes")?.as_u64().ok_or("`bytes` must be a u64")?,
        },
        "pong" => Reply::Pong {
            indexed: need(&v, "indexed")?
                .as_u64()
                .ok_or("`indexed` must be a u64")?,
        },
        "points" => Reply::Points(
            need(&v, "points")?
                .as_array()
                .ok_or("`points` must be an array")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| "`points` must contain numbers".to_string())
                })
                .collect::<Result<_, _>>()?,
        ),
        "shutting_down" => Reply::ShuttingDown,
        other => return Err(format!("unknown reply type `{other}`")),
    };
    Ok((req_id, Ok(reply)))
}

fn request_envelope(req_id: Option<u64>, mut fields: Vec<(&str, Value)>) -> String {
    if let Some(id) = req_id {
        fields.push(("req_id", (id as usize).into()));
    }
    object(fields).to_json()
}

fn samples_value(samples: &[f32]) -> Value {
    Value::Array(samples.iter().map(|&x| Value::Number(x as f64)).collect())
}

/// Encode a `hash` request line.
pub fn encode_hash(req_id: Option<u64>, samples: &[f32]) -> String {
    request_envelope(
        req_id,
        vec![("op", "hash".into()), ("samples", samples_value(samples))],
    )
}

/// Encode an `insert` request line.
pub fn encode_insert(req_id: Option<u64>, id: u64, samples: &[f32]) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "insert".into()),
            ("id", (id as usize).into()),
            ("samples", samples_value(samples)),
        ],
    )
}

/// Encode a `query` request line.
pub fn encode_query(req_id: Option<u64>, samples: &[f32], k: usize) -> String {
    request_envelope(
        req_id,
        vec![
            ("op", "query".into()),
            ("samples", samples_value(samples)),
            ("k", k.into()),
        ],
    )
}

/// Encode a `remove` request line.
pub fn encode_remove(req_id: Option<u64>, id: u64) -> String {
    request_envelope(
        req_id,
        vec![("op", "remove".into()), ("id", (id as usize).into())],
    )
}

/// Encode a bare admin/transport request line (`metrics`, `ping`,
/// `points`, `shutdown`).
pub fn encode_bare(req_id: Option<u64>, op: &str) -> String {
    request_envelope(req_id, vec![("op", op.into())])
}

/// Encode a `snapshot` request line.
pub fn encode_snapshot(req_id: Option<u64>, path: &str) -> String {
    request_envelope(
        req_id,
        vec![("op", "snapshot".into()), ("path", path.into())],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrips() {
        let line = encode_insert(Some(7), 42, &[0.5, -1.25]);
        let req = parse_request(&line).unwrap();
        assert_eq!(req.req_id, Some(7));
        match req.body {
            RequestBody::Op(Op::Insert { id, samples }) => {
                assert_eq!(id, 42);
                assert_eq!(samples, vec![0.5, -1.25]);
            }
            other => panic!("unexpected {other:?}"),
        }

        let req = parse_request(&encode_query(None, &[1.0], 5)).unwrap();
        assert_eq!(req.req_id, None);
        match req.body {
            RequestBody::Op(Op::Query { k, .. }) => assert_eq!(k, 5),
            other => panic!("unexpected {other:?}"),
        }

        match parse_request(&encode_bare(Some(1), "ping")).unwrap().body {
            RequestBody::Op(Op::Ping) => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_bare(None, "shutdown")).unwrap().body {
            RequestBody::Shutdown => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_request(&encode_snapshot(None, "/tmp/x")).unwrap().body {
            RequestBody::Op(Op::Snapshot { path }) => assert_eq!(path, "/tmp/x"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{}").is_err());
        assert!(parse_request(r#"{"op":"teleport"}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","id":1}"#).is_err());
        assert!(parse_request(r#"{"op":"insert","id":-1,"samples":[]}"#).is_err());
        assert!(parse_request(r#"{"op":"query","samples":["x"],"k":1}"#).is_err());
    }

    #[test]
    fn parse_errors_recover_req_id_when_json_is_valid() {
        // field-validation failures keep the correlation id…
        let e = parse_request(r#"{"op":"teleport","req_id":7}"#).unwrap_err();
        assert_eq!(e.req_id, Some(7));
        assert!(e.msg.contains("unknown op"), "{e}");
        let e = parse_request(r#"{"op":"insert","id":1,"req_id":9}"#).unwrap_err();
        assert_eq!(e.req_id, Some(9));
        assert!(e.msg.contains("missing field"), "{e}");
        // …but a frame that is not JSON at all has none to recover
        let e = parse_request("not json").unwrap_err();
        assert_eq!(e.req_id, None);
    }

    #[test]
    fn response_roundtrips() {
        let cases = vec![
            Response::Signature(vec![-3, 0, 7]),
            Response::Inserted { id: 9 },
            Response::Hits(vec![Hit {
                id: 4,
                distance: 0.125,
            }]),
            Response::Removed { id: 2 },
            Response::Pong { indexed: 11 },
            Response::Snapshotted {
                path: "/tmp/s.flsh".into(),
                bytes: 640,
            },
        ];
        for resp in cases {
            let line = encode_response(Some(3), &resp);
            let (req_id, decoded) = decode_reply(&line).unwrap();
            assert_eq!(req_id, Some(3));
            match (decoded.unwrap(), &resp) {
                (Reply::Signature(s), Response::Signature(want)) => assert_eq!(&s, want),
                (Reply::Inserted { id }, Response::Inserted { id: want }) => {
                    assert_eq!(id, *want)
                }
                (Reply::Hits(h), Response::Hits(want)) => assert_eq!(&h, want),
                (Reply::Removed { id }, Response::Removed { id: want }) => assert_eq!(id, *want),
                (Reply::Pong { indexed }, Response::Pong { indexed: want }) => {
                    assert_eq!(indexed, *want)
                }
                (
                    Reply::Snapshotted { path, bytes },
                    Response::Snapshotted {
                        path: wp,
                        bytes: wb,
                    },
                ) => {
                    assert_eq!(&path, wp);
                    assert_eq!(bytes, *wb);
                }
                (got, want) => panic!("mismatch: {got:?} vs {want:?}"),
            }
        }
    }

    #[test]
    fn error_envelope_roundtrips() {
        let line = encode_response(Some(5), &Response::Error("duplicate id 7".into()));
        let (req_id, decoded) = decode_reply(&line).unwrap();
        assert_eq!(req_id, Some(5));
        assert_eq!(decoded.unwrap_err(), "duplicate id 7");
        let (_, decoded) = decode_reply(&encode_error(None, "bad request")).unwrap();
        assert!(decoded.unwrap_err().contains("bad request"));
    }

    #[test]
    fn points_and_shutdown_roundtrip() {
        let (_, decoded) = decode_reply(&encode_points(None, &[0.25, 0.75])).unwrap();
        assert_eq!(decoded.unwrap(), Reply::Points(vec![0.25, 0.75]));
        let (_, decoded) = decode_reply(&encode_shutting_down(Some(1))).unwrap();
        assert_eq!(decoded.unwrap(), Reply::ShuttingDown);
    }

    #[test]
    fn metrics_reply_carries_object() {
        let m = crate::coordinator::ServiceMetrics::new();
        let line = encode_response(None, &Response::Metrics(m.snapshot()));
        let (_, decoded) = decode_reply(&line).unwrap();
        match decoded.unwrap() {
            Reply::Metrics(v) => assert_eq!(v.get("requests").unwrap().as_usize(), Some(0)),
            other => panic!("unexpected {other:?}"),
        }
    }
}
