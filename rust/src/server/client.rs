//! Clients for the wire protocol — a blocking one-in-flight [`Client`],
//! a windowed [`PipelinedClient`] that keeps several frames in flight and
//! correlates responses by `req_id`, and a multi-threaded load generator
//! with nanosecond-resolution latency histograms (closed-loop by
//! default, open-loop at a target arrival rate with `LoadConfig::rate`
//! / `funclsh load --rate`). Both clients also
//! speak the batched ops (`hash_batch`/`insert_batch`/`query_batch` —
//! N rows per frame with per-item results; `funclsh load --batch N`)
//! and transparently reassemble oversized batch replies that the server
//! streams as `batch_part` continuation frames.
//! All three speak either
//! wire format ([`WireMode`]): JSON is the default, binary
//! (`connect_with(addr, WireMode::Binary)` / `funclsh load --wire
//! binary`) opens with the `FBIN1` magic and ships sample rows as raw
//! `f32`s. The repo can drive its own serving layer end-to-end over
//! loopback (`funclsh load`, `examples/e2e_service.rs`,
//! `benches/server_bench.rs`).

use super::protocol::{self, Reply, WireMode};
use crate::coordinator::{EntryRecord, StatsDetail};
use crate::functions::{Function1D, Sine};
use crate::json::{object, Value};
use crate::search::Hit;
use crate::util::rng::{Rng64, Xoshiro256pp};
use crate::util::stats::quantile_sorted;
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, ErrorKind, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Client-side failure.
#[derive(Debug)]
pub enum ClientError {
    /// transport failure
    Io(std::io::Error),
    /// unparseable or out-of-order frame
    Protocol(String),
    /// well-formed server error envelope
    Server(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol: {e}"),
            ClientError::Server(e) => write!(f, "server: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl ClientError {
    /// Whether this failure is worth retrying against the same address:
    /// transport failures and connection closes (a restarting shard), or
    /// a typed `overloaded` shed (the server asked for backoff). Real
    /// request errors and protocol violations are not transient.
    pub fn is_transient(&self) -> bool {
        match self {
            ClientError::Io(_) => true,
            ClientError::Protocol(msg) => msg.contains("closed connection"),
            ClientError::Server(msg) => protocol::error_is_overloaded(msg),
        }
    }
}

/// Deterministic capped-exponential retry schedule shared by the
/// reconnecting clients, the load generator, and the cluster router:
/// attempt `a` sleeps `min(base << a, cap)` before retrying. No jitter —
/// every retry timeline in this repo is reproducible by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// retries after the first attempt (0 = fail on first error)
    pub attempts: usize,
    /// backoff before the first retry
    pub base: Duration,
    /// upper bound the doubling saturates at
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            attempts: 5,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    /// Policy from millisecond knobs (the `[cluster]` config spelling).
    pub fn new(attempts: usize, base_ms: u64, cap_ms: u64) -> Self {
        Self {
            attempts,
            base: Duration::from_millis(base_ms),
            cap: Duration::from_millis(cap_ms.max(base_ms)),
        }
    }

    /// The sleep before retry number `attempt` (0-based): capped
    /// exponential doubling of `base`.
    pub fn backoff(&self, attempt: usize) -> Duration {
        let mult = 1u32 << attempt.min(20) as u32;
        self.base.saturating_mul(mult).min(self.cap)
    }
}

/// Read one raw reply frame in `wire` format off a buffered stream (the
/// framing itself lives in [`protocol::read_frame`] — the blocking
/// mirror of the server's `Framer`). `in_flight` is folded into the
/// disconnect error so pipelined callers report how many requests the
/// close orphaned.
#[allow(clippy::type_complexity)]
fn read_one_frame(
    reader: &mut BufReader<TcpStream>,
    wire: WireMode,
    in_flight: usize,
) -> Result<(Option<u64>, Result<Reply, String>), ClientError> {
    let closed = || {
        ClientError::Protocol(if in_flight > 0 {
            format!("server closed connection with {in_flight} in flight")
        } else {
            "server closed connection".to_string()
        })
    };
    let payload = match protocol::read_frame(reader, wire) {
        Ok(Some(p)) => p,
        Ok(None) => return Err(closed()),
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Err(closed()),
        Err(e) if e.kind() == ErrorKind::InvalidData => {
            return Err(ClientError::Protocol(e.to_string()))
        }
        Err(e) => return Err(ClientError::Io(e)),
    };
    match wire {
        WireMode::Json => {
            let line = std::str::from_utf8(&payload)
                .map_err(|_| ClientError::Protocol("invalid utf-8 in reply".into()))?;
            protocol::decode_reply(line).map_err(ClientError::Protocol)
        }
        WireMode::Binary => protocol::decode_reply_binary(&payload).map_err(ClientError::Protocol),
    }
}

/// Read one *logical* reply: a plain frame, or a run of `batch_part`
/// continuation frames reassembled into the full [`Reply::Batch`].
/// Oversized batch responses stream as continuations (the server caps
/// every frame at `MAX_FRAME_BYTES`); callers above this function never
/// see a partial batch.
#[allow(clippy::type_complexity)]
fn read_reply_frame(
    reader: &mut BufReader<TcpStream>,
    wire: WireMode,
    in_flight: usize,
) -> Result<(Option<u64>, Result<Reply, String>), ClientError> {
    let (first_id, body) = read_one_frame(reader, wire, in_flight)?;
    let (mut more, mut items) = match body {
        Ok(Reply::BatchPart { more, items }) => (more, items),
        other => return Ok((first_id, other)),
    };
    while more {
        let (id, body) = read_one_frame(reader, wire, in_flight)?;
        if id != first_id {
            return Err(ClientError::Protocol(format!(
                "continuation frame changed req_id: stream {first_id:?}, frame {id:?}"
            )));
        }
        match body {
            Ok(Reply::BatchPart {
                more: m,
                items: part,
            }) => {
                items.extend(part);
                more = m;
            }
            Ok(other) => {
                return Err(ClientError::Protocol(format!(
                    "expected batch_part continuation, got {other:?}"
                )))
            }
            Err(e) => {
                return Err(ClientError::Protocol(format!(
                    "server error inside a batch_part stream: {e}"
                )))
            }
        }
    }
    Ok((first_id, Ok(Reply::Batch(items))))
}

/// Rows-per-frame sanity for the batch senders: the contiguous buffer
/// must hold a whole positive number of `dim`-wide rows — a ragged
/// buffer would mis-frame differently per wire format (JSON ships the
/// ceil, binary the floor), surfacing as a confusing server-side error
/// instead of this one client-side message.
fn batch_count(rows: &[f32], dim: usize) -> Result<usize, ClientError> {
    if dim == 0 || rows.is_empty() || rows.len() % dim != 0 {
        return Err(ClientError::Protocol(format!(
            "batch rows buffer of {} samples is not a positive multiple of dim {dim}",
            rows.len()
        )));
    }
    Ok(rows.len() / dim)
}

/// [`batch_count`] plus the one-id-per-row rule of `insert_batch`
/// (shared by the blocking and pipelined senders).
fn insert_batch_count(ids: &[u64], rows: &[f32], dim: usize) -> Result<usize, ClientError> {
    let count = batch_count(rows, dim)?;
    if count != ids.len() {
        return Err(ClientError::Protocol(format!(
            "{} ids but {count} rows of dim {dim}",
            ids.len()
        )));
    }
    Ok(count)
}

/// A blocking connection to a funclsh server: one in-flight request at
/// a time, correlated by `req_id`.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_req_id: u64,
    wire: WireMode,
}

impl Client {
    /// Connect to `addr` (e.g. `"127.0.0.1:7070"` or a `SocketAddr`) in
    /// the default JSON wire mode.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self, ClientError> {
        Self::connect_with(addr, WireMode::Json)
    }

    /// Connect in an explicit wire mode. Binary connections announce
    /// themselves with the `FBIN1` magic (queued here, flushed with the
    /// first request frame).
    pub fn connect_with<A: ToSocketAddrs>(addr: A, wire: WireMode) -> Result<Self, ClientError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        if wire == WireMode::Binary {
            protocol::write_magic(&mut writer)?;
        }
        Ok(Self {
            reader,
            writer,
            next_req_id: 1,
            wire,
        })
    }

    /// Connect with retry-and-backoff on transient connect failures (a
    /// shard that is restarting): up to `policy.attempts` retries, then a
    /// typed give-up error naming the budget. Used by the cluster router
    /// and the migration driver to ride out shard restarts.
    pub fn connect_with_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        wire: WireMode,
        policy: &RetryPolicy,
    ) -> Result<Self, ClientError> {
        let mut attempt = 0usize;
        loop {
            match Self::connect_with(addr.clone(), wire) {
                Ok(c) => return Ok(c),
                Err(e) if e.is_transient() && attempt < policy.attempts => {
                    std::thread::sleep(policy.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => {
                    return Err(ClientError::Protocol(format!(
                        "gave up connecting after {} attempt(s): {e}",
                        attempt + 1
                    )))
                }
            }
        }
    }

    /// This connection's wire mode.
    pub fn wire(&self) -> WireMode {
        self.wire
    }

    /// Bound every subsequent reply read: a server (or black-holed
    /// shard) that does not answer within `timeout` surfaces as a
    /// transient [`ClientError::Io`] instead of hanging the caller. The
    /// cluster router sets this to its per-shard request timeout —
    /// after an expiry the connection may hold a half-read reply, so
    /// callers must reconnect rather than reuse it.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<(), ClientError> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    fn call(&mut self, frame: Vec<u8>, req_id: u64) -> Result<Reply, ClientError> {
        self.writer.write_all(&frame)?;
        self.writer.flush()?;
        let (got_id, body) = read_reply_frame(&mut self.reader, self.wire, 0)?;
        if got_id != Some(req_id) {
            return Err(ClientError::Protocol(format!(
                "req_id mismatch: sent {req_id}, got {got_id:?}"
            )));
        }
        body.map_err(ClientError::Server)
    }

    fn next_id(&mut self) -> u64 {
        let id = self.next_req_id;
        self.next_req_id += 1;
        id
    }

    /// `hash`: signature of a sample row.
    pub fn hash(&mut self, samples: &[f32]) -> Result<Vec<i32>, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_hash_frame(self.wire, Some(rid), samples);
        match self.call(frame, rid)? {
            Reply::Signature(s) => Ok(s),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `insert`: add an entry.
    pub fn insert(&mut self, id: u64, samples: &[f32]) -> Result<(), ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_insert_frame(self.wire, Some(rid), id, samples);
        match self.call(frame, rid)? {
            Reply::Inserted { id: got } if got == id => Ok(()),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `query`: k-NN with exact re-ranking.
    pub fn query(&mut self, samples: &[f32], k: usize) -> Result<Vec<Hit>, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_query_frame(self.wire, Some(rid), samples, k);
        match self.call(frame, rid)? {
            Reply::Hits(h) => Ok(h),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `remove`: delete an entry.
    pub fn remove(&mut self, id: u64) -> Result<(), ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_remove_frame(self.wire, Some(rid), id);
        match self.call(frame, rid)? {
            Reply::Removed { id: got } if got == id => Ok(()),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `hash_batch`: signatures of `rows.len()/dim` contiguous sample
    /// rows shipped in **one frame**; per-row results in row order (a
    /// row the server refused comes back as that slot's `Err`).
    #[allow(clippy::type_complexity)]
    pub fn hash_batch(
        &mut self,
        rows: &[f32],
        dim: usize,
    ) -> Result<Vec<Result<Vec<i32>, String>>, ClientError> {
        batch_count(rows, dim)?;
        let rid = self.next_id();
        let frame = protocol::encode_hash_batch_frame(self.wire, Some(rid), rows, dim);
        match self.call(frame, rid)? {
            Reply::Batch(items) => items
                .into_iter()
                .map(|item| match item {
                    Ok(Reply::Signature(s)) => Ok(Ok(s)),
                    Ok(other) => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
                    Err(e) => Ok(Err(e)),
                })
                .collect(),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `insert_batch`: insert `ids.len()` entries in one frame; per-row
    /// acks/errors in row order.
    pub fn insert_batch(
        &mut self,
        ids: &[u64],
        rows: &[f32],
        dim: usize,
    ) -> Result<Vec<Result<u64, String>>, ClientError> {
        insert_batch_count(ids, rows, dim)?;
        let rid = self.next_id();
        let frame = protocol::encode_insert_batch_frame(self.wire, Some(rid), ids, rows, dim);
        match self.call(frame, rid)? {
            Reply::Batch(items) => items
                .into_iter()
                .map(|item| match item {
                    Ok(Reply::Inserted { id }) => Ok(Ok(id)),
                    Ok(other) => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
                    Err(e) => Ok(Err(e)),
                })
                .collect(),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `query_batch`: k-NN for `rows.len()/dim` rows in one frame;
    /// per-row hit lists (or errors) in row order.
    #[allow(clippy::type_complexity)]
    pub fn query_batch(
        &mut self,
        rows: &[f32],
        dim: usize,
        k: usize,
    ) -> Result<Vec<Result<Vec<Hit>, String>>, ClientError> {
        batch_count(rows, dim)?;
        let rid = self.next_id();
        let frame = protocol::encode_query_batch_frame(self.wire, Some(rid), rows, dim, k);
        match self.call(frame, rid)? {
            Reply::Batch(items) => items
                .into_iter()
                .map(|item| match item {
                    Ok(Reply::Hits(h)) => Ok(Ok(h)),
                    Ok(other) => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
                    Err(e) => Ok(Err(e)),
                })
                .collect(),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `metrics`: service metrics as a JSON object.
    pub fn metrics(&mut self) -> Result<Value, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_bare_frame(self.wire, Some(rid), "metrics");
        match self.call(frame, rid)? {
            Reply::Metrics(v) => Ok(v),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `stats`: one observability view (summary / stages / index / slow)
    /// as a JSON object (`funclsh stats`).
    pub fn stats(&mut self, detail: StatsDetail) -> Result<Value, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_stats_frame(self.wire, Some(rid), detail);
        match self.call(frame, rid)? {
            Reply::Stats(v) => Ok(v),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `snapshot`: server-side FLSH1 dump; returns bytes written.
    pub fn snapshot(&mut self, path: &str) -> Result<u64, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_snapshot_frame(self.wire, Some(rid), path);
        match self.call(frame, rid)? {
            Reply::Snapshotted { bytes, .. } => Ok(bytes),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `ping`: liveness probe; returns the indexed entry count.
    pub fn ping(&mut self) -> Result<u64, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_bare_frame(self.wire, Some(rid), "ping");
        match self.call(frame, rid)? {
            Reply::Pong { indexed } => Ok(indexed),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `points`: the service's published sample points.
    pub fn points(&mut self) -> Result<Vec<f64>, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_bare_frame(self.wire, Some(rid), "points");
        match self.call(frame, rid)? {
            Reply::Points(p) => Ok(p),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `shutdown`: request graceful server shutdown.
    pub fn shutdown_server(&mut self) -> Result<(), ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_bare_frame(self.wire, Some(rid), "shutdown");
        match self.call(frame, rid)? {
            Reply::ShuttingDown => Ok(()),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `query` against a cluster router, surfacing a degraded reply's
    /// gap instead of dropping it: returns `(hits, missing)`, where
    /// `missing` names the unavailable shard ranges and is empty on a
    /// full answer.
    #[allow(clippy::type_complexity)]
    pub fn query_degraded(
        &mut self,
        samples: &[f32],
        k: usize,
    ) -> Result<(Vec<Hit>, Vec<String>), ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_query_frame(self.wire, Some(rid), samples, k);
        match self.call(frame, rid)? {
            Reply::Hits(h) => Ok((h, Vec::new())),
            Reply::Degraded { missing, reply } => match *reply {
                Reply::Hits(h) => Ok((h, missing)),
                other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
            },
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `query_batch` against a cluster router, surfacing a degraded
    /// reply's gap: per-row results plus the missing shard ranges
    /// (empty on a full answer).
    #[allow(clippy::type_complexity)]
    pub fn query_batch_degraded(
        &mut self,
        rows: &[f32],
        dim: usize,
        k: usize,
    ) -> Result<(Vec<Result<Vec<Hit>, String>>, Vec<String>), ClientError> {
        batch_count(rows, dim)?;
        let rid = self.next_id();
        let frame = protocol::encode_query_batch_frame(self.wire, Some(rid), rows, dim, k);
        let (items, missing) = match self.call(frame, rid)? {
            Reply::Batch(items) => (items, Vec::new()),
            Reply::Degraded { missing, reply } => match *reply {
                Reply::Batch(items) => (items, missing),
                other => return Err(ClientError::Protocol(format!("unexpected {other:?}"))),
            },
            other => return Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        };
        let rows = items
            .into_iter()
            .map(|item| match item {
                Ok(Reply::Hits(h)) => Ok(Ok(h)),
                Ok(other) => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
                Err(e) => Ok(Err(e)),
            })
            .collect::<Result<_, _>>()?;
        Ok((rows, missing))
    }

    /// `migrate_pull`: one ordered chunk of the server's store starting
    /// at id `from_id` (inclusive); returns `(entries, done)`.
    #[allow(clippy::type_complexity)]
    pub fn migrate_pull(
        &mut self,
        from_id: u64,
        max: usize,
    ) -> Result<(Vec<EntryRecord>, bool), ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_migrate_pull_frame(self.wire, Some(rid), from_id, max);
        match self.call(frame, rid)? {
            Reply::Entries { entries, done } => Ok((entries, done)),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `entries_push`: apply migration entry records (overwrite-
    /// idempotent); returns the applied count.
    pub fn entries_push(&mut self, entries: &[EntryRecord]) -> Result<u64, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_entries_push_frame(self.wire, Some(rid), entries);
        match self.call(frame, rid)? {
            Reply::Ingested { count } => Ok(count),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }

    /// `entries_discard`: drop the named entries (an aborted migration's
    /// rollback); returns how many were actually present and removed.
    pub fn entries_discard(&mut self, ids: &[u64]) -> Result<u64, ClientError> {
        let rid = self.next_id();
        let frame = protocol::encode_entries_discard_frame(self.wire, Some(rid), ids);
        match self.call(frame, rid)? {
            Reply::Ingested { count } => Ok(count),
            other => Err(ClientError::Protocol(format!("unexpected {other:?}"))),
        }
    }
}

// ---------------------------------------------------------- pipelining

/// What reply shape an in-flight request expects (validated on receipt).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Expect {
    Signature,
    Inserted(u64),
    Hits,
    Removed(u64),
    Metrics,
    Stats,
    Snapshot,
    Pong,
    Points,
    ShuttingDown,
    /// a batch reply carrying exactly this many per-item results
    Batch(usize),
}

fn reply_matches(expect: Expect, reply: &Reply) -> bool {
    match (expect, reply) {
        // a degraded wrapper carries the partial answer of the same
        // shape: validate the inner reply against the expectation (a
        // degraded batch still answers every row, with per-item errors
        // for the rows an unavailable shard owned)
        (expect, Reply::Degraded { reply, .. }) => reply_matches(expect, reply),
        (Expect::Batch(n), Reply::Batch(items)) => items.len() == n,
        (Expect::Signature, Reply::Signature(_)) => true,
        (Expect::Inserted(id), Reply::Inserted { id: got }) => *got == id,
        (Expect::Hits, Reply::Hits(_)) => true,
        (Expect::Removed(id), Reply::Removed { id: got }) => *got == id,
        (Expect::Metrics, Reply::Metrics(_)) => true,
        (Expect::Stats, Reply::Stats(_)) => true,
        (Expect::Snapshot, Reply::Snapshotted { .. }) => true,
        (Expect::Pong, Reply::Pong { .. }) => true,
        (Expect::Points, Reply::Points(_)) => true,
        (Expect::ShuttingDown, Reply::ShuttingDown) => true,
        _ => false,
    }
}

/// A finished pipelined request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// correlation id of the request this answers
    pub req_id: u64,
    /// send-to-receive latency (includes queueing behind the window)
    pub latency: Duration,
    /// the server's answer: a typed reply, or its error envelope
    pub result: Result<Reply, String>,
}

/// A pipelined connection: up to `depth` request frames in flight at
/// once, responses matched by `req_id` (see the module doc's pipelining
/// contract — the server answers in request order, but correlation by id
/// keeps the client correct regardless). Speaks either wire format.
///
/// Each `send_*` call first harvests completions if the window is full,
/// then enqueues its frame; [`PipelinedClient::drain`] collects
/// everything still outstanding.
pub struct PipelinedClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// resolved peer address, kept so [`PipelinedClient::reconnect`] can
    /// re-dial the same endpoint after a transport failure
    addr: std::net::SocketAddr,
    next_req_id: u64,
    depth: usize,
    wire: WireMode,
    pending: HashMap<u64, (Expect, Instant)>,
}

impl PipelinedClient {
    /// Connect with a send window of `depth` in-flight frames in JSON
    /// mode (`depth = 1` degenerates to the blocking client's
    /// behaviour).
    pub fn connect<A: ToSocketAddrs>(addr: A, depth: usize) -> Result<Self, ClientError> {
        Self::connect_with(addr, depth, WireMode::Json)
    }

    /// Connect with an explicit wire mode; binary connections announce
    /// themselves with the `FBIN1` magic.
    pub fn connect_with<A: ToSocketAddrs>(
        addr: A,
        depth: usize,
        wire: WireMode,
    ) -> Result<Self, ClientError> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| ClientError::Protocol("address resolved to nothing".into()))?;
        let (reader, writer) = Self::open(addr, wire)?;
        Ok(Self {
            reader,
            writer,
            addr,
            next_req_id: 1,
            depth: depth.max(1),
            wire,
            pending: HashMap::new(),
        })
    }

    /// Dial `addr` and perform the wire-mode handshake.
    fn open(
        addr: std::net::SocketAddr,
        wire: WireMode,
    ) -> Result<(BufReader<TcpStream>, BufWriter<TcpStream>), ClientError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        if wire == WireMode::Binary {
            protocol::write_magic(&mut writer)?;
        }
        Ok((reader, writer))
    }

    /// Drop the broken connection and dial the same endpoint again.
    ///
    /// Every in-flight request is orphaned — its reply died with the old
    /// socket — so `pending` is cleared and the number of abandoned
    /// requests is returned for the caller to account as failures.
    /// `next_req_id` keeps counting monotonically across reconnects so
    /// stale bookkeeping (e.g. the load generator's lag map) can never
    /// collide with a fresh request's id.
    pub fn reconnect(&mut self) -> Result<usize, ClientError> {
        let orphaned = self.pending.len();
        let (reader, writer) = Self::open(self.addr, self.wire)?;
        self.reader = reader;
        self.writer = writer;
        self.pending.clear();
        Ok(orphaned)
    }

    /// [`PipelinedClient::reconnect`] under a deterministic capped-
    /// exponential [`RetryPolicy`]: transient dial failures are retried
    /// with backoff; a non-transient failure or an exhausted budget
    /// yields a typed give-up error. Returns the orphan count from the
    /// abandoned connection.
    pub fn reconnect_with_backoff(&mut self, policy: &RetryPolicy) -> Result<usize, ClientError> {
        let mut attempt = 0usize;
        loop {
            match self.reconnect() {
                Ok(orphaned) => return Ok(orphaned),
                Err(e) if e.is_transient() && attempt < policy.attempts => {
                    std::thread::sleep(policy.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => {
                    return Err(ClientError::Protocol(format!(
                        "gave up reconnecting after {} attempt(s): {e}",
                        attempt + 1
                    )))
                }
            }
        }
    }

    /// Frames sent but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// The send window.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// This connection's wire mode.
    pub fn wire(&self) -> WireMode {
        self.wire
    }

    /// The `req_id` the next `send_*` call will assign. Lets callers
    /// keep per-request bookkeeping outside the client — the open-loop
    /// load generator records each frame's send-schedule lag under the
    /// id it is about to get, then bills the lag back onto the matching
    /// completion's latency.
    pub fn peek_req_id(&self) -> u64 {
        self.next_req_id
    }

    /// Block for one response and match it to its request.
    fn recv_one(&mut self) -> Result<Completion, ClientError> {
        self.writer.flush()?;
        let (got_id, body) =
            read_reply_frame(&mut self.reader, self.wire, self.pending.len())?;
        let req_id = got_id.ok_or_else(|| {
            ClientError::Protocol("pipelined reply carried no req_id".into())
        })?;
        let (expect, sent_at) = self.pending.remove(&req_id).ok_or_else(|| {
            ClientError::Protocol(format!("reply for unknown req_id {req_id}"))
        })?;
        let latency = sent_at.elapsed();
        match body {
            Ok(reply) => {
                if !reply_matches(expect, &reply) {
                    return Err(ClientError::Protocol(format!(
                        "req {req_id}: expected {expect:?}, got {reply:?}"
                    )));
                }
                Ok(Completion {
                    req_id,
                    latency,
                    result: Ok(reply),
                })
            }
            Err(e) => Ok(Completion {
                req_id,
                latency,
                result: Err(e),
            }),
        }
    }

    /// Enqueue one frame, harvesting a completion first if the window is
    /// full. Returns the completions harvested (0 or 1).
    fn send(
        &mut self,
        build: impl FnOnce(u64) -> Vec<u8>,
        expect: Expect,
    ) -> Result<Vec<Completion>, ClientError> {
        let mut done = Vec::new();
        while self.pending.len() >= self.depth {
            done.push(self.recv_one()?);
        }
        let rid = self.next_req_id;
        self.next_req_id += 1;
        let frame = build(rid);
        self.pending.insert(rid, (expect, Instant::now()));
        self.writer.write_all(&frame)?;
        // flush per frame: the latency clock started above, so the frame
        // must leave now — parking it in the BufWriter until the next
        // harvest would bill this op for the client's own think time
        // (and depth = 1 would no longer match the blocking client)
        self.writer.flush()?;
        Ok(done)
    }

    /// Pipeline a `hash` request.
    pub fn send_hash(&mut self, samples: &[f32]) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_hash_frame(wire, Some(rid), samples),
            Expect::Signature,
        )
    }

    /// Pipeline an `insert` request.
    pub fn send_insert(
        &mut self,
        id: u64,
        samples: &[f32],
    ) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_insert_frame(wire, Some(rid), id, samples),
            Expect::Inserted(id),
        )
    }

    /// Pipeline a `query` request.
    pub fn send_query(
        &mut self,
        samples: &[f32],
        k: usize,
    ) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_query_frame(wire, Some(rid), samples, k),
            Expect::Hits,
        )
    }

    /// Pipeline a `remove` request.
    pub fn send_remove(&mut self, id: u64) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_remove_frame(wire, Some(rid), id),
            Expect::Removed(id),
        )
    }

    /// Pipeline a `hash_batch` of `rows.len()/dim` contiguous rows.
    pub fn send_hash_batch(
        &mut self,
        rows: &[f32],
        dim: usize,
    ) -> Result<Vec<Completion>, ClientError> {
        let count = batch_count(rows, dim)?;
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_hash_batch_frame(wire, Some(rid), rows, dim),
            Expect::Batch(count),
        )
    }

    /// Pipeline an `insert_batch`.
    pub fn send_insert_batch(
        &mut self,
        ids: &[u64],
        rows: &[f32],
        dim: usize,
    ) -> Result<Vec<Completion>, ClientError> {
        let count = insert_batch_count(ids, rows, dim)?;
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_insert_batch_frame(wire, Some(rid), ids, rows, dim),
            Expect::Batch(count),
        )
    }

    /// Pipeline a `query_batch`.
    pub fn send_query_batch(
        &mut self,
        rows: &[f32],
        dim: usize,
        k: usize,
    ) -> Result<Vec<Completion>, ClientError> {
        let count = batch_count(rows, dim)?;
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_query_batch_frame(wire, Some(rid), rows, dim, k),
            Expect::Batch(count),
        )
    }

    /// Pipeline a `ping`.
    pub fn send_ping(&mut self) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_bare_frame(wire, Some(rid), "ping"),
            Expect::Pong,
        )
    }

    /// Pipeline a `metrics` request.
    pub fn send_metrics(&mut self) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_bare_frame(wire, Some(rid), "metrics"),
            Expect::Metrics,
        )
    }

    /// Pipeline a `stats` request.
    pub fn send_stats(&mut self, detail: StatsDetail) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_stats_frame(wire, Some(rid), detail),
            Expect::Stats,
        )
    }

    /// Pipeline a `points` request.
    pub fn send_points(&mut self) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_bare_frame(wire, Some(rid), "points"),
            Expect::Points,
        )
    }

    /// Pipeline a `snapshot` request.
    pub fn send_snapshot(&mut self, path: &str) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_snapshot_frame(wire, Some(rid), path),
            Expect::Snapshot,
        )
    }

    /// Pipeline a graceful-shutdown request.
    pub fn send_shutdown(&mut self) -> Result<Vec<Completion>, ClientError> {
        let wire = self.wire;
        self.send(
            |rid| protocol::encode_bare_frame(wire, Some(rid), "shutdown"),
            Expect::ShuttingDown,
        )
    }

    /// Push every queued frame to the socket without waiting for
    /// responses (useful to fill the window before a drain).
    pub fn flush(&mut self) -> Result<(), ClientError> {
        self.writer.flush()?;
        Ok(())
    }

    /// Flush and collect every outstanding completion.
    pub fn drain(&mut self) -> Result<Vec<Completion>, ClientError> {
        self.writer.flush()?;
        let mut done = Vec::with_capacity(self.pending.len());
        while !self.pending.is_empty() {
            done.push(self.recv_one()?);
        }
        Ok(done)
    }
}

// ----------------------------------------------------------- histogram

/// Power-of-two latency histogram from 1 ns to ~9 min.
///
/// Bucket resolution is *nanoseconds* (bucket `i` counts latencies in
/// `[2^i ns, 2^(i+1) ns)`): loopback round-trips sit in the tens of
/// microseconds, and the earlier microsecond-floor buckets collapsed an
/// entire sub-millisecond load run into one or two bars, flattening the
/// reported distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    /// bucket `i` counts latencies in `[2^i ns, 2^(i+1) ns)`; the last
    /// bucket is open-ended
    pub buckets: [u64; 40],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: [0; 40] }
    }
}

impl LatencyHistogram {
    /// Record one latency.
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().max(1).min(u64::MAX as u128) as u64;
        let idx = (63 - ns.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Approximate `p`-quantile in seconds (geometric midpoint of the
    /// bucket containing the quantile; exact quantiles need the raw
    /// samples, which the load generator also keeps).
    pub fn approx_quantile_s(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return (1u64 << i) as f64 * std::f64::consts::SQRT_2 * 1e-9;
            }
        }
        (1u64 << (self.buckets.len() - 1)) as f64 * std::f64::consts::SQRT_2 * 1e-9
    }

    /// JSON rows `[{"le_ns":…, "count":…}, …]` (cumulative upper bounds,
    /// empty tail trimmed).
    pub fn to_value(&self) -> Value {
        let last = self
            .buckets
            .iter()
            .rposition(|&c| c > 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        Value::Array(
            self.buckets[..last]
                .iter()
                .enumerate()
                .map(|(i, &c)| {
                    object(vec![
                        // u64 shift: bucket 39's bound (2^40) would
                        // overflow a 32-bit usize
                        ("le_ns", Value::Number((1u64 << (i + 1)) as f64)),
                        ("count", (c as usize).into()),
                    ])
                })
                .collect(),
        )
    }
}

// -------------------------------------------------------------- load gen

/// Load-generator settings.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// concurrent client threads (one connection each)
    pub threads: usize,
    /// operations per thread
    pub ops_per_thread: usize,
    /// in-flight frames per connection (1 = no pipelining)
    pub pipeline_depth: usize,
    /// rows per request frame (1 = single-op frames; N > 1 ships N rows
    /// per `*_batch` frame — `ops_per_thread` still counts rows)
    pub batch: usize,
    /// wire format every connection speaks
    pub wire: WireMode,
    /// fraction of ops that are inserts
    pub insert_fraction: f64,
    /// fraction of ops that are queries (the rest are hash-only)
    pub query_fraction: f64,
    /// neighbours per query
    pub k: usize,
    /// RNG seed (thread `t` uses `seed + t`)
    pub seed: u64,
    /// base for generated insert ids: thread `t` inserts
    /// `id_base + (t << 32) + i`. The default (`1 << 40`) keeps load
    /// traffic clear of normal corpus ids (which start at 0)
    pub id_base: u64,
    /// target aggregate arrival rate in ops/s across all threads
    /// (`0.0` = closed loop: send as fast as the pipeline window
    /// allows). Open-loop runs schedule each frame at its ideal
    /// arrival instant; a frame that leaves late (the connection was
    /// busy) has its send lag billed onto its latency, so the reported
    /// quantiles do not suffer coordinated omission. The pipeline
    /// window still bounds in-flight frames — size `pipeline_depth`
    /// generously when driving a server past saturation
    pub rate: f64,
    /// survive transport failures: when a send or drain hits a
    /// transient error (connection reset, typed `overloaded` refusal of
    /// the connection itself), re-dial the endpoint under the default
    /// [`RetryPolicy`] instead of aborting the thread. Orphaned
    /// in-flight requests are counted as errors; the run carries on.
    /// Lets `funclsh load --rate` ride through a shard restart
    pub reconnect: bool,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self {
            threads: 8,
            ops_per_thread: 250,
            pipeline_depth: 1,
            batch: 1,
            wire: WireMode::Json,
            insert_fraction: 0.5,
            query_fraction: 0.3,
            k: 10,
            seed: 0x10AD,
            id_base: 1 << 40,
            rate: 0.0,
            reconnect: false,
        }
    }
}

/// Aggregated result of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// total operations attempted
    pub ops: usize,
    /// inserts issued
    pub inserts: usize,
    /// queries issued
    pub queries: usize,
    /// hash-only ops issued
    pub hashes: usize,
    /// failed operations (excluding admission-control sheds)
    pub errors: usize,
    /// operations the server refused with a typed `overloaded`
    /// envelope (admission control doing its job — counted apart from
    /// `errors` because a shed under deliberate overload is expected)
    pub sheds: usize,
    /// operations answered with a typed `degraded` envelope or a
    /// degraded-wrapped partial result (a cluster router honestly
    /// reporting missing shard ranges — counted apart from `errors`
    /// because the reply is well-formed and partial by contract)
    pub degraded: usize,
    /// times a connection was re-dialed after a transport failure
    /// (only with [`LoadConfig::reconnect`])
    pub reconnects: usize,
    /// target aggregate arrival rate the run aimed for (ops/s;
    /// `0.0` = closed loop)
    pub target_rate_ops_s: f64,
    /// in-flight frames per connection during the run
    pub pipeline_depth: usize,
    /// rows per request frame during the run
    pub batch: usize,
    /// wire format the run used
    pub wire: WireMode,
    /// wall-clock duration of the run
    pub elapsed: Duration,
    /// mean per-op latency (seconds)
    pub latency_mean_s: f64,
    /// median per-op latency (seconds)
    pub latency_p50_s: f64,
    /// 99th-percentile per-op latency (seconds)
    pub latency_p99_s: f64,
    /// merged latency histogram
    pub histogram: LatencyHistogram,
    /// server-side stage totals accumulated *by this run* (the delta of
    /// two `stats detail=stages` snapshots bracketing the run); `None`
    /// when the caller didn't fetch them (e.g. tracing disabled)
    pub server_stages: Option<Value>,
}

impl LoadReport {
    /// Operations per second.
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64().max(1e-12)
    }

    /// Render as a JSON object (the `funclsh load` output).
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("ops", self.ops.into()),
            ("inserts", self.inserts.into()),
            ("queries", self.queries.into()),
            ("hashes", self.hashes.into()),
            ("errors", self.errors.into()),
            ("sheds", self.sheds.into()),
            ("degraded", self.degraded.into()),
            ("reconnects", self.reconnects.into()),
            ("pipeline_depth", self.pipeline_depth.into()),
            ("batch", self.batch.into()),
            ("wire", self.wire.as_str().into()),
            ("elapsed_s", self.elapsed.as_secs_f64().into()),
            ("target_rate_ops_s", self.target_rate_ops_s.into()),
            ("throughput_ops_s", self.throughput().into()),
            ("latency_mean_s", self.latency_mean_s.into()),
            ("latency_p50_s", self.latency_p50_s.into()),
            ("latency_p99_s", self.latency_p99_s.into()),
            ("histogram", self.histogram.to_value()),
        ];
        if let Some(stages) = &self.server_stages {
            fields.push(("server_stages", stages.clone()));
        }
        object(fields).to_json()
    }
}

/// Per-thread tally, merged after join.
#[derive(Default)]
struct ThreadTally {
    inserts: usize,
    queries: usize,
    hashes: usize,
    errors: usize,
    sheds: usize,
    degraded: usize,
    reconnects: usize,
    latencies: Vec<f64>,
    histogram: LatencyHistogram,
}

impl ThreadTally {
    /// Count one failed op: a typed `overloaded` envelope is a shed
    /// (the server's admission control answering deliberate
    /// overpressure), a typed `degraded` envelope is a cluster router
    /// honestly naming an unavailable shard range, anything else is an
    /// error.
    fn fail(&mut self, msg: &str) {
        if protocol::error_is_overloaded(msg) {
            self.sheds += 1;
        } else if protocol::error_is_degraded(msg) {
            self.degraded += 1;
        } else {
            self.errors += 1;
        }
    }

    /// Fold completions in. `lags` maps `req_id` to how far behind its
    /// open-loop schedule the frame left the client; the lag is billed
    /// onto the completion's latency so a saturated run cannot hide
    /// queueing delay by simply sending late (coordinated omission).
    /// Closed-loop runs pass an empty map.
    fn absorb(&mut self, completions: Vec<Completion>, lags: &mut HashMap<u64, Duration>) {
        for c in completions {
            let latency = c.latency + lags.remove(&c.req_id).unwrap_or(Duration::ZERO);
            // a degraded wrapper is a well-formed partial answer from a
            // cluster router: count the envelope, then tally its inner
            // reply like any other (per-item degraded errors inside a
            // batch land in `degraded` via `fail`'s classification)
            let reply = match c.result {
                Ok(Reply::Degraded { reply, .. }) => {
                    self.degraded += 1;
                    Ok(*reply)
                }
                other => other,
            };
            match reply {
                // a batch frame completes all its rows at once: each row
                // counts as one op at the frame's latency (the whole
                // point of batching is that they share it)
                Ok(Reply::Batch(items)) => {
                    for item in items {
                        match item {
                            Ok(_) => {
                                self.latencies.push(latency.as_secs_f64());
                                self.histogram.record(latency);
                            }
                            Err(e) => self.fail(&e),
                        }
                    }
                }
                Ok(_) => {
                    self.latencies.push(latency.as_secs_f64());
                    self.histogram.record(latency);
                }
                Err(e) => self.fail(&e),
            }
        }
    }
}

/// Run mixed insert/query/hash traffic against `addr` from
/// `cfg.threads` concurrent connections, each keeping up to
/// `cfg.pipeline_depth` frames in flight and speaking `cfg.wire`. The
/// workload is the paper's sine family sampled at `points` (fetch them
/// with [`Client::points`]). Insert ids are partitioned per thread above
/// `cfg.id_base`, so a run never collides with itself or (at the
/// default base) with an existing 0-based corpus. With `cfg.rate > 0`
/// the run is open-loop: frames are scheduled at the target arrival
/// rate regardless of how fast the server answers, late sends bill
/// their lag onto the op's latency, and typed `overloaded` refusals
/// are tallied as `sheds` rather than errors.
pub fn run_load(
    addr: std::net::SocketAddr,
    points: &[f64],
    cfg: &LoadConfig,
) -> Result<LoadReport, ClientError> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..cfg.threads {
        let points = points.to_vec();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> Result<ThreadTally, ClientError> {
            let mut client =
                PipelinedClient::connect_with(addr, cfg.pipeline_depth.max(1), cfg.wire)?;
            let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed.wrapping_add(t as u64));
            let mut tally = ThreadTally::default();
            let batch = cfg.batch.max(1);
            let dim = points.len();
            // open-loop pacing: this thread's share of the target rate,
            // and each in-flight frame's lag behind its scheduled
            // arrival instant (billed onto its latency in `absorb`)
            let thread_rate = if cfg.rate > 0.0 {
                cfg.rate / cfg.threads.max(1) as f64
            } else {
                0.0
            };
            let start = Instant::now();
            let policy = RetryPolicy::default();
            let mut lags: HashMap<u64, Duration> = HashMap::new();
            let mut i = 0usize;
            while i < cfg.ops_per_thread {
                // rows per frame: `batch` of them, except a short tail
                let n = batch.min(cfg.ops_per_thread - i);
                if thread_rate > 0.0 {
                    // the frame carrying ops [i, i+n) is due when op i
                    // arrives in the ideal open-loop schedule
                    let scheduled = start + Duration::from_secs_f64(i as f64 / thread_rate);
                    let now = Instant::now();
                    if now < scheduled {
                        std::thread::sleep(scheduled - now);
                    } else {
                        // behind schedule: send immediately and record
                        // the lag under the id the frame is about to get
                        lags.insert(client.peek_req_id(), now - scheduled);
                    }
                }
                let roll = rng.uniform();
                let mut rows: Vec<f32> = Vec::with_capacity(n * dim);
                for _ in 0..n {
                    let phase = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
                    let f = Sine::paper(phase);
                    rows.extend(points.iter().map(|&x| f.eval(x) as f32));
                }
                let is_insert = roll < cfg.insert_fraction;
                let is_query = !is_insert && roll < cfg.insert_fraction + cfg.query_fraction;
                let attempt = if batch == 1 {
                    // single-op frames: the baseline the batch grid is
                    // measured against
                    if is_insert {
                        let id = cfg.id_base + ((t as u64) << 32) + i as u64;
                        client.send_insert(id, &rows)
                    } else if is_query {
                        client.send_query(&rows, cfg.k)
                    } else {
                        client.send_hash(&rows)
                    }
                } else if is_insert {
                    let ids: Vec<u64> = (0..n)
                        .map(|j| cfg.id_base + ((t as u64) << 32) + (i + j) as u64)
                        .collect();
                    client.send_insert_batch(&ids, &rows, dim)
                } else if is_query {
                    client.send_query_batch(&rows, dim, cfg.k)
                } else {
                    client.send_hash_batch(&rows, dim)
                };
                match attempt {
                    Ok(done) => {
                        // bill the op-kind counters only once the frame
                        // is actually on the wire — a send that dies in
                        // the reconnect path below retries the slot
                        // without double-counting
                        if is_insert {
                            tally.inserts += n;
                        } else if is_query {
                            tally.queries += n;
                        } else {
                            tally.hashes += n;
                        }
                        tally.absorb(done, &mut lags);
                        i += n;
                    }
                    Err(e) if cfg.reconnect && e.is_transient() => {
                        // the socket died: every in-flight frame is an
                        // orphan whose reply will never arrive. Count
                        // them as errors, re-dial under backoff, and
                        // retry this slot on the fresh connection.
                        let orphaned = client.reconnect_with_backoff(&policy)?;
                        tally.errors += orphaned;
                        tally.reconnects += 1;
                        lags.clear();
                    }
                    Err(e) => return Err(e),
                }
            }
            match client.drain() {
                Ok(drained) => tally.absorb(drained, &mut lags),
                Err(e) if cfg.reconnect && e.is_transient() => {
                    // the run is over; orphans from a dying socket are
                    // errors, but there is nothing left to resend
                    tally.errors += client.in_flight();
                }
                Err(e) => return Err(e),
            }
            Ok(tally)
        }));
    }

    let mut merged = ThreadTally::default();
    let mut first_err = None;
    for h in handles {
        match h.join().expect("load thread panicked") {
            Ok(t) => {
                merged.inserts += t.inserts;
                merged.queries += t.queries;
                merged.hashes += t.hashes;
                merged.errors += t.errors;
                merged.sheds += t.sheds;
                merged.degraded += t.degraded;
                merged.reconnects += t.reconnects;
                merged.latencies.extend(t.latencies);
                merged.histogram.merge(&t.histogram);
            }
            Err(e) => first_err = Some(e),
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let elapsed = t0.elapsed();
    // total_cmp: a NaN latency (impossible today, but this sort must not
    // be the thing that panics a finished load run) sorts to the end
    // instead of aborting
    merged.latencies.sort_by(f64::total_cmp);
    let q = |p: f64| {
        if merged.latencies.is_empty() {
            0.0
        } else {
            quantile_sorted(&merged.latencies, p)
        }
    };
    let mean = if merged.latencies.is_empty() {
        0.0
    } else {
        merged.latencies.iter().sum::<f64>() / merged.latencies.len() as f64
    };
    Ok(LoadReport {
        ops: merged.inserts + merged.queries + merged.hashes,
        inserts: merged.inserts,
        queries: merged.queries,
        hashes: merged.hashes,
        errors: merged.errors,
        sheds: merged.sheds,
        degraded: merged.degraded,
        reconnects: merged.reconnects,
        target_rate_ops_s: cfg.rate.max(0.0),
        pipeline_depth: cfg.pipeline_depth.max(1),
        batch: cfg.batch.max(1),
        wire: cfg.wire,
        elapsed,
        latency_mean_s: mean,
        latency_p50_s: q(0.5),
        latency_p99_s: q(0.99),
        histogram: merged.histogram,
        server_stages: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_merge() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_nanos(1)); // bucket 0
        h.record(Duration::from_nanos(3)); // bucket 1
        h.record(Duration::from_micros(1)); // 1000 ns -> bucket 9
        h.record(Duration::from_micros(1000)); // 1e6 ns -> bucket 19
        assert_eq!(h.count(), 4);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[9], 1);
        assert_eq!(h.buckets[19], 1);
        let mut other = LatencyHistogram::default();
        other.record(Duration::from_nanos(3));
        other.merge(&h);
        assert_eq!(other.count(), 5);
        assert_eq!(other.buckets[1], 2);
    }

    #[test]
    fn histogram_resolves_sub_millisecond_latencies() {
        // the whole point of the ns-floor buckets: a loopback-speed run
        // (tens to hundreds of µs) spreads over distinct buckets instead
        // of collapsing into one
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(5)); // 5000 ns -> bucket 12
        h.record(Duration::from_micros(20)); // 20000 ns -> bucket 14
        h.record(Duration::from_micros(80)); // 80000 ns -> bucket 16
        h.record(Duration::from_micros(300)); // 300000 ns -> bucket 18
        let occupied: Vec<usize> = (0..h.buckets.len()).filter(|&i| h.buckets[i] > 0).collect();
        assert_eq!(occupied, vec![12, 14, 16, 18]);
        // approximate quantiles spread too (no single-bucket collapse)
        assert!(h.approx_quantile_s(0.01) < h.approx_quantile_s(0.99));
        assert!(h.approx_quantile_s(0.99) < 1e-3);
    }

    #[test]
    fn histogram_clamps_extremes() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_nanos(0)); // clamps to bucket 0
        h.record(Duration::from_secs(3600)); // clamps to the last bucket
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[39], 1);
    }

    #[test]
    fn histogram_json_trims_tail() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_nanos(2));
        let v = h.to_value();
        let rows = v.as_array().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("count").unwrap().as_usize(), Some(1));
        assert_eq!(rows[1].get("le_ns").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn approx_quantile_empty_and_single() {
        let h = LatencyHistogram::default();
        assert_eq!(h.approx_quantile_s(0.5), 0.0);
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(10));
        let q = h.approx_quantile_s(0.5);
        assert!(q > 5e-6 && q < 2e-5, "{q}");
    }

    #[test]
    fn batch_count_rejects_ragged_buffers() {
        assert_eq!(batch_count(&[0.0; 8], 4).unwrap(), 2);
        assert!(batch_count(&[0.0; 7], 4).is_err(), "ragged buffer");
        assert!(batch_count(&[], 4).is_err(), "empty batch");
        assert!(batch_count(&[0.0; 4], 0).is_err(), "zero dim");
    }

    #[test]
    fn tally_classifies_sheds_and_bills_send_lag() {
        let mut tally = ThreadTally::default();
        let mut lags = HashMap::new();
        // req 7 left 1 ms behind its open-loop schedule
        lags.insert(7, Duration::from_millis(1));
        let completions = vec![
            Completion {
                req_id: 7,
                latency: Duration::from_micros(10),
                result: Ok(Reply::Pong { indexed: 0 }),
            },
            Completion {
                req_id: 8,
                latency: Duration::from_micros(10),
                result: Err(protocol::overloaded_msg("connection in-flight byte budget")),
            },
            Completion {
                req_id: 9,
                latency: Duration::from_micros(10),
                result: Err("bad dim".into()),
            },
        ];
        tally.absorb(completions, &mut lags);
        assert_eq!(tally.sheds, 1, "typed overloaded envelope counts as a shed");
        assert_eq!(tally.errors, 1, "other failures stay errors");
        assert_eq!(tally.latencies.len(), 1);
        // 10 µs wire latency + 1 ms schedule lag
        assert!(
            tally.latencies[0] >= 1.0e-3,
            "lag not billed: {}",
            tally.latencies[0]
        );
        assert!(lags.is_empty(), "billed lag is consumed");
    }

    #[test]
    fn tally_counts_degraded_envelopes() {
        let mut tally = ThreadTally::default();
        let mut lags = HashMap::new();
        let completions = vec![
            // a degraded-wrapped batch: the envelope counts once, and
            // each per-item degraded error inside it counts too
            Completion {
                req_id: 1,
                latency: Duration::from_micros(10),
                result: Ok(Reply::Degraded {
                    missing: vec!["0000000000000000-7fffffffffffffff@127.0.0.1:1".into()],
                    reply: Box::new(Reply::Batch(vec![
                        Ok(Reply::Pong { indexed: 0 }),
                        Err(protocol::degraded_msg("shard range unavailable")),
                    ])),
                }),
            },
            // a bare typed degraded error (single-op path)
            Completion {
                req_id: 2,
                latency: Duration::from_micros(10),
                result: Err(protocol::degraded_msg("shard range unavailable")),
            },
        ];
        tally.absorb(completions, &mut lags);
        assert_eq!(tally.degraded, 3, "envelope + inner item + bare error");
        assert_eq!(tally.errors, 0, "degraded replies are not errors");
        assert_eq!(tally.latencies.len(), 1, "the healthy inner item still lands");
    }

    #[test]
    fn retry_policy_backoff_is_deterministic_and_capped() {
        let p = RetryPolicy::default();
        assert_eq!(p.attempts, 5);
        assert_eq!(p.backoff(0), Duration::from_millis(50));
        assert_eq!(p.backoff(1), Duration::from_millis(100));
        assert_eq!(p.backoff(2), Duration::from_millis(200));
        assert_eq!(p.backoff(3), Duration::from_millis(400));
        assert_eq!(p.backoff(4), Duration::from_millis(800));
        assert_eq!(p.backoff(5), Duration::from_secs(1), "cap reached");
        assert_eq!(p.backoff(60), Duration::from_secs(1), "huge attempt stays capped");
        // cap is clamped up to base so the schedule never goes backwards
        let q = RetryPolicy::new(3, 100, 10);
        assert_eq!(q.backoff(0), Duration::from_millis(100));
        assert_eq!(q.backoff(9), Duration::from_millis(100));
    }

    #[test]
    fn transient_error_classification() {
        use std::io;
        assert!(ClientError::Io(io::Error::new(io::ErrorKind::ConnectionReset, "rst"))
            .is_transient());
        assert!(ClientError::Protocol("server closed connection".into()).is_transient());
        assert!(!ClientError::Protocol("reply for unknown req_id 3".into()).is_transient());
        assert!(ClientError::Server(protocol::overloaded_msg("queue full")).is_transient());
        assert!(
            !ClientError::Server(protocol::degraded_msg("shard range unavailable"))
                .is_transient(),
            "a degraded reply is an answer, not a transport fault"
        );
        assert!(!ClientError::Server("bad dim".into()).is_transient());
    }

    #[test]
    fn report_json_shape() {
        let report = LoadReport {
            ops: 10,
            inserts: 5,
            queries: 3,
            hashes: 2,
            errors: 0,
            sheds: 3,
            degraded: 2,
            reconnects: 1,
            target_rate_ops_s: 500.0,
            pipeline_depth: 4,
            batch: 16,
            wire: WireMode::Binary,
            elapsed: Duration::from_millis(100),
            latency_mean_s: 0.001,
            latency_p50_s: 0.001,
            latency_p99_s: 0.002,
            histogram: LatencyHistogram::default(),
            server_stages: None,
        };
        assert!((report.throughput() - 100.0).abs() < 1.0);
        let v = crate::json::parse(&report.to_json()).unwrap();
        assert_eq!(v.get("ops").unwrap().as_usize(), Some(10));
        assert_eq!(v.get("pipeline_depth").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(16));
        assert_eq!(v.get("wire").unwrap().as_str(), Some("binary"));
        assert_eq!(v.get("sheds").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("degraded").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("reconnects").unwrap().as_usize(), Some(1));
        assert_eq!(
            v.get("target_rate_ops_s").unwrap().as_f64(),
            Some(500.0)
        );
        assert!(v.get("throughput_ops_s").unwrap().as_f64().unwrap() > 0.0);
        // server_stages is omitted unless the caller spliced one in
        assert!(v.get("server_stages").is_none());
        let mut with = report.clone();
        with.server_stages = Some(object(vec![("traced", 10.0.into())]));
        let v = crate::json::parse(&with.to_json()).unwrap();
        assert_eq!(
            v.get("server_stages").unwrap().get("traced").unwrap().as_usize(),
            Some(10)
        );
    }
}
