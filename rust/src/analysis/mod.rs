//! In-repo static analysis: `funclsh analyze`.
//!
//! A zero-dependency invariant linter for this repository's own source
//! tree. A lightweight Rust lexer ([`lexer`]) produces a comment- and
//! string-aware token stream (no full AST), and a registry of rules
//! ([`rules`]) matches token runs against the invariants the PR history
//! shows regressing repeatedly. The CLI (`funclsh analyze`) walks
//! `src/` + `tests/`, prints `file:line` findings, and `--deny` makes
//! them fatal for CI; a checked-in baseline file can grandfather
//! existing hits (the repo keeps it empty).
//!
//! ## The rules, and the regression that motivated each
//!
//! | rule | invariant | history |
//! |------|-----------|---------|
//! | `frame-localization` | no frame-scan / length-prefix / negotiation logic outside `server/protocol.rs`; magic bytes via `protocol::write_magic`, lengths via `MAGIC_LEN`, caps via `MAX_FRAME_BYTES` | PR 5 unified three divergent frame-scan implementations into `protocol::Framer`; the rule was then enforced only by a hand-run `rg` |
//! | `float-total-cmp` | never `.partial_cmp(..)` on floats — `f64::total_cmp` is total over NaN and bit-stable (the paper's reproducibility contract) | NaN `partial_cmp().unwrap()` panics were fixed in PR 4 and regressed again in PR 6 |
//! | `mutex-poison` | no bare `.lock()/.read()/.write()/.wait(..)` + `.unwrap()` in library code — lock acquisition goes through [`crate::util::sync`], which recovers with `unwrap_or_else(PoisonError::into_inner)`; `#[cfg(test)]` code is exempt | PR 7 retrofitted poison recovery after a panicking worker wedged every later request |
//! | `unsafe-safety` | `unsafe` only in `server/reactor.rs`, `runtime/pjrt_path.rs` and `coordinator/simd.rs`, each use under a `// SAFETY:` comment | the raw-syscall epoll reactor (PR 6) and the AVX2 hash-kernel tile are the only dense unsafe modules and must stay quarantined |
//! | `wire-tags` | `OP_*`/`REPLY_*`/`ERR_CODE_*` tags in `protocol.rs` are `u8`, unique, contiguous from 1 | PR 5/8 grew the FBIN1 op space; a duplicate or gap silently corrupts cross-version framing |
//! | `print-discipline` | no `println!`/`eprintln!`/`dbg!`/`process::exit` outside `cli/`, `bench/`, `main.rs`, `util/log.rs` | PR 8 cluster nodes run headless; stray stdout corrupts newline-framed JSON |
//! | `checked-float-cast` | no bare float → `i8`/`i16`/`i32` `as` casts in library code outside `hashing/quantize.rs` — lower through `quantize_hash` / `SigVec::from_i32`, which range-check and return a typed `HashOverflow` | the seed hash kernel's `.floor() as i32` *saturated*: overflowing hashes pinned to `i32::MAX`/`MIN` and NaN collapsed to bucket 0 instead of surfacing a per-item error |
//!
//! Rules are pure functions over one file's token stream, so each is
//! unit-tested on fixture snippets (positive and negative, including
//! banned tokens hidden in strings/raw strings/comments), and
//! `tests/analysis_selfcheck.rs` asserts the repo's own tree passes
//! with an empty baseline — the linter gates itself.

pub mod lexer;
pub mod rules;

pub use rules::{all_rules, Rule, Violation};

use crate::json::{object, Value};
use rules::FileCtx;
use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

/// Lint one file's source text under its repo-relative path (forward
/// slashes). This is the seam the walker and the unit tests share.
pub fn analyze_source(rel_path: &str, source: &str) -> Vec<Violation> {
    let tokens = lexer::lex(source);
    let ctx = FileCtx::new(rel_path, &tokens);
    let mut out = Vec::new();
    for rule in all_rules() {
        rule.check(&ctx, &mut out);
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Collect every `.rs` file under `<root>/src` and `<root>/tests`,
/// as (repo-relative path, absolute path), sorted for deterministic
/// output.
pub fn collect_files(root: &Path) -> io::Result<Vec<(String, PathBuf)>> {
    let mut files = Vec::new();
    for top in ["src", "tests"] {
        let dir = root.join(top);
        if dir.is_dir() {
            walk(&dir, root, &mut files)?;
        }
    }
    files.sort();
    Ok(files)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

/// Walk `<root>/src` + `<root>/tests` and lint every file. Returns
/// (files scanned, raw violations) — baseline suppression is a
/// separate step so `--write-baseline` can see the raw set.
pub fn scan_tree(root: &Path) -> io::Result<(usize, Vec<Violation>)> {
    let files = collect_files(root)?;
    let mut violations = Vec::new();
    for (rel, abs) in &files {
        let bytes = std::fs::read(abs)?;
        let source = String::from_utf8_lossy(&bytes);
        violations.extend(analyze_source(rel, &source));
    }
    Ok((files.len(), violations))
}

/// Where `analyze` looks for the baseline when `--baseline` is not
/// given.
pub fn default_baseline_path(root: &Path) -> PathBuf {
    root.join("ANALYZE_BASELINE.txt")
}

/// Grandfathered violations: up to `count` hits of `rule` in `path`
/// are suppressed. The repo's checked-in baseline is kept empty; the
/// mechanism exists so a future emergency can land with an explicit,
/// reviewable debt record instead of a disabled linter.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Baseline {
    entries: BTreeMap<(String, String), usize>,
}

impl Baseline {
    /// Parse the `rule<ws>path<ws>count` line format (`#` comments and
    /// blank lines ignored).
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries = BTreeMap::new();
        for (n, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            let [rule, path, count] = fields.as_slice() else {
                return Err(format!("baseline line {}: want `rule path count`", n + 1));
            };
            let count: usize = count
                .parse()
                .map_err(|_| format!("baseline line {}: bad count `{count}`", n + 1))?;
            *entries
                .entry((rule.to_string(), path.to_string()))
                .or_insert(0) += count;
        }
        Ok(Self { entries })
    }

    /// Render the baseline that would exactly suppress `violations`.
    pub fn render_from(violations: &[Violation]) -> String {
        let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        for v in violations {
            *counts.entry((v.rule.to_string(), v.path.clone())).or_insert(0) += 1;
        }
        let mut out = String::from(
            "# funclsh analyze baseline — grandfathered violations, `rule path count`\n\
             # per line. Regenerate with `funclsh analyze --write-baseline`; the goal\n\
             # is for this file to stay empty.\n",
        );
        for ((rule, path), count) in &counts {
            out.push_str(&format!("{rule}\t{path}\t{count}\n"));
        }
        out
    }

    /// True if no entries (nothing grandfathered).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The outcome of a scan after baseline suppression.
#[derive(Debug, Default)]
pub struct Report {
    /// Number of `.rs` files lexed and checked.
    pub files_scanned: usize,
    /// Violations that survived the baseline (what `--deny` gates on).
    pub violations: Vec<Violation>,
    /// How many hits the baseline swallowed.
    pub suppressed: usize,
    /// Baseline entries that over-promise (fewer matches than their
    /// count) — a sign the debt was paid and the entry should go.
    pub stale_baseline: Vec<String>,
}

impl Report {
    /// Build the report: scan results + baseline suppression.
    pub fn new(files_scanned: usize, raw: Vec<Violation>, baseline: &Baseline) -> Self {
        let mut remaining = baseline.entries.clone();
        let mut violations = Vec::new();
        let mut suppressed = 0usize;
        for v in raw {
            let key = (v.rule.to_string(), v.path.clone());
            match remaining.get_mut(&key) {
                Some(left) if *left > 0 => {
                    *left -= 1;
                    suppressed += 1;
                }
                _ => violations.push(v),
            }
        }
        let stale_baseline = remaining
            .iter()
            .filter(|(_, left)| **left > 0)
            .map(|((rule, path), left)| {
                format!(
                    "baseline entry `{rule} {path}` allows {left} more \
                     hit(s) than exist — remove or shrink it"
                )
            })
            .collect();
        Self {
            files_scanned,
            violations,
            suppressed,
            stale_baseline,
        }
    }

    /// Nothing survived the baseline: the tree upholds every invariant.
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Human-readable rendering (`file:line: [rule] message` plus a
    /// one-line summary). The caller decides where it goes — this
    /// module never prints.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{}:{}: [{}] {}\n", v.path, v.line, v.rule, v.message));
        }
        for s in &self.stale_baseline {
            out.push_str(&format!("warning: {s}\n"));
        }
        out.push_str(&format!(
            "analyze: {} file(s), {} violation(s){}\n",
            self.files_scanned,
            self.violations.len(),
            if self.suppressed > 0 {
                format!(", {} suppressed by baseline", self.suppressed)
            } else {
                String::new()
            }
        ));
        out
    }

    /// Machine-readable rendering for `--json`.
    pub fn render_json(&self) -> String {
        object(vec![
            ("files_scanned", Value::Number(self.files_scanned as f64)),
            (
                "violations",
                Value::Array(
                    self.violations
                        .iter()
                        .map(|v| {
                            object(vec![
                                ("rule", Value::String(v.rule.to_string())),
                                ("path", Value::String(v.path.clone())),
                                ("line", Value::Number(v.line as f64)),
                                ("message", Value::String(v.message.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("suppressed", Value::Number(self.suppressed as f64)),
            (
                "stale_baseline",
                Value::Array(
                    self.stale_baseline
                        .iter()
                        .map(|s| Value::String(s.clone()))
                        .collect(),
                ),
            ),
            ("clean", Value::Bool(self.clean())),
        ])
        .to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analyze_source_runs_every_rule_and_sorts_by_line() {
        let src = "pub fn f(m: &std::sync::Mutex<u32>) {\n\
                   let g = m.lock().unwrap();\n\
                   let o = 1.0f64.partial_cmp(&2.0);\n\
                   println!(\"{g:?} {o:?}\");\n\
                   }\n";
        let v = analyze_source("src/lsh/mod.rs", src);
        let rules: Vec<&str> = v.iter().map(|v| v.rule).collect();
        assert_eq!(rules, ["mutex-poison", "float-total-cmp", "print-discipline"]);
        assert_eq!(v.iter().map(|v| v.line).collect::<Vec<_>>(), [2, 3, 4]);
        assert!(v.iter().all(|v| v.path == "src/lsh/mod.rs"));
    }

    #[test]
    fn baseline_round_trips_through_render_and_parse() {
        let violations = vec![
            Violation {
                rule: "float-total-cmp",
                path: "src/a.rs".into(),
                line: 3,
                message: "m".into(),
            },
            Violation {
                rule: "float-total-cmp",
                path: "src/a.rs".into(),
                line: 9,
                message: "m".into(),
            },
            Violation {
                rule: "mutex-poison",
                path: "src/b.rs".into(),
                line: 1,
                message: "m".into(),
            },
        ];
        let text = Baseline::render_from(&violations);
        let parsed = Baseline::parse(&text).unwrap();
        let report = Report::new(2, violations, &parsed);
        assert!(report.clean());
        assert_eq!(report.suppressed, 3);
        assert!(report.stale_baseline.is_empty());
    }

    #[test]
    fn baseline_suppresses_up_to_count_and_flags_stale_entries() {
        let baseline = Baseline::parse(
            "# comment\n\
             float-total-cmp\tsrc/a.rs\t1\n\
             unsafe-safety\tsrc/gone.rs\t2\n",
        )
        .unwrap();
        let violations = vec![
            Violation {
                rule: "float-total-cmp",
                path: "src/a.rs".into(),
                line: 3,
                message: "m".into(),
            },
            Violation {
                rule: "float-total-cmp",
                path: "src/a.rs".into(),
                line: 9,
                message: "m".into(),
            },
        ];
        let report = Report::new(1, violations, &baseline);
        assert_eq!(report.suppressed, 1);
        assert_eq!(report.violations.len(), 1);
        assert_eq!(report.violations[0].line, 9);
        assert_eq!(report.stale_baseline.len(), 1);
        assert!(report.stale_baseline[0].contains("src/gone.rs"));
    }

    #[test]
    fn baseline_rejects_malformed_lines() {
        assert!(Baseline::parse("too few fields\n").is_err());
        assert!(Baseline::parse("rule path not-a-number\n").is_err());
        assert!(Baseline::parse("\n# only comments\n").unwrap().is_empty());
    }

    #[test]
    fn report_renders_text_and_json_with_positions() {
        let violations = vec![Violation {
            rule: "wire-tags",
            path: "src/server/protocol.rs".into(),
            line: 42,
            message: "duplicate wire tag".into(),
        }];
        let report = Report::new(5, violations, &Baseline::default());
        let text = report.render_text();
        assert!(text.contains("src/server/protocol.rs:42: [wire-tags] duplicate wire tag"));
        assert!(text.contains("5 file(s), 1 violation(s)"));
        let json = crate::json::parse(&report.render_json()).unwrap();
        assert_eq!(json.get("clean"), Some(&Value::Bool(false)));
        let v = json.get("violations").and_then(|v| v.as_array()).unwrap();
        assert_eq!(v[0].get("line").and_then(|l| l.as_u64()), Some(42));
    }

    #[test]
    fn clean_report_is_clean() {
        let report = Report::new(10, Vec::new(), &Baseline::default());
        assert!(report.clean());
        assert!(report.render_text().contains("0 violation(s)"));
        let json = crate::json::parse(&report.render_json()).unwrap();
        assert_eq!(json.get("clean"), Some(&Value::Bool(true)));
    }
}
