//! A lightweight Rust lexer for the in-repo linter.
//!
//! This is deliberately **not** a full Rust grammar: the rules in
//! [`super::rules`] only need a token stream that is reliably aware of
//! comments, string/char/byte literals (including raw strings), and
//! lifetimes — so that a banned identifier inside `"a string"` or a
//! `// comment` can never fire a rule, and so that every token carries
//! the 1-based source line it starts on. Numbers are lexed loosely
//! (`1e-5` may come out as several tokens); no rule cares.
//!
//! Invariants the rules rely on:
//!
//! * `Comment` tokens are kept in the stream (the `unsafe`/`SAFETY:`
//!   rule reads them); use [`code_tokens`] for a comment-free view.
//! * A raw string `r#"…"#` is one `Str` token regardless of content;
//!   nested block comments terminate correctly.
//! * `'a` lexes as `Lifetime`, `'a'` as `Char`, `b'\n'` as `Byte`.

/// What kind of lexeme a [`Token`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (including raw identifiers `r#ident`).
    Ident,
    /// Numeric literal (lexed loosely; suffixes are folded in).
    Number,
    /// String literal: `"…"`, `r"…"`, `r#"…"#` (quotes included).
    Str,
    /// Byte-string literal: `b"…"`, `br#"…"#`.
    ByteStr,
    /// Character literal `'x'`.
    Char,
    /// Byte literal `b'x'`.
    Byte,
    /// Lifetime such as `'a` (also matches the loop-label form).
    Lifetime,
    /// Any single punctuation / operator character.
    Punct,
    /// Line (`//`) or block (`/* … */`) comment, doc or not.
    Comment,
}

/// One lexeme with its source text and 1-based starting line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    /// Is this token the identifier `s`?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokenKind::Ident && self.text == s
    }

    /// Is this token the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }
}

/// Indices into the full token stream of every non-comment token, in
/// order. Rules that match token runs use this view so comments can
/// never split a pattern; the index maps back into the full stream.
pub fn code_tokens(tokens: &[Token]) -> Vec<usize> {
    (0..tokens.len())
        .filter(|&i| tokens[i].kind != TokenKind::Comment)
        .collect()
}

struct Cursor<'a> {
    chars: &'a [char],
    pos: usize,
    line: u32,
}

impl<'a> Cursor<'a> {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `source`, keeping comments in the stream. Never fails: any
/// byte sequence produces *some* token stream (unterminated literals
/// run to end of input), which is the right behaviour for a linter that
/// must not panic on the tree it scans.
pub fn lex(source: &str) -> Vec<Token> {
    let chars: Vec<char> = source.chars().collect();
    let mut cur = Cursor {
        chars: &chars,
        pos: 0,
        line: 1,
    };
    let mut out = Vec::new();

    while let Some(c) = cur.peek(0) {
        let line = cur.line;
        match c {
            _ if c.is_whitespace() => {
                cur.bump();
            }
            '/' if cur.peek(1) == Some('/') => {
                let mut text = String::new();
                while let Some(c) = cur.peek(0) {
                    if c == '\n' {
                        break;
                    }
                    text.push(c);
                    cur.bump();
                }
                out.push(Token {
                    kind: TokenKind::Comment,
                    text,
                    line,
                });
            }
            '/' if cur.peek(1) == Some('*') => {
                let mut text = String::new();
                let mut depth = 0usize;
                while let Some(c) = cur.peek(0) {
                    if c == '/' && cur.peek(1) == Some('*') {
                        depth += 1;
                        text.push_str("/*");
                        cur.bump();
                        cur.bump();
                    } else if c == '*' && cur.peek(1) == Some('/') {
                        depth -= 1;
                        text.push_str("*/");
                        cur.bump();
                        cur.bump();
                        if depth == 0 {
                            break;
                        }
                    } else {
                        text.push(c);
                        cur.bump();
                    }
                }
                out.push(Token {
                    kind: TokenKind::Comment,
                    text,
                    line,
                });
            }
            'r' if raw_string_hashes(&cur, 1).is_some() => {
                let text = lex_raw_string(&mut cur, 1);
                out.push(Token {
                    kind: TokenKind::Str,
                    text,
                    line,
                });
            }
            'b' if cur.peek(1) == Some('r') && raw_string_hashes(&cur, 2).is_some() => {
                let text = lex_raw_string(&mut cur, 2);
                out.push(Token {
                    kind: TokenKind::ByteStr,
                    text,
                    line,
                });
            }
            'b' if cur.peek(1) == Some('"') => {
                cur.bump();
                let mut text = String::from("b");
                lex_quoted(&mut cur, '"', &mut text);
                out.push(Token {
                    kind: TokenKind::ByteStr,
                    text,
                    line,
                });
            }
            'b' if cur.peek(1) == Some('\'') => {
                cur.bump();
                let mut text = String::from("b");
                lex_quoted(&mut cur, '\'', &mut text);
                out.push(Token {
                    kind: TokenKind::Byte,
                    text,
                    line,
                });
            }
            'r' if cur.peek(1) == Some('#') && cur.peek(2).is_some_and(is_ident_start) => {
                // raw identifier r#ident
                let mut text = String::from("r#");
                cur.bump();
                cur.bump();
                while let Some(c) = cur.peek(0) {
                    if !is_ident_continue(c) {
                        break;
                    }
                    text.push(c);
                    cur.bump();
                }
                out.push(Token {
                    kind: TokenKind::Ident,
                    text,
                    line,
                });
            }
            '"' => {
                let mut text = String::new();
                lex_quoted(&mut cur, '"', &mut text);
                out.push(Token {
                    kind: TokenKind::Str,
                    text,
                    line,
                });
            }
            '\'' => {
                // Lifetime (`'a`) vs char literal (`'a'`, `'\n'`): a
                // backslash or a closing quote two ahead means char.
                let next = cur.peek(1);
                let is_char = match next {
                    Some('\\') => true,
                    Some(c2) if is_ident_start(c2) => cur.peek(2) == Some('\''),
                    _ => true,
                };
                if is_char {
                    let mut text = String::new();
                    lex_quoted(&mut cur, '\'', &mut text);
                    out.push(Token {
                        kind: TokenKind::Char,
                        text,
                        line,
                    });
                } else {
                    let mut text = String::from("'");
                    cur.bump();
                    while let Some(c) = cur.peek(0) {
                        if !is_ident_continue(c) {
                            break;
                        }
                        text.push(c);
                        cur.bump();
                    }
                    out.push(Token {
                        kind: TokenKind::Lifetime,
                        text,
                        line,
                    });
                }
            }
            _ if is_ident_start(c) => {
                let mut text = String::new();
                while let Some(c) = cur.peek(0) {
                    if !is_ident_continue(c) {
                        break;
                    }
                    text.push(c);
                    cur.bump();
                }
                out.push(Token {
                    kind: TokenKind::Ident,
                    text,
                    line,
                });
            }
            _ if c.is_ascii_digit() => {
                let mut text = String::new();
                while let Some(c) = cur.peek(0) {
                    if is_ident_continue(c) {
                        text.push(c);
                        cur.bump();
                    } else if c == '.' && cur.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                        text.push(c);
                        cur.bump();
                    } else {
                        break;
                    }
                }
                out.push(Token {
                    kind: TokenKind::Number,
                    text,
                    line,
                });
            }
            _ => {
                cur.bump();
                out.push(Token {
                    kind: TokenKind::Punct,
                    text: c.to_string(),
                    line,
                });
            }
        }
    }
    out
}

/// If the cursor at offset `skip` sits on `#*"` (zero or more hashes
/// then a quote), return the hash count — i.e. `r`/`br` starts a raw
/// string here.
fn raw_string_hashes(cur: &Cursor<'_>, skip: usize) -> Option<usize> {
    let mut n = 0;
    loop {
        match cur.peek(skip + n) {
            Some('#') => n += 1,
            Some('"') => return Some(n),
            _ => return None,
        }
    }
}

/// Consume a raw string starting at the `r`/`b` (after `skip` prefix
/// chars), returning its full text including delimiters.
fn lex_raw_string(cur: &mut Cursor<'_>, skip: usize) -> String {
    let mut text = String::new();
    for _ in 0..skip {
        text.push(cur.bump().unwrap_or('\0'));
    }
    let mut hashes = 0usize;
    while cur.peek(0) == Some('#') {
        hashes += 1;
        text.push('#');
        cur.bump();
    }
    text.push('"');
    cur.bump(); // opening quote
    while let Some(c) = cur.peek(0) {
        if c == '"' {
            let closes = (0..hashes).all(|i| cur.peek(1 + i) == Some('#'));
            if closes {
                text.push('"');
                cur.bump();
                for _ in 0..hashes {
                    text.push('#');
                    cur.bump();
                }
                break;
            }
        }
        text.push(c);
        cur.bump();
    }
    text
}

/// Consume a `\`-escaped literal delimited by `delim`, starting at the
/// opening delimiter; appends the full text (delimiters included).
fn lex_quoted(cur: &mut Cursor<'_>, delim: char, text: &mut String) {
    text.push(delim);
    cur.bump(); // opening delimiter
    while let Some(c) = cur.peek(0) {
        if c == '\\' {
            text.push(c);
            cur.bump();
            if let Some(esc) = cur.peek(0) {
                text.push(esc);
                cur.bump();
            }
        } else if c == delim {
            text.push(c);
            cur.bump();
            break;
        } else {
            text.push(c);
            cur.bump();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let toks = kinds("let x = a.partial_cmp(&b);");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokenKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, ["let", "x", "a", "partial_cmp", "b"]);
        assert!(toks.contains(&(TokenKind::Punct, ".".to_string())));
    }

    #[test]
    fn strings_hide_identifiers() {
        let toks = kinds(r#"let s = "call .lock().unwrap() here";"#);
        assert!(toks
            .iter()
            .all(|(k, t)| *k != TokenKind::Ident || t != "unwrap"));
        assert_eq!(
            toks.iter().filter(|(k, _)| *k == TokenKind::Str).count(),
            1
        );
    }

    #[test]
    fn raw_strings_and_hashes() {
        let toks = kinds(r####"let s = r#"quote " inside"#; let t = r"plain";"####);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokenKind::Str)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(strs.len(), 2);
        assert!(strs[0].contains("quote \" inside"));
        assert_eq!(strs[1], "r\"plain\"");
    }

    #[test]
    fn byte_strings_and_byte_literals() {
        let toks = kinds("let m = b\"FBIN1\"; let n = b'\\n';");
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::ByteStr && t.contains("FBIN1")));
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Byte && t == r"b'\n'"));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Lifetime && t == "'a"));
        assert!(toks.iter().any(|(k, t)| *k == TokenKind::Char && t == "'x'"));
    }

    #[test]
    fn comments_kept_and_nested_blocks_terminate() {
        let src = "// line SAFETY: one\n/* outer /* inner */ still */ fn f() {}";
        let toks = lex(src);
        let comments: Vec<&Token> = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Comment)
            .collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("SAFETY:"));
        assert!(comments[1].text.contains("inner"));
        // the `fn` after the block comment is real code on line 2
        let f = toks.iter().find(|t| t.is_ident("fn")).unwrap();
        assert_eq!(f.line, 2);
    }

    #[test]
    fn line_numbers_cross_multiline_strings() {
        let src = "let a = \"one\ntwo\nthree\";\nlet b = 1;";
        let toks = lex(src);
        let b = toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b.line, 4);
    }

    #[test]
    fn code_tokens_skips_comments() {
        let toks = lex("a /* gap */ . b");
        let code = code_tokens(&toks);
        assert_eq!(code.len(), 3);
        assert!(toks[code[0]].is_ident("a"));
        assert!(toks[code[1]].is_punct('.'));
        assert!(toks[code[2]].is_ident("b"));
    }

    #[test]
    fn unterminated_literals_do_not_hang() {
        for src in ["\"open", "r#\"open", "b\"open", "'", "/* open"] {
            let _ = lex(src);
        }
    }
}
