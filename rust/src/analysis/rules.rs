//! The rule registry: each repo invariant as a token-stream check.
//!
//! Every rule operates on the lexed token stream of one file (see
//! [`super::lexer`]) plus its repo-relative path, and appends
//! [`Violation`]s with exact file:line positions. Rules are pure
//! functions — no I/O, no printing — so they are trivially unit-testable
//! on fixture snippets and safe to run from tests over the repo's own
//! tree.
//!
//! See the [module docs](super) for the list of rules and the PR
//! regressions that motivated each one.

use super::lexer::{code_tokens, Token, TokenKind};

/// One finding: which rule fired, where, and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Stable rule identifier (used in baselines and `--json` output).
    pub rule: &'static str,
    /// Repo-relative path with forward slashes (e.g. `src/lsh/mod.rs`).
    pub path: String,
    /// 1-based line of the offending token.
    pub line: u32,
    /// Human-oriented explanation, including the fix.
    pub message: String,
}

/// A registered rule.
pub struct Rule {
    /// Stable identifier, e.g. `float-total-cmp`.
    pub id: &'static str,
    /// One-line statement of the invariant.
    pub summary: &'static str,
    /// The PR history that motivated machine-enforcement.
    pub origin: &'static str,
    check: fn(&FileCtx<'_>, &mut Vec<Violation>),
}

impl Rule {
    /// Run this rule over one lexed file.
    pub fn check(&self, ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
        (self.check)(ctx, out);
    }
}

/// Everything a rule may look at for one file.
pub struct FileCtx<'a> {
    /// Repo-relative path, forward slashes (`src/...` or `tests/...`).
    pub rel_path: &'a str,
    /// The full token stream, comments included.
    pub tokens: &'a [Token],
    /// Indices of non-comment tokens (the pattern-matching view).
    pub code: Vec<usize>,
    /// Per-`code`-index flag: is this token inside a `#[cfg(test)]`
    /// item (attribute through closing brace)?
    pub in_test: Vec<bool>,
}

impl<'a> FileCtx<'a> {
    /// Lex-independent constructor used by the runner and by tests.
    pub fn new(rel_path: &'a str, tokens: &'a [Token]) -> Self {
        let code = code_tokens(tokens);
        let in_test = test_region_mask(tokens, &code);
        Self {
            rel_path,
            tokens,
            code,
            in_test,
        }
    }

    fn code_tok(&self, c: usize) -> &Token {
        &self.tokens[self.code[c]]
    }
}

/// The full registry, in reporting order.
pub fn all_rules() -> &'static [Rule] {
    &RULES
}

static RULES: [Rule; 7] = [
    Rule {
        id: "frame-localization",
        summary: "wire framing (magic bytes, length prefixes, scan caps, negotiation) \
                  lives only in server/protocol.rs; other server/cluster code goes \
                  through Framer / write_magic / MAGIC_LEN / MAX_FRAME_BYTES",
        origin: "PR 5 unified three frame-scan implementations into protocol::Framer \
                 and the invariant was previously enforced only by a hand-run rg",
        check: check_frame_localization,
    },
    Rule {
        id: "float-total-cmp",
        summary: "no .partial_cmp(..) on floats — use f64::total_cmp, which is total \
                  over NaN and bit-stable",
        origin: "NaN partial_cmp().unwrap() panics were fixed in PR 4 and regressed \
                 again by PR 6",
        check: check_float_total_cmp,
    },
    Rule {
        id: "mutex-poison",
        summary: "no bare .lock()/.read()/.write()/Condvar-wait .unwrap() in library \
                  code; go through crate::util::sync, which recovers from poisoning \
                  with .unwrap_or_else(std::sync::PoisonError::into_inner)",
        origin: "PR 7 retrofitted poison recovery after a panicking worker wedged \
                 every subsequent request behind a poisoned Mutex",
        check: check_mutex_poison,
    },
    Rule {
        id: "unsafe-safety",
        summary: "every `unsafe` is preceded by a // SAFETY: comment and confined to \
                  server/reactor.rs, runtime/pjrt_path.rs and coordinator/simd.rs",
        origin: "the raw-syscall epoll reactor (PR 6) and the AVX2 hash-kernel tile \
                 are the repo's only dense unsafe modules and must stay that way",
        check: check_unsafe_safety,
    },
    Rule {
        id: "wire-tags",
        summary: "binary wire tag constants (OP_*, REPLY_*, ERR_CODE_*) in \
                  protocol.rs are u8, unique, and contiguous from 1",
        origin: "PR 5/8 grew the FBIN1 op space; a duplicated or gapped tag would \
                 silently corrupt cross-version framing",
        check: check_wire_tags,
    },
    Rule {
        id: "print-discipline",
        summary: "no println!/eprintln!/print!/eprint!/dbg!/process::exit in library \
                  code — only cli/, bench/, main.rs and util/log.rs talk to \
                  stdio or end the process",
        origin: "PR 8's cluster nodes run headless; stray prints corrupted \
                 newline-framed JSON when stdout was redirected into the wire",
        check: check_print_discipline,
    },
    Rule {
        id: "checked-float-cast",
        summary: "no bare float -> i8/i16/i32 `as` casts in library code outside \
                  hashing/quantize.rs — `as` saturates silently (NaN becomes 0); \
                  go through quantize_hash / SigVec::from_i32",
        origin: "the seed hash kernel lowered `.floor()` with a bare `as i32`, \
                 collapsing overflowing and NaN hash values to MAX/MIN/bucket 0 \
                 instead of reporting a per-item error",
        check: check_checked_float_cast,
    },
];

// ------------------------------------------------------------ helpers

/// Per-code-token mask of `#[cfg(test)]` regions (the attribute tokens
/// themselves, any stacked attributes after it, and the annotated item
/// through its closing brace or terminating semicolon).
fn test_region_mask(tokens: &[Token], code: &[usize]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let tok = |c: usize| -> &Token { &tokens[code[c]] };
    let mut c = 0;
    while c < code.len() {
        if tok(c).is_punct('#') && c + 1 < code.len() && tok(c + 1).is_punct('[') {
            let (attr_end, is_test) = scan_attribute(tokens, code, c + 1);
            if is_test {
                let start = c;
                let mut end = attr_end; // index just past the `]`
                                        // skip stacked attributes after the cfg(test) one
                while end + 1 < code.len() && tok(end).is_punct('#') && tok(end + 1).is_punct('[')
                {
                    end = scan_attribute(tokens, code, end + 1).0;
                }
                end = scan_item(tokens, code, end);
                for m in mask.iter_mut().take(end.min(code.len())).skip(start) {
                    *m = true;
                }
                c = end;
                continue;
            }
            c = attr_end;
            continue;
        }
        c += 1;
    }
    mask
}

/// Scan an attribute starting at its `[` code index; return the code
/// index just past the matching `]` and whether it is a `cfg` attribute
/// with a non-negated `test` predicate (so `#[cfg(test)]` and
/// `#[cfg(all(test, not(miri)))]` match, `#[cfg(not(test))]` and
/// `#[cfg_attr(..)]` do not).
fn scan_attribute(tokens: &[Token], code: &[usize], open: usize) -> (usize, bool) {
    let tok = |c: usize| -> &Token { &tokens[code[c]] };
    let mut depth = 0usize;
    let mut end = open;
    while end < code.len() {
        if tok(end).is_punct('[') {
            depth += 1;
        } else if tok(end).is_punct(']') {
            depth -= 1;
            if depth == 0 {
                end += 1;
                break;
            }
        }
        end += 1;
    }
    let body = &code[open..end];
    let is_cfg = body
        .iter()
        .position(|&i| tokens[i].is_ident("cfg"))
        // `cfg` must be the attribute head: `#[cfg(...)]`
        .is_some_and(|p| p == 1);
    let mut is_test = false;
    if is_cfg {
        for (j, &i) in body.iter().enumerate() {
            if tokens[i].is_ident("test") {
                let negated = j >= 2
                    && tokens[body[j - 1]].is_punct('(')
                    && tokens[body[j - 2]].is_ident("not");
                if !negated {
                    is_test = true;
                }
            }
        }
    }
    (end, is_test)
}

/// Scan one item starting at code index `start` (just past the
/// attributes): returns the code index just past the item's closing
/// `}` — or past the `;` for brace-less items.
fn scan_item(tokens: &[Token], code: &[usize], start: usize) -> usize {
    let tok = |c: usize| -> &Token { &tokens[code[c]] };
    let mut c = start;
    let mut depth = 0usize;
    while c < code.len() {
        if tok(c).is_punct('{') {
            depth += 1;
        } else if tok(c).is_punct('}') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return c + 1;
            }
        } else if tok(c).is_punct(';') && depth == 0 {
            return c + 1;
        }
        c += 1;
    }
    c
}

fn violation(ctx: &FileCtx<'_>, rule: &'static str, line: u32, message: String) -> Violation {
    Violation {
        rule,
        path: ctx.rel_path.to_string(),
        line,
        message,
    }
}

// -------------------------------------------------------------- rules

const FRAME_BANNED_IDENTS: [&str; 6] = [
    "BINARY_MAGIC",
    "MAX_LINE_BYTES",
    "split_binary_frame",
    "negotiate",
    "from_le_bytes",
    "to_le_bytes",
];

/// Rule 1: `src/server/**` and `src/cluster/**` (except `protocol.rs`
/// itself) may not re-implement framing — no magic-byte constants, no
/// little-endian length (de)serialisation, no newline byte literals,
/// no references to the internal scan cap. Integration tests under
/// `tests/` are out of scope on purpose: adversarial suites *must*
/// hand-craft malformed wire bytes.
fn check_frame_localization(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    let p = ctx.rel_path;
    let in_scope = (p.starts_with("src/server/") || p.starts_with("src/cluster/"))
        && !p.ends_with("protocol.rs");
    if !in_scope {
        return;
    }
    for &i in &ctx.code {
        let t = &ctx.tokens[i];
        match t.kind {
            TokenKind::Ident if FRAME_BANNED_IDENTS.contains(&t.text.as_str()) => {
                out.push(violation(
                    ctx,
                    "frame-localization",
                    t.line,
                    format!(
                        "`{}` outside server/protocol.rs — framing is localized there; \
                         use protocol::Framer / write_magic / MAGIC_LEN / MAX_FRAME_BYTES",
                        t.text
                    ),
                ));
            }
            TokenKind::Str | TokenKind::ByteStr if t.text.contains("FBIN1") => {
                out.push(violation(
                    ctx,
                    "frame-localization",
                    t.line,
                    "literal FBIN1 magic outside server/protocol.rs — \
                     use protocol::write_magic"
                        .to_string(),
                ));
            }
            TokenKind::Byte if t.text == r"b'\n'" => {
                out.push(violation(
                    ctx,
                    "frame-localization",
                    t.line,
                    "newline frame-delimiter byte outside server/protocol.rs — \
                     use protocol::Framer for frame scanning"
                        .to_string(),
                ));
            }
            _ => {}
        }
    }
}

/// Rule 2: `.partial_cmp(..)` is banned everywhere (library and tests);
/// `f64::total_cmp` is total over NaN and bit-stable. The only allowed
/// occurrence is *defining* `fn partial_cmp` in a `PartialOrd` impl.
fn check_float_total_cmp(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    for (c, &i) in ctx.code.iter().enumerate() {
        let t = &ctx.tokens[i];
        if !t.is_ident("partial_cmp") {
            continue;
        }
        if c > 0 && ctx.code_tok(c - 1).is_ident("fn") {
            continue; // a PartialOrd impl defining the method
        }
        out.push(violation(
            ctx,
            "float-total-cmp",
            t.line,
            "call to partial_cmp — NaN makes it partial and .unwrap() panics; \
             use f64::total_cmp (PR 4 and PR 6 both fixed this class)"
                .to_string(),
        ));
    }
}

/// Rule 3: a poisoned lock must not take the process down with it.
/// Flags `.lock().unwrap()`, empty-argument `.read().unwrap()` /
/// `.write().unwrap()` (the `io::Read`/`io::Write` methods always take
/// a buffer, so the empty call is unambiguously `RwLock`), and Condvar
/// `.wait(..)`/`.wait_timeout(..)` followed by `.unwrap()`/`.expect(..)`.
/// `#[cfg(test)]` code is exempt: there a poisoned lock means the test
/// already panicked, and test-only types (e.g. the reactor's `Poller`)
/// have fallible `wait` methods of their own.
fn check_mutex_poison(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    let n = ctx.code.len();
    for c in 0..n {
        if ctx.in_test[c] || !ctx.code_tok(c).is_punct('.') || c + 1 >= n {
            continue;
        }
        let m = ctx.code_tok(c + 1);
        let after_call = if m.kind == TokenKind::Ident
            && matches!(m.text.as_str(), "lock" | "read" | "write")
            && c + 3 < n
            && ctx.code_tok(c + 2).is_punct('(')
            && ctx.code_tok(c + 3).is_punct(')')
        {
            Some(c + 4)
        } else if m.kind == TokenKind::Ident
            && matches!(m.text.as_str(), "wait" | "wait_timeout")
            && c + 2 < n
            && ctx.code_tok(c + 2).is_punct('(')
        {
            // balanced-paren scan; require at least one argument token
            // so `Child::wait()` (no args) is not mistaken for Condvar
            let mut depth = 0usize;
            let mut end = None;
            for j in c + 2..n {
                if ctx.code_tok(j).is_punct('(') {
                    depth += 1;
                } else if ctx.code_tok(j).is_punct(')') {
                    depth -= 1;
                    if depth == 0 {
                        end = Some(j);
                        break;
                    }
                }
            }
            match end {
                Some(j) if j > c + 3 => Some(j + 1),
                _ => None,
            }
        } else {
            None
        };
        let Some(u) = after_call else { continue };
        if u + 1 < n
            && ctx.code_tok(u).is_punct('.')
            && (ctx.code_tok(u + 1).is_ident("unwrap") || ctx.code_tok(u + 1).is_ident("expect"))
        {
            out.push(violation(
                ctx,
                "mutex-poison",
                m.line,
                format!(
                    "bare .{}(..).{}() — a poisoned lock would panic every later \
                     caller; use crate::util::sync ({})",
                    m.text,
                    ctx.code_tok(u + 1).text,
                    "poison recovery via unwrap_or_else(PoisonError::into_inner)"
                ),
            ));
        }
    }
}

const UNSAFE_WHITELIST: [&str; 3] = [
    "src/server/reactor.rs",
    "src/runtime/pjrt_path.rs",
    "src/coordinator/simd.rs",
];

/// How many lines above an `unsafe` token a `// SAFETY:` comment may
/// sit and still count as covering it.
const SAFETY_LOOKBACK_LINES: u32 = 8;

/// Rule 4: `unsafe` stays quarantined in the three whitelisted modules,
/// and every occurrence there carries a nearby `// SAFETY:` comment.
fn check_unsafe_safety(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    for &i in &ctx.code {
        let t = &ctx.tokens[i];
        if !t.is_ident("unsafe") {
            continue;
        }
        if !UNSAFE_WHITELIST.contains(&ctx.rel_path) {
            out.push(violation(
                ctx,
                "unsafe-safety",
                t.line,
                format!(
                    "unsafe outside the whitelist ({}) — keep raw-pointer/FFI/intrinsic \
                     code quarantined in the reactor, the PJRT path and the SIMD tile",
                    UNSAFE_WHITELIST.join(", ")
                ),
            ));
            continue;
        }
        let lo = t.line.saturating_sub(SAFETY_LOOKBACK_LINES);
        let covered = ctx.tokens.iter().any(|k| {
            k.kind == TokenKind::Comment
                && k.text.contains("SAFETY:")
                && k.line >= lo
                && k.line <= t.line
        });
        if !covered {
            out.push(violation(
                ctx,
                "unsafe-safety",
                t.line,
                "unsafe without a // SAFETY: comment in the preceding 8 lines"
                    .to_string(),
            ));
        }
    }
}

/// Rule 5: the binary wire's `OP_*` / `REPLY_*` / `ERR_CODE_*` tag
/// constants in `protocol.rs` must be `u8`, mutually unique, and
/// contiguous from 1 within each prefix — a gap or duplicate would
/// silently corrupt cross-version framing. Firing requires the file to
/// actually declare `OP_*` and `REPLY_*` tags: a refactor that renames
/// them away is itself a violation.
fn check_wire_tags(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    if ctx.rel_path != "src/server/protocol.rs" {
        return;
    }
    let mut groups: [(&str, Vec<(u64, u32, String)>); 3] = [
        ("OP_", Vec::new()),
        ("REPLY_", Vec::new()),
        ("ERR_CODE_", Vec::new()),
    ];
    let n = ctx.code.len();
    for c in 0..n.saturating_sub(6) {
        if !ctx.code_tok(c).is_ident("const") {
            continue;
        }
        let name = ctx.code_tok(c + 1);
        if name.kind != TokenKind::Ident {
            continue;
        }
        let Some(group) = groups
            .iter_mut()
            .find(|(p, _)| name.text.starts_with(p))
        else {
            continue;
        };
        if !(ctx.code_tok(c + 2).is_punct(':')
            && ctx.code_tok(c + 3).is_ident("u8")
            && ctx.code_tok(c + 4).is_punct('=')
            && ctx.code_tok(c + 6).is_punct(';'))
        {
            out.push(violation(
                ctx,
                "wire-tags",
                name.line,
                format!(
                    "wire tag `{}` is not a simple `const {}: u8 = <int>;` declaration",
                    name.text, name.text
                ),
            ));
            continue;
        }
        let value = ctx.code_tok(c + 5);
        match (value.kind == TokenKind::Number, value.text.parse::<u64>()) {
            (true, Ok(v)) => group.1.push((v, name.line, name.text.clone())),
            _ => out.push(violation(
                ctx,
                "wire-tags",
                name.line,
                format!("wire tag `{}` has a non-decimal-literal value", name.text),
            )),
        }
    }
    for (prefix, tags) in &groups {
        if tags.is_empty() {
            out.push(violation(
                ctx,
                "wire-tags",
                1,
                format!(
                    "no `{prefix}*` tag constants found in protocol.rs — the wire-tag \
                     audit has nothing to check (were they renamed?)"
                ),
            ));
            continue;
        }
        let mut sorted = tags.clone();
        sorted.sort_by_key(|(v, _, _)| *v);
        for w in sorted.windows(2) {
            if w[0].0 == w[1].0 {
                out.push(violation(
                    ctx,
                    "wire-tags",
                    w[1].1,
                    format!(
                        "duplicate wire tag value {}: `{}` and `{}`",
                        w[1].0, w[0].2, w[1].2
                    ),
                ));
            }
        }
        let max = sorted.last().map(|(v, _, _)| *v).unwrap_or(0);
        if sorted.first().map(|(v, _, _)| *v) != Some(1) || max != sorted.len() as u64 {
            // only meaningful when there are no duplicates; report once
            let values: Vec<String> = sorted.iter().map(|(v, _, _)| v.to_string()).collect();
            out.push(violation(
                ctx,
                "wire-tags",
                sorted[0].1,
                format!(
                    "`{prefix}*` tags are not contiguous from 1: [{}]",
                    values.join(", ")
                ),
            ));
        }
    }
}

const PRINT_WHITELIST_PREFIXES: [&str; 2] = ["src/cli/", "src/bench/"];
const PRINT_WHITELIST_FILES: [&str; 2] = ["src/main.rs", "src/util/log.rs"];
const PRINT_MACROS: [&str; 5] = ["println", "eprintln", "print", "eprint", "dbg"];

/// Rule 6: library code never talks to stdio or ends the process —
/// headless cluster nodes redirect stdout into the wire, so a stray
/// print corrupts newline-framed JSON. Diagnostics go through
/// `util::log::warn`; only `cli/`, `bench/`, `main.rs` and the log
/// choke point itself are exempt. `#[cfg(test)]` code may print.
fn check_print_discipline(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    let p = ctx.rel_path;
    if !p.starts_with("src/")
        || PRINT_WHITELIST_PREFIXES.iter().any(|w| p.starts_with(w))
        || PRINT_WHITELIST_FILES.contains(&p)
    {
        return;
    }
    let n = ctx.code.len();
    for c in 0..n {
        if ctx.in_test[c] {
            continue;
        }
        let t = ctx.code_tok(c);
        if t.kind != TokenKind::Ident {
            continue;
        }
        if PRINT_MACROS.contains(&t.text.as_str())
            && c + 1 < n
            && ctx.code_tok(c + 1).is_punct('!')
        {
            out.push(violation(
                ctx,
                "print-discipline",
                t.line,
                format!(
                    "{}! in library code — route diagnostics through \
                     crate::util::log::warn (stdout may be a wire)",
                    t.text
                ),
            ));
        }
        if t.text == "exit"
            && c >= 3
            && ctx.code_tok(c - 1).is_punct(':')
            && ctx.code_tok(c - 2).is_punct(':')
            && ctx.code_tok(c - 3).is_ident("process")
        {
            out.push(violation(
                ctx,
                "print-discipline",
                t.line,
                "process::exit in library code — return an error and let main decide"
                    .to_string(),
            ));
        }
    }
}

/// The one module allowed to spell a float→int `as` cast: the checked
/// quantizer itself (its cast is guarded by an explicit range test).
const FLOAT_CAST_WHITELIST: [&str; 1] = ["src/hashing/quantize.rs"];

/// Signature-width identifiers a float expression must never be
/// `as`-cast to directly.
const NARROW_INT_TYPES: [&str; 3] = ["i8", "i16", "i32"];

/// `f64`/`f32` methods whose receiver (and so whose call result) is a
/// float. Deliberately excludes names shared with integer/iterator
/// APIs (`abs`, `min`, `max`, `signum`, `clamp`) — a lexical rule
/// cannot see types, so shared names would flag integer code.
const FLOAT_METHODS: [&str; 19] = [
    "floor",
    "ceil",
    "round",
    "trunc",
    "fract",
    "sqrt",
    "cbrt",
    "exp",
    "exp2",
    "ln",
    "log2",
    "log10",
    "powf",
    "powi",
    "recip",
    "to_degrees",
    "to_radians",
    "mul_add",
    "hypot",
];

/// Is this `Number` literal a float? Loose-lexed suffixes are folded
/// into the token text, so `2.5`, `1e9`, and `3f64` are each one
/// token; hex/octal/binary literals are integers even when their
/// digits contain `e`.
fn is_float_literal(text: &str) -> bool {
    let lower = text.to_ascii_lowercase();
    if lower.starts_with("0x") || lower.starts_with("0o") || lower.starts_with("0b") {
        return false;
    }
    lower.contains('.')
        || lower.ends_with("f32")
        || lower.ends_with("f64")
        || lower.contains('e')
}

/// Rule 7: a bare `as i8`/`as i16`/`as i32` on a float expression
/// **saturates silently** — overflow pins to MAX/MIN and NaN becomes 0
/// — which is exactly the seed bug that collapsed non-finite hash
/// values into bucket 0. Library code routes every float→int lowering
/// through `hashing::quantize_hash` (scalar) or `SigVec::from_i32`
/// (signature narrowing), both of which range-check first and return a
/// typed `HashOverflow`.
///
/// Lexical detection: flag `<float> as {i8,i16,i32}` where `<float>`
/// is a float literal, the ident `f32`/`f64` (a cast chain like
/// `x as f64 as i32`), or a `)` whose matching `(` closes a call to a
/// known float-only method (`.floor() as i32`). Tests are exempt —
/// fixtures legitimately build raw expectations — as is the quantize
/// module, whose single cast sits behind an explicit range guard.
fn check_checked_float_cast(ctx: &FileCtx<'_>, out: &mut Vec<Violation>) {
    let p = ctx.rel_path;
    if !p.starts_with("src/") || FLOAT_CAST_WHITELIST.contains(&p) {
        return;
    }
    let n = ctx.code.len();
    for c in 1..n.saturating_sub(1) {
        if ctx.in_test[c] || !ctx.code_tok(c).is_ident("as") {
            continue;
        }
        let target = ctx.code_tok(c + 1);
        if target.kind != TokenKind::Ident || !NARROW_INT_TYPES.contains(&target.text.as_str()) {
            continue;
        }
        let prev = ctx.code_tok(c - 1);
        let float_source = match prev.kind {
            TokenKind::Number => is_float_literal(&prev.text),
            TokenKind::Ident => prev.text == "f32" || prev.text == "f64",
            _ if prev.is_punct(')') => {
                // Walk back to the matching `(`; the ident before it
                // names the call. `(a / b).floor() as i32` matches the
                // empty arg list of `floor`, not the parenthesised
                // receiver, because the scan starts at the *last* `)`.
                let mut depth = 0usize;
                let mut open = None;
                for j in (0..c).rev() {
                    if ctx.code_tok(j).is_punct(')') {
                        depth += 1;
                    } else if ctx.code_tok(j).is_punct('(') {
                        depth -= 1;
                        if depth == 0 {
                            open = Some(j);
                            break;
                        }
                    }
                }
                open.is_some_and(|j| {
                    j > 0
                        && ctx.code_tok(j - 1).kind == TokenKind::Ident
                        && FLOAT_METHODS.contains(&ctx.code_tok(j - 1).text.as_str())
                })
            }
            _ => false,
        };
        if float_source {
            out.push(violation(
                ctx,
                "checked-float-cast",
                ctx.code_tok(c).line,
                format!(
                    "bare float `as {}` saturates (overflow pins to MAX/MIN, NaN \
                     becomes 0) — use hashing::quantize_hash / SigVec::from_i32, \
                     which range-check and return a typed HashOverflow",
                    target.text
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run_rule(id: &str, rel_path: &str, src: &str) -> Vec<Violation> {
        let tokens = lex(src);
        let ctx = FileCtx::new(rel_path, &tokens);
        let rule = all_rules().iter().find(|r| r.id == id).expect("known rule");
        let mut out = Vec::new();
        rule.check(&ctx, &mut out);
        out
    }

    // ---------------------------------------------- frame-localization

    #[test]
    fn frame_rule_flags_magic_and_le_bytes_in_server_scope() {
        let src = "let m = BINARY_MAGIC;\nlet n = u32::from_le_bytes(b);\n";
        let v = run_rule("frame-localization", "src/server/client.rs", src);
        assert_eq!(v.len(), 2);
        assert_eq!((v[0].path.as_str(), v[0].line), ("src/server/client.rs", 1));
        assert_eq!(v[1].line, 2);
    }

    #[test]
    fn frame_rule_flags_fbin1_literal_and_newline_byte() {
        let src = "w.write_all(b\"FBIN1\")?;\nif b == b'\\n' { split(); }\n";
        let v = run_rule("frame-localization", "src/cluster/router.rs", src);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].line, 1);
        assert_eq!(v[1].line, 2);
    }

    #[test]
    fn frame_rule_ignores_protocol_rs_other_modules_and_comments() {
        let src = "let m = BINARY_MAGIC; // BINARY_MAGIC in a comment is fine elsewhere\n";
        assert!(run_rule("frame-localization", "src/server/protocol.rs", src).is_empty());
        assert!(run_rule("frame-localization", "src/lsh/shard.rs", src).is_empty());
        let comment_only = "// uses BINARY_MAGIC and b'\\n' only in prose\nlet x = 1;\n";
        assert!(run_rule("frame-localization", "src/server/mod.rs", comment_only).is_empty());
    }

    #[test]
    fn frame_rule_allows_negotiated_method_and_public_cap() {
        let src = "if let Some(m) = framer.negotiated() { cap(protocol::MAX_FRAME_BYTES); }\n";
        assert!(run_rule("frame-localization", "src/server/event_loop.rs", src).is_empty());
    }

    // ------------------------------------------------- float-total-cmp

    #[test]
    fn total_cmp_rule_flags_calls_everywhere_with_position() {
        let src = "fn f(xs: &mut [f64]) {\n    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let v = run_rule("float-total-cmp", "src/search/mod.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 2);
        let in_tests_dir = run_rule("float-total-cmp", "tests/properties.rs", src);
        assert_eq!(in_tests_dir.len(), 1);
    }

    #[test]
    fn total_cmp_rule_skips_definitions_strings_and_comments() {
        let src = "impl PartialOrd for T {\n\
                   fn partial_cmp(&self, o: &Self) -> Option<O> { Some(self.cmp(o)) }\n\
                   }\n\
                   // partial_cmp in a comment\n\
                   let s = \"partial_cmp in a string\";\n";
        assert!(run_rule("float-total-cmp", "src/wasserstein/discrete.rs", src).is_empty());
    }

    // ---------------------------------------------------- mutex-poison

    #[test]
    fn poison_rule_flags_lock_rwlock_and_condvar_unwraps() {
        let src = "let a = m.lock().unwrap();\n\
                   let b = rw.read().unwrap();\n\
                   let c = rw.write().expect(\"w\");\n\
                   let g = cv.wait(g).unwrap();\n\
                   let (g, t) = cv.wait_timeout(g, d).unwrap();\n";
        let v = run_rule("mutex-poison", "src/coordinator/batcher.rs", src);
        assert_eq!(v.iter().map(|v| v.line).collect::<Vec<_>>(), [1, 2, 3, 4, 5]);
    }

    #[test]
    fn poison_rule_allows_recovery_io_read_write_and_child_wait() {
        let src = "let a = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n\
                   let b = crate::util::sync::lock(&m);\n\
                   let n = file.read(&mut buf).unwrap();\n\
                   sock.write(&buf[..n]).unwrap();\n\
                   let status = child.wait().unwrap();\n";
        assert!(run_rule("mutex-poison", "src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn poison_rule_exempts_cfg_test_regions() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   fn t() {\n\
                   let g = m.lock().unwrap();\n\
                   let r = poller.wait(timeout).unwrap();\n\
                   }\n\
                   }\n";
        assert!(run_rule("mutex-poison", "src/server/reactor.rs", src).is_empty());
        let lib = "fn f() { let g = m.lock().unwrap(); }\n";
        assert_eq!(run_rule("mutex-poison", "src/server/reactor.rs", lib).len(), 1);
    }

    // --------------------------------------------------- unsafe-safety

    #[test]
    fn unsafe_rule_enforces_whitelist() {
        let src = "let p = unsafe { *ptr };\n";
        let v = run_rule("unsafe-safety", "src/lsh/mod.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 1);
        assert!(v[0].message.contains("whitelist"));
    }

    #[test]
    fn unsafe_rule_requires_nearby_safety_comment() {
        let bare = "fn f() { unsafe { syscall() }; }\n";
        let v = run_rule("unsafe-safety", "src/server/reactor.rs", bare);
        assert_eq!(v.len(), 1);
        assert!(v[0].message.contains("SAFETY:"));
        let covered = "// SAFETY: fd is open for the lifetime of self\n\
                       fn f() { unsafe { syscall() }; }\n";
        assert!(run_rule("unsafe-safety", "src/server/reactor.rs", covered).is_empty());
        let far = format!(
            "// SAFETY: too far away\n{}fn f() {{ unsafe {{ syscall() }}; }}\n",
            "\n".repeat(12)
        );
        assert_eq!(run_rule("unsafe-safety", "src/server/reactor.rs", &far).len(), 1);
    }

    #[test]
    fn unsafe_rule_ignores_prose_mentions() {
        let src = "// this API is not unsafe, just sharp\nlet s = \"unsafe\";\n";
        assert!(run_rule("unsafe-safety", "src/json/mod.rs", src).is_empty());
    }

    // ------------------------------------------------------- wire-tags

    #[test]
    fn wire_tags_accept_unique_contiguous_groups() {
        let src = "const OP_A: u8 = 1;\n\
                   const OP_B: u8 = 2;\n\
                   const REPLY_A: u8 = 1;\n\
                   const ERR_CODE_X: u8 = 1;\n";
        assert!(run_rule("wire-tags", "src/server/protocol.rs", src).is_empty());
    }

    #[test]
    fn wire_tags_flag_duplicates_and_gaps_with_lines() {
        let dup = "const OP_A: u8 = 1;\n\
                   const OP_B: u8 = 1;\n\
                   const REPLY_A: u8 = 1;\n\
                   const ERR_CODE_X: u8 = 1;\n";
        let v = run_rule("wire-tags", "src/server/protocol.rs", dup);
        assert!(v.iter().any(|v| v.line == 2 && v.message.contains("duplicate")));
        let gap = "const OP_A: u8 = 1;\n\
                   const OP_B: u8 = 3;\n\
                   const REPLY_A: u8 = 1;\n\
                   const ERR_CODE_X: u8 = 1;\n";
        let v = run_rule("wire-tags", "src/server/protocol.rs", gap);
        assert!(v.iter().any(|v| v.message.contains("not contiguous")));
    }

    #[test]
    fn wire_tags_flag_missing_groups_and_wrong_types() {
        let none = "const SOMETHING_ELSE: u8 = 1;\n";
        let v = run_rule("wire-tags", "src/server/protocol.rs", none);
        assert_eq!(v.len(), 3); // OP_, REPLY_, ERR_CODE_ all absent
        let wrong = "const OP_A: u16 = 1;\nconst REPLY_A: u8 = 1;\nconst ERR_CODE_X: u8 = 1;\n";
        let v = run_rule("wire-tags", "src/server/protocol.rs", wrong);
        assert!(v.iter().any(|v| v.line == 1 && v.message.contains("u8")));
    }

    #[test]
    fn wire_tags_only_apply_to_protocol_rs() {
        let src = "const OP_A: u8 = 1;\nconst OP_B: u8 = 1;\n";
        assert!(run_rule("wire-tags", "src/cluster/router.rs", src).is_empty());
    }

    // ------------------------------------------------ print-discipline

    #[test]
    fn print_rule_flags_macros_and_process_exit() {
        let src = "pub fn f() {\n\
                   println!(\"hi\");\n\
                   eprintln!(\"warn\");\n\
                   dbg!(1);\n\
                   std::process::exit(2);\n\
                   }\n";
        let v = run_rule("print-discipline", "src/coordinator/service.rs", src);
        assert_eq!(v.iter().map(|v| v.line).collect::<Vec<_>>(), [2, 3, 4, 5]);
    }

    #[test]
    fn print_rule_whitelists_cli_bench_main_and_log() {
        let src = "pub fn f() { println!(\"ok\"); std::process::exit(0); }\n";
        for path in ["src/cli/mod.rs", "src/bench/mod.rs", "src/main.rs", "src/util/log.rs"] {
            assert!(run_rule("print-discipline", path, src).is_empty(), "{path}");
        }
    }

    #[test]
    fn print_rule_skips_cfg_test_regions_but_not_cfg_not_test() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   #[test]\n\
                   fn t() { println!(\"test output is fine\"); }\n\
                   }\n\
                   pub fn lib() { eprintln!(\"not fine\"); }\n";
        let v = run_rule("print-discipline", "src/lsh/mod.rs", src);
        assert_eq!(v.iter().map(|v| v.line).collect::<Vec<_>>(), [6]);
        let not_test = "#[cfg(not(test))]\npub fn lib() { eprintln!(\"still library code\"); }\n";
        assert_eq!(run_rule("print-discipline", "src/lsh/mod.rs", not_test).len(), 1);
    }

    #[test]
    fn print_rule_allows_writeln_and_log_warn() {
        let src = "writeln!(out, \"data\")?;\ncrate::util::log::warn(\"slow path\");\n";
        assert!(run_rule("print-discipline", "src/trace/mod.rs", src).is_empty());
    }

    // ---------------------------------------------- checked-float-cast

    #[test]
    fn float_cast_rule_flags_literals_cast_chains_and_float_methods() {
        let src = "let a = 2.5 as i32;\n\
                   let b = 1e9 as i16;\n\
                   let c = x as f64 as i32;\n\
                   let d = (v / r).floor() as i32;\n\
                   let e = y.powi(3) as i8;\n";
        let v = run_rule("checked-float-cast", "src/coordinator/hashpath.rs", src);
        assert_eq!(v.iter().map(|v| v.line).collect::<Vec<_>>(), [1, 2, 3, 4, 5]);
        assert!(v[0].message.contains("quantize_hash"));
    }

    #[test]
    fn float_cast_rule_allows_integer_sources_and_unlisted_methods() {
        let src = "let a = 5 as i32;\n\
                   let b = 0x1e as i32;\n\
                   let c = k as i32;\n\
                   let d = v.len() as i32;\n\
                   let e = i8::from_le_bytes(b) as i32;\n\
                   let f = (id % 3) as i32;\n\
                   let g = n.abs() as i32;\n\
                   let h = x as i64;\n";
        assert!(run_rule("checked-float-cast", "src/lsh/shard.rs", src).is_empty());
    }

    #[test]
    fn float_cast_rule_exempts_quantize_tests_and_non_src() {
        let src = "let a = 2.5 as i32;\n";
        assert!(run_rule("checked-float-cast", "src/hashing/quantize.rs", src).is_empty());
        assert!(run_rule("checked-float-cast", "tests/kernel_parity.rs", src).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\nfn t() { let a = 2.5 as i32; }\n}\n";
        assert!(run_rule("checked-float-cast", "src/lsh/mod.rs", in_test).is_empty());
        let prose = "// 2.5 as i32 in a comment\nlet s = \"3.5 as i32\";\n";
        assert!(run_rule("checked-float-cast", "src/lsh/mod.rs", prose).is_empty());
    }

    #[test]
    fn test_region_mask_handles_cfg_all_and_stacked_attrs() {
        let src = "#[cfg(all(test, not(miri)))]\n\
                   #[allow(dead_code)]\n\
                   mod tests { fn t() { println!(\"x\"); } }\n\
                   pub fn lib() { println!(\"y\"); }\n";
        let v = run_rule("print-discipline", "src/config/mod.rs", src);
        assert_eq!(v.iter().map(|v| v.line).collect::<Vec<_>>(), [4]);
    }
}
