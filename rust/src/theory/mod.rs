//! Theoretical collision probabilities and the paper's Theorem 1 bounds.
//!
//! These curves are what every figure plots the observed rates against:
//!
//! * Eq. 7 — SimHash: `P = 1 − arccos(cossim)/π`.
//! * Eq. 8 — p-stable hash: `P = ∫₀^{r/c} f_p(s)(1 − cs/r) ds` with `f_p`
//!   the pdf of the absolute value of a standard p-stable variate. Closed
//!   forms for `p = 1, 2`; numeric evaluation (Nolan-style integral for the
//!   stable pdf + Gauss–Legendre) for general `p ∈ (0, 2)`.
//! * Theorem 1 — the upper/lower collision-probability bands under an
//!   embedding error `‖ε‖ ≤ ε`.

use crate::quadrature::gauss_legendre;
use crate::util::special::{normal_cdf, normal_pdf};
use std::f64::consts::PI;

/// Eq. 7: SimHash collision probability at cosine similarity `s ∈ [-1, 1]`.
pub fn simhash_collision_probability(s: f64) -> f64 {
    let s = s.clamp(-1.0, 1.0);
    1.0 - s.acos() / PI
}

/// Eq. 8 specialized to `p = 2` (Gaussian): closed form from Datar et al.:
/// `P(c) = 2Φ(r/c) − 1 − 2/(√(2π) (r/c)) (1 − e^{−r²/(2c²)})`.
pub fn gaussian_collision_probability(c: f64, r: f64) -> f64 {
    assert!(r > 0.0);
    if c <= 0.0 {
        return 1.0;
    }
    let s = r / c;
    2.0 * normal_cdf(s) - 1.0 - 2.0 / ((2.0 * PI).sqrt() * s) * (1.0 - (-s * s / 2.0).exp())
}

/// Eq. 8 specialized to `p = 1` (Cauchy):
/// `P(c) = (2/π) arctan(r/c) − 1/(π (r/c)) ln(1 + (r/c)²)`.
pub fn cauchy_collision_probability(c: f64, r: f64) -> f64 {
    assert!(r > 0.0);
    if c <= 0.0 {
        return 1.0;
    }
    let s = r / c;
    2.0 / PI * s.atan() - (1.0 + s * s).ln() / (PI * s)
}

/// pdf of a standard symmetric `p`-stable variate, by numerical inversion
/// of the characteristic function: `f(x) = (1/π) ∫₀^∞ e^{−t^p} cos(xt) dt`.
///
/// Adequate for the moderate `x` needed by collision-probability integrals
/// (the oscillatory tail is handled by splitting at the cosine zeros).
pub fn stable_pdf(x: f64, p: f64) -> f64 {
    assert!(p > 0.0 && p <= 2.0);
    if (p - 2.0).abs() < 1e-12 {
        // Convention note: we follow Datar et al., whose 2-stable hash
        // draws α ~ N(0,1) — so the "standard" 2-stable density here is
        // φ(x), not the e^{-t²} characteristic-function normalization
        // (which would be N(0,2)). The sampler in util::rng matches.
        return normal_pdf(x);
    }
    if (p - 1.0).abs() < 1e-12 {
        return 1.0 / (PI * (1.0 + x * x));
    }
    let x = x.abs();
    // Integrate e^{-t^p} cos(xt) over [0, T] with panels no wider than the
    // cosine half-period (and no wider than 1 so the e^{-t^p} decay near
    // t = 0 is always resolved).
    let (nodes, weights) = gauss_legendre(32);
    let mut total = 0.0;
    let panel_width = if x > 1e-9 { (PI / x).min(1.0) } else { 1.0 };
    let mut a = 0.0;
    for _ in 0..2000 {
        let b = a + panel_width;
        let mid = 0.5 * (a + b);
        let half = 0.5 * (b - a);
        let mut panel = 0.0;
        for (t, w) in nodes.iter().zip(&weights) {
            let u = mid + half * t;
            panel += w * (-(u.powf(p))).exp() * (x * u).cos();
        }
        panel *= half;
        total += panel;
        a = b;
        // stop once the envelope e^{-a^p} is negligible
        if (-(a.powf(p))).exp() < 1e-16 {
            break;
        }
    }
    (total / PI).max(0.0)
}

/// Eq. 8 for general `p`: `P(c) = ∫₀^{r/c} f_p(s) (1 − cs/r) ds` where
/// `f_p(s) = 2 · stable_pdf(s, p)` is the density of `|X|`.
pub fn pstable_collision_probability(c: f64, r: f64, p: f64) -> f64 {
    assert!(r > 0.0);
    if c <= 0.0 {
        return 1.0;
    }
    if (p - 2.0).abs() < 1e-12 {
        return gaussian_collision_probability(c, r);
    }
    if (p - 1.0).abs() < 1e-12 {
        return cauchy_collision_probability(c, r);
    }
    let s_max = r / c;
    let (nodes, weights) = gauss_legendre(64);
    let mid = 0.5 * s_max;
    let half = 0.5 * s_max;
    let mut acc = 0.0;
    for (t, w) in nodes.iter().zip(&weights) {
        let s = mid + half * t;
        acc += w * 2.0 * stable_pdf(s, p) * (1.0 - c * s / r);
    }
    (acc * half).clamp(0.0, 1.0)
}

/// `‖f_p‖_∞` — the sup of the density of `|X|` for a standard p-stable `X`
/// (attained at 0 for the symmetric densities used here).
pub fn stable_abs_pdf_sup(p: f64) -> f64 {
    2.0 * stable_pdf(0.0, p)
}

/// Theorem 1: bounds on the collision probability of the *embedded* hash
/// when the embedding carries absolute error `ε` (i.e. `‖ε_f‖ + ‖ε_g‖ ≤ ε`)
/// at true distance `c`, bucket width `r`, stability index `p`.
///
/// Returns `(lower, upper)`:
/// * upper = `P + min(ε/(c−ε), ε r ‖f_p‖_∞ / (2(c−ε)²))` (for `ε < c`)
/// * lower = `P − min(2ε/(c+ε), ε r ‖f_p‖_∞ / (2(c+ε)²))`
pub fn theorem1_bounds(c: f64, r: f64, p: f64, eps: f64) -> (f64, f64) {
    assert!(c > 0.0 && eps >= 0.0);
    let pr = pstable_collision_probability(c, r, p);
    let sup = stable_abs_pdf_sup(p);
    let upper = if eps < c {
        let t1 = eps / (c - eps);
        let t2 = eps * r * sup / (2.0 * (c - eps) * (c - eps));
        (pr + t1.min(t2)).min(1.0)
    } else {
        1.0
    };
    let t1 = 2.0 * eps / (c + eps);
    let t2 = eps * r * sup / (2.0 * (c + eps) * (c + eps));
    let lower = (pr - t1.min(t2)).max(0.0);
    (lower, upper)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quadrature::integrate_gl;

    #[test]
    fn simhash_extremes() {
        assert!((simhash_collision_probability(1.0) - 1.0).abs() < 1e-12);
        assert!((simhash_collision_probability(-1.0)).abs() < 1e-12);
        assert!((simhash_collision_probability(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gaussian_closed_form_matches_integral() {
        // Direct quadrature of Eq. 8 vs the closed form.
        for &(c, r) in &[(0.5, 1.0), (1.0, 1.0), (2.0, 1.0), (1.0, 4.0)] {
            let integral = {
                let f = move |t: f64| {
                    2.0 / (c * (2.0 * PI).sqrt())
                        * (-(t * t) / (2.0 * c * c)).exp()
                        * (1.0 - t / r)
                };
                integrate_gl(&f, 0.0, r, 128)
            };
            let closed = gaussian_collision_probability(c, r);
            assert!(
                (integral - closed).abs() < 1e-10,
                "c={c} r={r}: {integral} vs {closed}"
            );
        }
    }

    #[test]
    fn cauchy_closed_form_matches_integral() {
        for &(c, r) in &[(0.5, 1.0), (1.0, 2.0), (3.0, 1.0)] {
            let integral = {
                let f = move |t: f64| {
                    (2.0 / (PI * c)) / (1.0 + (t / c) * (t / c)) * (1.0 - t / r)
                };
                integrate_gl(&f, 0.0, r, 256)
            };
            let closed = cauchy_collision_probability(c, r);
            assert!(
                (integral - closed).abs() < 1e-9,
                "c={c} r={r}: {integral} vs {closed}"
            );
        }
    }

    #[test]
    fn stable_pdf_special_cases() {
        // p = 1 must be Cauchy, p = 2 must be N(0, 1) (Datar convention).
        assert!((stable_pdf(0.0, 1.0) - 1.0 / PI).abs() < 1e-12);
        assert!((stable_pdf(1.0, 1.0) - 1.0 / (2.0 * PI)).abs() < 1e-12);
        assert!((stable_pdf(1.0, 2.0) - normal_pdf(1.0)).abs() < 1e-12);
    }

    #[test]
    fn stable_pdf_generic_integrates_to_one() {
        // ∫ f_{1.5} = 1 (symmetric: 2 ∫₀^∞). The heavy x^{-2.5} tail past
        // the truncation at 40 carries ~1.6e-3 of mass.
        let p = 1.5;
        let f = move |x: f64| stable_pdf(x, p);
        let total = 2.0 * integrate_gl(&f, 0.0, 40.0, 400);
        assert!((total - 1.0).abs() < 4e-3, "total {total}");
    }

    #[test]
    fn generic_p_matches_closed_forms_at_1_and_2() {
        // The numeric path (forced via p ± tiny offsets) agrees with the
        // closed forms.
        for &c in &[0.5, 1.0, 2.0] {
            let num = pstable_collision_probability(c, 1.0, 1.0 + 1e-9);
            let closed = cauchy_collision_probability(c, 1.0);
            assert!((num - closed).abs() < 1e-3, "c={c}: {num} vs {closed}");
        }
    }

    #[test]
    fn collision_probability_monotone_in_c() {
        for &p in &[0.5, 1.0, 1.5, 2.0] {
            let mut prev = 1.0;
            for i in 1..20 {
                let c = 0.2 * i as f64;
                let pr = pstable_collision_probability(c, 1.0, p);
                assert!(pr <= prev + 1e-9, "p={p} c={c}: {pr} > {prev}");
                assert!((0.0..=1.0).contains(&pr));
                prev = pr;
            }
        }
    }

    #[test]
    fn sup_values() {
        // ‖f_2‖_∞ = 2 φ(0) = √(2/π); ‖f_1‖_∞ = 2/π.
        assert!((stable_abs_pdf_sup(2.0) - (2.0 / PI).sqrt()).abs() < 1e-12);
        assert!((stable_abs_pdf_sup(1.0) - 2.0 / PI).abs() < 1e-12);
    }

    #[test]
    fn theorem1_bands_bracket_p_and_tighten() {
        let (c, r, p) = (1.0, 1.0, 2.0);
        let pr = pstable_collision_probability(c, r, p);
        let (lo1, hi1) = theorem1_bounds(c, r, p, 0.2);
        let (lo2, hi2) = theorem1_bounds(c, r, p, 0.02);
        assert!(lo1 <= pr && pr <= hi1);
        assert!(lo2 <= pr && pr <= hi2);
        assert!(hi2 - lo2 < hi1 - lo1, "bands must tighten as ε → 0");
        // ε = 0 collapses the band
        let (lo0, hi0) = theorem1_bounds(c, r, p, 0.0);
        assert!((lo0 - pr).abs() < 1e-12 && (hi0 - pr).abs() < 1e-12);
    }

    #[test]
    fn theorem1_degenerate_eps_ge_c() {
        let (lo, hi) = theorem1_bounds(0.5, 1.0, 2.0, 0.6);
        assert_eq!(hi, 1.0);
        assert!(lo >= 0.0);
    }
}
