//! Workload generators — the random inputs of every experiment, matching
//! the paper's §4 descriptions exactly, plus corpora and request traces
//! for the end-to-end service experiments.

use crate::functions::{Function1D, GaussianDist, GaussianMixture, Sine};
use crate::util::rng::Rng64;
use std::f64::consts::PI;

/// Figure 1–2 workload: pairs of `sin(2πx + δ)` with
/// `δ₁, δ₂ ~ Uniform[0, 2π]`.
pub fn sine_pair(rng: &mut dyn Rng64) -> (Sine, Sine) {
    (
        Sine::paper(rng.uniform_in(0.0, 2.0 * PI)),
        Sine::paper(rng.uniform_in(0.0, 2.0 * PI)),
    )
}

/// Figure 3 workload: pairs of 1-D Gaussians with
/// `μ ~ Uniform[−1, 1]` and `σ² ~ Uniform[0, 1]` (σ² floored away from 0
/// to keep the distributions nondegenerate, matching the paper's sampler).
pub fn gaussian_pair(rng: &mut dyn Rng64) -> (GaussianDist, GaussianDist) {
    let draw = |rng: &mut dyn Rng64| {
        let mu = rng.uniform_in(-1.0, 1.0);
        let var = rng.uniform_in(1e-4, 1.0);
        GaussianDist::new(mu, var.sqrt())
    };
    (draw(rng), draw(rng))
}

/// A random Gaussian mixture with `k` components — the corpus entries of
/// the end-to-end k-NN experiment (E6).
pub fn random_gmm(k: usize, rng: &mut dyn Rng64) -> GaussianMixture {
    assert!(k >= 1);
    let comps = (0..k)
        .map(|_| {
            GaussianDist::new(
                rng.uniform_in(-2.0, 2.0),
                rng.uniform_in(0.05, 0.8),
            )
        })
        .collect();
    let weights = (0..k).map(|_| rng.uniform_in(0.1, 1.0)).collect();
    GaussianMixture::new(comps, weights)
}

/// A corpus of `n` random GMMs (1–4 components each).
pub fn gmm_corpus(n: usize, rng: &mut dyn Rng64) -> Vec<GaussianMixture> {
    (0..n)
        .map(|_| {
            let k = 1 + rng.uniform_usize(4);
            random_gmm(k, rng)
        })
        .collect()
}

/// One request of a synthetic service trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceOp {
    /// insert a new corpus entry (pre-sampled function values)
    Insert {
        /// entry id
        id: u64,
        /// raw samples at the embedder's sample points
        samples: Vec<f64>,
    },
    /// k-NN query
    Query {
        /// raw samples at the embedder's sample points
        samples: Vec<f64>,
        /// number of neighbours requested
        k: usize,
    },
}

/// Generate a mixed insert/query trace over sine functions sampled at
/// `points` (`insert_fraction` of operations are inserts).
pub fn sine_trace(
    n_ops: usize,
    points: &[f64],
    insert_fraction: f64,
    rng: &mut dyn Rng64,
) -> Vec<TraceOp> {
    let mut next_id = 0u64;
    (0..n_ops)
        .map(|_| {
            let phase = rng.uniform_in(0.0, 2.0 * PI);
            let f = Sine::paper(phase);
            let samples: Vec<f64> = points.iter().map(|&x| f.eval(x)).collect();
            if rng.uniform() < insert_fraction {
                let id = next_id;
                next_id += 1;
                TraceOp::Insert { id, samples }
            } else {
                TraceOp::Query { samples, k: 10 }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::Distribution1D;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sine_pair_phases_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(61);
        for _ in 0..100 {
            let (f, g) = sine_pair(&mut rng);
            assert!((0.0..2.0 * PI).contains(&f.phase));
            assert!((0.0..2.0 * PI).contains(&g.phase));
            assert_eq!(f.amplitude, 1.0);
        }
    }

    #[test]
    fn gaussian_pair_parameter_ranges() {
        let mut rng = Xoshiro256pp::seed_from_u64(63);
        for _ in 0..100 {
            let (a, b) = gaussian_pair(&mut rng);
            for g in [a, b] {
                assert!((-1.0..1.0).contains(&g.mu));
                assert!(g.sigma > 0.0 && g.sigma <= 1.0);
            }
        }
    }

    #[test]
    fn gmm_corpus_valid_distributions() {
        let mut rng = Xoshiro256pp::seed_from_u64(65);
        let corpus = gmm_corpus(20, &mut rng);
        assert_eq!(corpus.len(), 20);
        for g in &corpus {
            assert!((1..=4).contains(&g.num_components()));
            // CDF must be monotone, quantile must invert it
            let q = g.quantile(0.5);
            assert!((g.cdf(q) - 0.5).abs() < 1e-8);
        }
    }

    #[test]
    fn trace_mix_and_ids() {
        let mut rng = Xoshiro256pp::seed_from_u64(67);
        let points: Vec<f64> = (0..8).map(|i| i as f64 / 8.0).collect();
        let trace = sine_trace(1000, &points, 0.5, &mut rng);
        let inserts: Vec<u64> = trace
            .iter()
            .filter_map(|op| match op {
                TraceOp::Insert { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        // ids are dense 0..k
        for (want, got) in inserts.iter().enumerate() {
            assert_eq!(*got, want as u64);
        }
        let frac = inserts.len() as f64 / 1000.0;
        assert!((frac - 0.5).abs() < 0.1, "insert fraction {frac}");
        // sample vectors have the right arity
        for op in &trace {
            let s = match op {
                TraceOp::Insert { samples, .. } | TraceOp::Query { samples, .. } => samples,
            };
            assert_eq!(s.len(), 8);
        }
    }
}
