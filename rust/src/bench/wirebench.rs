//! The `bench-wire` grid: JSON vs `FBIN1` binary loopback throughput of
//! the serving layer at dim ∈ {64, 256, 1024} × batch ∈ {1, 16, 256},
//! recorded as the second JSON trajectory file (`BENCH_wire.json`) so
//! later PRs have wire numbers to regress against.
//!
//! For each (dim, wire, batch) cell the grid boots one server on an
//! ephemeral loopback port, drives it with the pipelined load generator
//! (hash-heavy mix — sample rows dominate the wire cost, which is what
//! the binary format exists to cut; `batch` rows per frame, which is
//! what the batched ops exist to amortize), and records throughput,
//! latency percentiles, and the exact frame size of a `hash`/
//! `hash_batch` op in each format. Every JSON row is self-describing:
//! it carries the *negotiated* wire mode and batch size straight from
//! the load report, plus the serving io_mode, so `BENCH_wire.json`
//! trajectories can be compared across PRs without reconstructing the
//! grid loops. `funclsh bench-wire [--quick] [--out F]` runs it; CI's
//! `bench-smoke` job uploads the artifact alongside
//! `BENCH_hashpath.json`.

use crate::config::ServiceConfig;
use crate::coordinator::{Coordinator, CpuHashPath, HashPath, StatsDetail};
use crate::embedding::{Embedder, Interval, MonteCarloEmbedder};
use crate::functions::{Function1D, Sine};
use crate::hashing::PStableHashBank;
use crate::json::{self, Value};
use crate::server::{protocol, run_load, Client, LoadConfig, Server, WireMode};
use crate::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// Options of one `bench-wire` run.
pub struct WireBenchOptions {
    /// the CI smoke grid (fewer ops per case; same dims — the dim ≥ 256
    /// rows are the acceptance evidence)
    pub quick: bool,
}

fn boot(dim: usize) -> (Server, Vec<f64>) {
    let mut cfg = ServiceConfig {
        dim,
        k: 4,
        l: 8,
        workers: 4,
        max_batch: 128,
        max_wait_us: 200,
        queue_depth: 4096,
        ..Default::default()
    };
    cfg.server.port = 0;
    cfg.server.max_conns = 16;
    let mut rng = Xoshiro256pp::seed_from_u64(0xB1A5 ^ dim as u64);
    let emb = MonteCarloEmbedder::new(Interval::unit(), dim, 2.0, &mut rng);
    let points = emb.sample_points().to_vec();
    let bank = PStableHashBank::new(dim, cfg.total_hashes(), 2.0, cfg.r, &mut rng);
    let path: Arc<dyn HashPath> = Arc::new(CpuHashPath::new(Box::new(emb), Box::new(bank)));
    let svc = Arc::new(Coordinator::start(&cfg, path));
    let server = Server::start(&cfg, svc, points.clone()).expect("bind loopback");
    (server, points)
}

fn finish(server: Server) {
    let (svc, _) = server.shutdown();
    if let Ok(svc) = Arc::try_unwrap(svc) {
        svc.shutdown();
    }
}

fn sample_row(points: &[f64]) -> Vec<f32> {
    let f = Sine::paper(0.37);
    points.iter().map(|&x| f.eval(x) as f32).collect()
}

/// Median of one stage from a `stats detail=summary` rollup, in ns
/// (0 when the stage never ran or the server doesn't trace).
fn stage_p50_ns(summary: &Value, stage: &str) -> f64 {
    summary
        .get("stages")
        .and_then(|s| s.get(stage))
        .and_then(|s| s.get("p50_ns"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0)
}

/// The batch axis of the grid (1 = single-op frames, the baseline the
/// batched rows are compared against).
pub const BATCH_GRID: [usize; 3] = [1, 16, 256];

/// Run the wire grid and return the JSON report.
pub fn run(opts: &WireBenchOptions) -> Value {
    let dims: &[usize] = &[64, 256, 1024];
    let (threads, ops) = if opts.quick { (4usize, 512usize) } else { (8, 2048) };
    let mut cases = Vec::new();
    let mut speedups = Vec::new();
    println!("== bench-wire: json vs binary loopback throughput (rows/frame grid) ==");
    for &dim in dims {
        // throughput[wire][batch] for the speedup summary
        let mut tput = [[0.0f64; BATCH_GRID.len()]; 2];
        for (wi, wire) in [WireMode::Json, WireMode::Binary].into_iter().enumerate() {
            for (bi, &batch) in BATCH_GRID.iter().enumerate() {
                let (server, points) = boot(dim);
                let load = LoadConfig {
                    threads,
                    ops_per_thread: ops,
                    pipeline_depth: 8,
                    batch,
                    wire,
                    // hash-heavy mix: the row payload dominates the
                    // frame, which is the cost the binary format exists
                    // to cut (and the batch ops exist to amortize)
                    insert_fraction: 0.2,
                    query_fraction: 0.2,
                    k: 10,
                    seed: 0xB1A5,
                    ..Default::default()
                };
                let report = run_load(server.addr(), &points, &load).expect("load run");
                // server-side stage medians for this cell: where did the
                // wall time of this (dim, wire, batch) shape actually go?
                let summary = Client::connect(server.addr())
                    .and_then(|mut c| c.stats(StatsDetail::Summary))
                    .expect("stats summary");
                let row = sample_row(&points);
                // exact wire cost of a hash frame at this batch size
                let frame_bytes = if batch == 1 {
                    protocol::encode_hash_frame(wire, Some(1), &row).len()
                } else {
                    let rows: Vec<f32> =
                        row.iter().copied().cycle().take(batch * dim).collect();
                    protocol::encode_hash_batch_frame(wire, Some(1), &rows, dim).len()
                };
                println!(
                    "   wire/{}/dim={dim}/batch={}: {:.0} op/s, p50 {:.3} ms, \
                     p99 {:.3} ms, hash frame {} B ({} B/row), {} errors",
                    report.wire.as_str(),
                    report.batch,
                    report.throughput(),
                    report.latency_p50_s * 1e3,
                    report.latency_p99_s * 1e3,
                    frame_bytes,
                    frame_bytes / batch,
                    report.errors
                );
                tput[wi][bi] = report.throughput();
                // self-describing rows: the negotiated wire mode, batch
                // size, and pipeline depth come from the load report
                // itself, the io_mode from the server that ran
                cases.push(json::object(vec![
                    ("dim", dim.into()),
                    ("wire", report.wire.as_str().into()),
                    ("batch", report.batch.into()),
                    ("io_mode", server.io_mode().as_str().into()),
                    ("pipeline_depth", report.pipeline_depth.into()),
                    ("threads", threads.into()),
                    ("ops", report.ops.into()),
                    ("errors", report.errors.into()),
                    ("throughput_ops_s", report.throughput().into()),
                    ("latency_p50_s", report.latency_p50_s.into()),
                    ("latency_p99_s", report.latency_p99_s.into()),
                    ("hash_frame_bytes", frame_bytes.into()),
                    ("hash_frame_bytes_per_row", (frame_bytes / batch).into()),
                    ("stage_decode_p50_ns", stage_p50_ns(&summary, "decode").into()),
                    (
                        "stage_queue_wait_p50_ns",
                        stage_p50_ns(&summary, "queue_wait").into(),
                    ),
                    ("stage_kernel_p50_ns", stage_p50_ns(&summary, "kernel").into()),
                    ("stage_encode_p50_ns", stage_p50_ns(&summary, "encode").into()),
                ]));
                finish(server);
            }
        }
        let last = BATCH_GRID.len() - 1;
        speedups.push(json::object(vec![
            ("dim", dim.into()),
            (
                "binary_over_json_batch1",
                (tput[1][0] / tput[0][0].max(1e-9)).into(),
            ),
            (
                "json_batched_over_single",
                (tput[0][last] / tput[0][0].max(1e-9)).into(),
            ),
            (
                "binary_batched_over_single",
                (tput[1][last] / tput[1][0].max(1e-9)).into(),
            ),
        ]));
    }
    json::object(vec![
        ("bench", "wire_throughput".into()),
        ("mode", if opts.quick { "quick" } else { "full" }.into()),
        (
            "batch_grid",
            Value::Array(BATCH_GRID.iter().map(|&b| b.into()).collect()),
        ),
        ("cases", Value::Array(cases)),
        ("speedup", Value::Array(speedups)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_frame_sizes_favor_binary_at_high_dim() {
        // the static part of the acceptance criterion, without booting
        // servers: binary hash frames shrink the wire payload several-fold
        // at dim ≥ 256
        for dim in [64usize, 256, 1024] {
            let row: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
            let j = protocol::encode_hash_frame(WireMode::Json, Some(1), &row).len();
            let b = protocol::encode_hash_frame(WireMode::Binary, Some(1), &row).len();
            assert!(b < j, "dim {dim}: binary {b} B vs json {j} B");
            if dim >= 256 {
                assert!(b * 2 < j, "dim {dim}: binary {b} B should be <50% of json {j} B");
            }
        }
    }

    #[test]
    fn batched_frames_amortize_per_row_overhead() {
        // the static part of the batch acceptance: a hash_batch frame
        // costs strictly less per row than N single hash frames, in
        // both formats, at every grid batch size > 1
        let dim = 256usize;
        let row: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        for wire in [WireMode::Json, WireMode::Binary] {
            let single = protocol::encode_hash_frame(wire, Some(1), &row).len();
            for &batch in &BATCH_GRID[1..] {
                let rows: Vec<f32> = row.iter().copied().cycle().take(batch * dim).collect();
                let frame = protocol::encode_hash_batch_frame(wire, Some(1), &rows, dim).len();
                assert!(
                    frame < batch * single,
                    "{wire:?} batch {batch}: {frame} B >= {batch}x{single} B"
                );
            }
        }
    }
}
