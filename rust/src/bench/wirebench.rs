//! The `bench-wire` grid: JSON vs `FBIN1` binary loopback throughput of
//! the serving layer at dim ∈ {64, 256, 1024} × batch ∈ {1, 16, 256},
//! recorded as the second JSON trajectory file (`BENCH_wire.json`) so
//! later PRs have wire numbers to regress against.
//!
//! For each (dim, wire, batch) cell the grid boots one server on an
//! ephemeral loopback port, drives it with the pipelined load generator
//! (hash-heavy mix — sample rows dominate the wire cost, which is what
//! the binary format exists to cut; `batch` rows per frame, which is
//! what the batched ops exist to amortize), and records throughput,
//! latency percentiles, and the exact frame size of a `hash`/
//! `hash_batch` op in each format. Every JSON row is self-describing:
//! it carries the *negotiated* wire mode and batch size straight from
//! the load report, plus the serving io_mode, so `BENCH_wire.json`
//! trajectories can be compared across PRs without reconstructing the
//! grid loops. Each row also carries the pure framing overhead of its
//! wire format (newline vs `u32` length prefix, via
//! [`protocol::frame_overhead_bytes`]) so payload and framing cost can
//! be regressed separately.
//!
//! The grid is followed by one *latency-under-overload* row: a server
//! booted with deliberately tight in-flight byte budgets is probed for
//! its closed-loop sustainable rate, then driven open-loop at 4x that
//! rate (`LoadConfig::rate`). The row records typed sheds (client- and
//! server-side counts), the p99 of admitted ops (send-lag billed, so
//! coordinated omission cannot hide queueing), and process RSS around
//! the run — the evidence that admission control degrades gracefully
//! instead of falling over. `funclsh bench-wire [--quick]
//! [--require-shed] [--out F]` runs it; CI's `bench-smoke` and
//! `overload-smoke` jobs upload the artifact alongside
//! `BENCH_hashpath.json`.

use crate::config::ServiceConfig;
use crate::coordinator::{Coordinator, CpuHashPath, HashPath, StatsDetail};
use crate::embedding::{Embedder, Interval, MonteCarloEmbedder};
use crate::functions::{Function1D, Sine};
use crate::hashing::PStableHashBank;
use crate::json::{self, Value};
use crate::server::{protocol, run_load, Client, LoadConfig, Server, WireMode};
use crate::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// Options of one `bench-wire` run.
pub struct WireBenchOptions {
    /// the CI smoke grid (fewer ops per case; same dims — the dim ≥ 256
    /// rows are the acceptance evidence)
    pub quick: bool,
    /// fail the run (`funclsh bench-wire` exits 1) when the overload
    /// row records zero sheds — CI's graceful-degradation gate: a
    /// saturating run that never trips admission control means the
    /// budgets are not actually bounding anything
    pub require_shed: bool,
}

fn boot(dim: usize) -> (Server, Vec<f64>) {
    boot_limited(dim, None)
}

/// [`boot`] with optional `(per_conn, global)` in-flight byte budgets —
/// the overload row shrinks them far below the defaults so a pipelined
/// loopback burst deterministically trips admission control.
fn boot_limited(dim: usize, limits: Option<(usize, usize)>) -> (Server, Vec<f64>) {
    let mut cfg = ServiceConfig {
        dim,
        k: 4,
        l: 8,
        workers: 4,
        max_batch: 128,
        max_wait_us: 200,
        queue_depth: 4096,
        ..Default::default()
    };
    cfg.server.port = 0;
    cfg.server.max_conns = 16;
    if let Some((per_conn, global)) = limits {
        cfg.server.max_inflight_bytes_per_conn = per_conn;
        cfg.server.max_inflight_bytes = global;
    }
    let mut rng = Xoshiro256pp::seed_from_u64(0xB1A5 ^ dim as u64);
    let emb = MonteCarloEmbedder::new(Interval::unit(), dim, 2.0, &mut rng);
    let points = emb.sample_points().to_vec();
    let bank = PStableHashBank::new(dim, cfg.total_hashes(), 2.0, cfg.r, &mut rng);
    let path: Arc<dyn HashPath> = Arc::new(CpuHashPath::new(Box::new(emb), Box::new(bank)));
    let svc = Arc::new(Coordinator::start(&cfg, path));
    let server = Server::start(&cfg, svc, points.clone()).expect("bind loopback");
    (server, points)
}

fn finish(server: Server) {
    let (svc, _) = server.shutdown();
    if let Ok(svc) = Arc::try_unwrap(svc) {
        svc.shutdown();
    }
}

fn sample_row(points: &[f64]) -> Vec<f32> {
    let f = Sine::paper(0.37);
    points.iter().map(|&x| f.eval(x) as f32).collect()
}

/// Median of one stage from a `stats detail=summary` rollup, in ns
/// (0 when the stage never ran or the server doesn't trace).
fn stage_p50_ns(summary: &Value, stage: &str) -> f64 {
    summary
        .get("stages")
        .and_then(|s| s.get(stage))
        .and_then(|s| s.get("p50_ns"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0)
}

/// The batch axis of the grid (1 = single-op frames, the baseline the
/// batched rows are compared against).
pub const BATCH_GRID: [usize; 3] = [1, 16, 256];

/// Resident set size of this process in KiB (`VmRSS` from
/// `/proc/self/status`); `None` off Linux. The loopback server runs in
/// this process, so the figure bounds client *and* server together —
/// exactly the thing a memory-bloat regression would inflate.
fn rss_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// The latency-under-overload row: boot a server with deliberately
/// tight in-flight byte budgets, probe its closed-loop sustainable
/// rate, then drive it open-loop at 4x that rate and record how it
/// degrades — typed sheds (client- and server-side counts), bounded
/// p99 over the ops it did admit (send lag billed, so coordinated
/// omission cannot flatter the tail), and process RSS around the run.
fn overload_case(opts: &WireBenchOptions) -> Value {
    use crate::coordinator::metrics::{u64_value, value_u64};
    let dim = 256usize;
    let (threads, ops) = if opts.quick { (4usize, 256usize) } else { (8, 1024) };
    // budgets sized well below one 32-deep burst of ~1 KiB binary hash
    // frames: a saturating client overruns the per-conn budget inside a
    // single read batch, so admission control engages deterministically
    let per_conn = 8usize << 10;
    let global = 32usize << 10;
    let (server, points) = boot_limited(dim, Some((per_conn, global)));
    let base = LoadConfig {
        threads,
        ops_per_thread: ops,
        pipeline_depth: 2,
        batch: 1,
        wire: WireMode::Binary,
        insert_fraction: 0.2,
        query_fraction: 0.2,
        k: 10,
        seed: 0x0AD1,
        ..Default::default()
    };
    // closed-loop probe at shallow depth: the rate the server sustains
    // without backpressure — the baseline "4x" is measured against
    let probe = run_load(server.addr(), &points, &base).expect("overload probe run");
    let sustainable = probe.throughput();
    let rss_before = rss_kib();
    let open = LoadConfig {
        pipeline_depth: 32,
        rate: sustainable * 4.0,
        seed: 0x0AD2,
        ..base.clone()
    };
    let report = run_load(server.addr(), &points, &open).expect("overload run");
    let rss_after = rss_kib();
    // server-side confirmation that the refusals were admission control
    // (and not, say, protocol errors miscounted client-side)
    let server_sheds = Client::connect(server.addr())
        .and_then(|mut c| c.metrics())
        .ok()
        .and_then(|m| m.get("overload_sheds").and_then(value_u64))
        .unwrap_or(0);
    let io_mode = server.io_mode().as_str();
    finish(server);
    println!(
        "   overload/dim={dim}: sustainable {:.0} op/s, open loop at {:.0} op/s -> \
         {:.0} op/s admitted, {} sheds ({} server-side), {} errors, p99 {:.3} ms",
        sustainable,
        report.target_rate_ops_s,
        report.throughput(),
        report.sheds,
        server_sheds,
        report.errors,
        report.latency_p99_s * 1e3
    );
    let mut fields = vec![
        ("dim", dim.into()),
        ("wire", report.wire.as_str().into()),
        ("io_mode", io_mode.into()),
        ("threads", threads.into()),
        ("ops", report.ops.into()),
        ("sustainable_ops_s", sustainable.into()),
        ("target_rate_ops_s", report.target_rate_ops_s.into()),
        ("achieved_ops_s", report.throughput().into()),
        ("sheds", report.sheds.into()),
        ("server_overload_sheds", u64_value(server_sheds)),
        ("errors", report.errors.into()),
        ("latency_p50_s", report.latency_p50_s.into()),
        ("latency_p99_s", report.latency_p99_s.into()),
        ("max_inflight_bytes_per_conn", per_conn.into()),
        ("max_inflight_bytes", global.into()),
    ];
    if let (Some(b), Some(a)) = (rss_before, rss_after) {
        fields.push(("rss_before_kib", u64_value(b)));
        fields.push(("rss_after_kib", u64_value(a)));
    }
    json::object(fields)
}

/// Run the wire grid and return the JSON report.
pub fn run(opts: &WireBenchOptions) -> Value {
    let dims: &[usize] = &[64, 256, 1024];
    let (threads, ops) = if opts.quick { (4usize, 512usize) } else { (8, 2048) };
    let mut cases = Vec::new();
    let mut speedups = Vec::new();
    println!("== bench-wire: json vs binary loopback throughput (rows/frame grid) ==");
    for &dim in dims {
        // throughput[wire][batch] for the speedup summary
        let mut tput = [[0.0f64; BATCH_GRID.len()]; 2];
        for (wi, wire) in [WireMode::Json, WireMode::Binary].into_iter().enumerate() {
            for (bi, &batch) in BATCH_GRID.iter().enumerate() {
                let (server, points) = boot(dim);
                let load = LoadConfig {
                    threads,
                    ops_per_thread: ops,
                    pipeline_depth: 8,
                    batch,
                    wire,
                    // hash-heavy mix: the row payload dominates the
                    // frame, which is the cost the binary format exists
                    // to cut (and the batch ops exist to amortize)
                    insert_fraction: 0.2,
                    query_fraction: 0.2,
                    k: 10,
                    seed: 0xB1A5,
                    ..Default::default()
                };
                let report = run_load(server.addr(), &points, &load).expect("load run");
                // server-side stage medians for this cell: where did the
                // wall time of this (dim, wire, batch) shape actually go?
                let summary = Client::connect(server.addr())
                    .and_then(|mut c| c.stats(StatsDetail::Summary))
                    .expect("stats summary");
                let row = sample_row(&points);
                // pure framing cost of this wire format (newline vs u32
                // length prefix), kept apart from the payload so the
                // two can be regressed separately; per-row it amortizes
                // across the batch
                let overhead = protocol::frame_overhead_bytes(wire);
                // exact wire cost of a hash frame at this batch size
                let frame_bytes = if batch == 1 {
                    protocol::encode_hash_frame(wire, Some(1), &row).len()
                } else {
                    let rows: Vec<f32> =
                        row.iter().copied().cycle().take(batch * dim).collect();
                    protocol::encode_hash_batch_frame(wire, Some(1), &rows, dim).len()
                };
                println!(
                    "   wire/{}/dim={dim}/batch={}: {:.0} op/s, p50 {:.3} ms, \
                     p99 {:.3} ms, hash frame {} B ({} B/row, {} B framing), {} errors",
                    report.wire.as_str(),
                    report.batch,
                    report.throughput(),
                    report.latency_p50_s * 1e3,
                    report.latency_p99_s * 1e3,
                    frame_bytes,
                    frame_bytes / batch,
                    overhead,
                    report.errors
                );
                tput[wi][bi] = report.throughput();
                // self-describing rows: the negotiated wire mode, batch
                // size, and pipeline depth come from the load report
                // itself, the io_mode from the server that ran
                cases.push(json::object(vec![
                    ("dim", dim.into()),
                    ("wire", report.wire.as_str().into()),
                    ("batch", report.batch.into()),
                    ("io_mode", server.io_mode().as_str().into()),
                    ("pipeline_depth", report.pipeline_depth.into()),
                    ("threads", threads.into()),
                    ("ops", report.ops.into()),
                    ("errors", report.errors.into()),
                    ("throughput_ops_s", report.throughput().into()),
                    ("latency_p50_s", report.latency_p50_s.into()),
                    ("latency_p99_s", report.latency_p99_s.into()),
                    ("hash_frame_bytes", frame_bytes.into()),
                    ("hash_frame_bytes_per_row", (frame_bytes / batch).into()),
                    ("frame_overhead_bytes", overhead.into()),
                    (
                        "framing_overhead_bytes_per_row",
                        (overhead as f64 / batch as f64).into(),
                    ),
                    ("stage_decode_p50_ns", stage_p50_ns(&summary, "decode").into()),
                    (
                        "stage_queue_wait_p50_ns",
                        stage_p50_ns(&summary, "queue_wait").into(),
                    ),
                    ("stage_kernel_p50_ns", stage_p50_ns(&summary, "kernel").into()),
                    ("stage_encode_p50_ns", stage_p50_ns(&summary, "encode").into()),
                ]));
                finish(server);
            }
        }
        let last = BATCH_GRID.len() - 1;
        speedups.push(json::object(vec![
            ("dim", dim.into()),
            (
                "binary_over_json_batch1",
                (tput[1][0] / tput[0][0].max(1e-9)).into(),
            ),
            (
                "json_batched_over_single",
                (tput[0][last] / tput[0][0].max(1e-9)).into(),
            ),
            (
                "binary_batched_over_single",
                (tput[1][last] / tput[1][0].max(1e-9)).into(),
            ),
        ]));
    }
    println!("== bench-wire: latency under overload (open loop at 4x sustainable) ==");
    let overload = overload_case(opts);
    json::object(vec![
        ("bench", "wire_throughput".into()),
        ("mode", if opts.quick { "quick" } else { "full" }.into()),
        (
            "batch_grid",
            Value::Array(BATCH_GRID.iter().map(|&b| b.into()).collect()),
        ),
        ("cases", Value::Array(cases)),
        ("speedup", Value::Array(speedups)),
        ("overload", overload),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_frame_sizes_favor_binary_at_high_dim() {
        // the static part of the acceptance criterion, without booting
        // servers: binary hash frames shrink the wire payload several-fold
        // at dim ≥ 256
        for dim in [64usize, 256, 1024] {
            let row: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
            let j = protocol::encode_hash_frame(WireMode::Json, Some(1), &row).len();
            let b = protocol::encode_hash_frame(WireMode::Binary, Some(1), &row).len();
            assert!(b < j, "dim {dim}: binary {b} B vs json {j} B");
            if dim >= 256 {
                assert!(b * 2 < j, "dim {dim}: binary {b} B should be <50% of json {j} B");
            }
        }
    }

    #[test]
    fn batched_frames_amortize_per_row_overhead() {
        // the static part of the batch acceptance: a hash_batch frame
        // costs strictly less per row than N single hash frames, in
        // both formats, at every grid batch size > 1
        let dim = 256usize;
        let row: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        for wire in [WireMode::Json, WireMode::Binary] {
            let single = protocol::encode_hash_frame(wire, Some(1), &row).len();
            for &batch in &BATCH_GRID[1..] {
                let rows: Vec<f32> = row.iter().copied().cycle().take(batch * dim).collect();
                let frame = protocol::encode_hash_batch_frame(wire, Some(1), &rows, dim).len();
                assert!(
                    frame < batch * single,
                    "{wire:?} batch {batch}: {frame} B >= {batch}x{single} B"
                );
            }
        }
    }
}
