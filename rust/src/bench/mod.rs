//! A small criterion-style benchmark harness (the criterion crate is not
//! in the offline vendor set).
//!
//! Provides warmup, timed iterations, and robust summary statistics
//! (mean / p50 / p99 / min), plus throughput reporting and CSV/JSON emit.
//! All `cargo bench` targets in `rust/benches/` are built on this.

pub mod hashbench;
pub mod observebench;
pub mod wirebench;

use crate::util::stats::quantile_sorted;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// case name
    pub name: String,
    /// number of timed iterations
    pub iters: u64,
    /// mean time per iteration
    pub mean: Duration,
    /// median time per iteration
    pub p50: Duration,
    /// 99th-percentile time per iteration
    pub p99: Duration,
    /// fastest iteration
    pub min: Duration,
    /// optional items-per-iteration for throughput reporting
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Items per second (if `items_per_iter` was set).
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|items| items / self.mean.as_secs_f64())
    }

    /// One human-readable summary line.
    pub fn line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>10.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:>10.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>12?}  p50 {:>12?}  p99 {:>12?}  min {:>12?}{}",
            self.name, self.mean, self.p50, self.p99, self.min, tp
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// warmup duration before timing
    pub warmup: Duration,
    /// target measurement duration
    pub measure: Duration,
    /// hard cap on timed iterations
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 1_000_000,
        }
    }
}

/// A benchmark suite: runs cases, collects results, prints a report.
pub struct Bench {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Suite with default config (honours `FUNCLSH_BENCH_FAST=1` for CI:
    /// 50 ms warmup / 200 ms measure).
    pub fn new() -> Self {
        let mut config = BenchConfig::default();
        if std::env::var("FUNCLSH_BENCH_FAST").as_deref() == Ok("1") {
            config.warmup = Duration::from_millis(50);
            config.measure = Duration::from_millis(200);
        }
        Self {
            config,
            results: Vec::new(),
        }
    }

    /// Suite with explicit config.
    pub fn with_config(config: BenchConfig) -> Self {
        Self {
            config,
            results: Vec::new(),
        }
    }

    /// Run a case; `f` is one iteration. Use `std::hint::black_box` inside
    /// `f` on inputs/outputs to defeat the optimizer.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.case_with_items(name, None, &mut f)
    }

    /// Run a throughput case: `items` is the number of logical items each
    /// iteration processes (e.g. batch size).
    pub fn throughput_case<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &BenchResult {
        self.case_with_items(name, Some(items), &mut f)
    }

    fn case_with_items(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // warmup
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.config.warmup {
            f();
            warm_iters += 1;
        }
        // choose a per-sample batch so each sample is ≥ ~20µs, keeping
        // timer overhead below 1%.
        let est = self.config.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let batch = ((20e-6 / est.max(1e-12)).ceil() as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let mut total_iters = 0u64;
        let measure_start = Instant::now();
        while measure_start.elapsed() < self.config.measure && total_iters < self.config.max_iters
        {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let el = t.elapsed().as_secs_f64() / batch as f64;
            samples.push(el);
            total_iters += batch;
        }
        samples.sort_by(f64::total_cmp);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(quantile_sorted(&samples, 0.5)),
            p99: Duration::from_secs_f64(quantile_sorted(&samples, 0.99)),
            min: Duration::from_secs_f64(samples[0]),
            items_per_iter: items,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render results as CSV (`name,iters,mean_ns,p50_ns,p99_ns,min_ns,throughput`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,iters,mean_ns,p50_ns,p99_ns,min_ns,items_per_sec\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.name,
                r.iters,
                r.mean.as_nanos(),
                r.p50.as_nanos(),
                r.p99.as_nanos(),
                r.min.as_nanos(),
                r.throughput().map(|t| format!("{t:.1}")).unwrap_or_default()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_config() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_iters: 100_000,
        }
    }

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::with_config(fast_config());
        let mut acc = 0u64;
        let r = b.case("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(17));
        });
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bench::with_config(fast_config());
        let r = b.throughput_case("batch", 128.0, || {
            std::hint::black_box((0..64).sum::<u64>());
        });
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn csv_has_rows() {
        let mut b = Bench::with_config(fast_config());
        b.case("a", || {
            std::hint::black_box(1 + 1);
        });
        let csv = b.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("name,"));
    }
}
