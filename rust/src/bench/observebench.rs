//! The `bench-observe` run: what does per-request stage tracing cost?
//!
//! Boots pairs of loopback servers — tracing on vs `--no-trace` — and
//! drives both with identical pipelined batch-256 binary load (the
//! highest-throughput configuration the serving stack has, i.e. the one
//! where a fixed per-request overhead hurts the most *per frame* but is
//! amortized across the most rows). Each mode gets a fresh server per
//! trial so corpus growth never skews a comparison, trials alternate
//! modes so thermal/background drift hits both equally, and each mode's
//! best trial is compared (best-of-N is the standard anti-noise choice
//! for an A/B throughput gate).
//!
//! On top of the overhead number the traced side is reconciled:
//!
//! - the `decode` stage count must equal the ops the load generator got
//!   acked (every traced op stamps every stage, zeros included), and
//! - every slow-log entry's per-stage sum must cover ≥ 95% of its
//!   end-to-end time (the stamps partition the span's lifetime, so this
//!   holds by construction — the check guards the *plumbing*, e.g. a
//!   stage stamped twice or a span recorded before write-queued).
//!
//! `funclsh bench-observe [--quick] [--out F] [--max-overhead-pct F]`
//! writes `BENCH_observe.json`; CI's `observability-smoke` job runs it
//! with a gate and uploads the artifact.

use crate::config::ServiceConfig;
use crate::coordinator::metrics::value_u64;
use crate::coordinator::{Coordinator, CpuHashPath, HashPath, StatsDetail};
use crate::embedding::{Embedder, Interval, MonteCarloEmbedder};
use crate::hashing::PStableHashBank;
use crate::json::{self, Value};
use crate::server::{run_load, Client, LoadConfig, LoadReport, Server, WireMode};
use crate::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// Options of one `bench-observe` run.
pub struct ObserveBenchOptions {
    /// CI smoke sizing (fewer ops and trials; same batch-256 shape)
    pub quick: bool,
    /// fail the run when tracing costs more than this many percent of
    /// untraced throughput (infinite = report only)
    pub max_overhead_pct: f64,
}

/// Rows per frame in every load run: the grid's largest batch, where
/// per-row overhead is most amortized and a throughput delta is purest
/// fixed-cost signal.
pub const OBSERVE_BATCH: usize = 256;

fn boot(trace: bool) -> (Server, Vec<f64>) {
    let dim = 64usize;
    let mut cfg = ServiceConfig {
        dim,
        k: 4,
        l: 8,
        workers: 4,
        max_batch: 128,
        max_wait_us: 200,
        queue_depth: 4096,
        ..Default::default()
    };
    cfg.server.port = 0;
    cfg.server.trace = trace;
    let mut rng = Xoshiro256pp::seed_from_u64(0x0B5E);
    let emb = MonteCarloEmbedder::new(Interval::unit(), dim, 2.0, &mut rng);
    let points = emb.sample_points().to_vec();
    let bank = PStableHashBank::new(dim, cfg.total_hashes(), 2.0, cfg.r, &mut rng);
    let path: Arc<dyn HashPath> = Arc::new(CpuHashPath::new(Box::new(emb), Box::new(bank)));
    let svc = Arc::new(Coordinator::start(&cfg, path));
    svc.shared_metrics().set_tracing(cfg.server.trace);
    let server = Server::start(&cfg, svc, points.clone()).expect("bind loopback");
    (server, points)
}

fn finish(server: Server) {
    let (svc, _) = server.shutdown();
    if let Ok(svc) = Arc::try_unwrap(svc) {
        svc.shutdown();
    }
}

fn load_cfg(threads: usize, ops: usize) -> LoadConfig {
    LoadConfig {
        threads,
        ops_per_thread: ops,
        pipeline_depth: 8,
        batch: OBSERVE_BATCH,
        wire: WireMode::Binary,
        insert_fraction: 0.2,
        query_fraction: 0.2,
        k: 10,
        seed: 0x0B5E,
        ..Default::default()
    }
}

/// One fresh-server trial; returns the load report plus (for traced
/// servers) the post-run stats views needed for reconciliation.
fn trial(trace: bool, threads: usize, ops: usize) -> (LoadReport, Option<(Value, Value)>) {
    let (server, points) = boot(trace);
    let report = run_load(server.addr(), &points, &load_cfg(threads, ops)).expect("load run");
    let views = if trace {
        let mut c = Client::connect(server.addr()).expect("stats probe");
        let stages = c.stats(StatsDetail::Stages).expect("stats stages");
        let slow = c.stats(StatsDetail::Slow).expect("stats slow");
        Some((stages, slow))
    } else {
        None
    };
    finish(server);
    (report, views)
}

/// Total `decode` stage observations in a `stats detail=stages` reply —
/// the number of traced ops, since every traced op stamps every stage.
fn decode_count(stages: &Value) -> u64 {
    let Some(Value::Array(cells)) = stages.get("stages") else {
        return 0;
    };
    cells
        .iter()
        .filter(|c| c.get("stage").and_then(Value::as_str) == Some("decode"))
        .filter_map(|c| c.get("count").and_then(value_u64))
        .sum()
}

/// Worst-case stage-sum / total ratio across the slow log (1.0 when the
/// log is empty — nothing to falsify).
fn min_stage_sum_ratio(slow: &Value) -> f64 {
    let Some(Value::Array(entries)) = slow.get("slow") else {
        return 1.0;
    };
    let mut min = 1.0f64;
    for e in entries {
        let Some(total) = e.get("total_ns").and_then(value_u64) else {
            continue;
        };
        if total == 0 {
            continue;
        }
        let sum: u64 = match e.get("stages") {
            Some(Value::Object(stages)) => {
                stages.iter().filter_map(|(_, v)| value_u64(v)).sum()
            }
            _ => 0,
        };
        min = min.min(sum as f64 / total as f64);
    }
    min
}

/// Run the tracing-overhead comparison and return the JSON report.
pub fn run(opts: &ObserveBenchOptions) -> Value {
    let (threads, ops, trials) = if opts.quick {
        (4usize, 4 * OBSERVE_BATCH, 3usize)
    } else {
        (8, 16 * OBSERVE_BATCH, 5)
    };
    println!(
        "== bench-observe: tracing on vs off (binary wire, batch {OBSERVE_BATCH}, \
         {threads} threads x {ops} ops, best of {trials}) =="
    );

    let mut traced_best = 0.0f64;
    let mut untraced_best = 0.0f64;
    let mut traced_rows = Vec::new();
    let mut untraced_rows = Vec::new();
    let mut recon_ops_ok = true;
    let mut min_ratio = 1.0f64;
    for t in 0..trials {
        // alternate modes within each trial so slow drift (thermal,
        // background load) lands on both sides equally
        let (report, views) = trial(true, threads, ops);
        let (stages, slow) = views.expect("traced trial returns stats");
        let traced_ops = decode_count(&stages);
        // acked ops only: a rejected row is never traced
        let acked = (report.ops - report.errors) as u64;
        if traced_ops != acked {
            recon_ops_ok = false;
            println!("   !! trial {t}: traced {traced_ops} ops but load acked {acked}");
        }
        min_ratio = min_ratio.min(min_stage_sum_ratio(&slow));
        traced_best = traced_best.max(report.throughput());
        println!(
            "   trace=on  trial {t}: {:.0} op/s, p99 {:.3} ms, {} traced ops",
            report.throughput(),
            report.latency_p99_s * 1e3,
            traced_ops
        );
        traced_rows.push(report.throughput());

        let (report, _) = trial(false, threads, ops);
        untraced_best = untraced_best.max(report.throughput());
        println!(
            "   trace=off trial {t}: {:.0} op/s, p99 {:.3} ms",
            report.throughput(),
            report.latency_p99_s * 1e3
        );
        untraced_rows.push(report.throughput());
    }

    let overhead_pct = (1.0 - traced_best / untraced_best.max(1e-9)) * 100.0;
    println!(
        "   best traced {traced_best:.0} op/s vs untraced {untraced_best:.0} op/s \
         -> overhead {overhead_pct:.2}% (min stage-sum ratio {min_ratio:.4})"
    );
    json::object(vec![
        ("bench", "observe_overhead".into()),
        ("mode", if opts.quick { "quick" } else { "full" }.into()),
        ("wire", "binary".into()),
        ("batch", OBSERVE_BATCH.into()),
        ("threads", threads.into()),
        ("ops_per_thread", ops.into()),
        ("trials", trials.into()),
        (
            "traced_ops_s",
            Value::Array(traced_rows.iter().map(|&t| t.into()).collect()),
        ),
        (
            "untraced_ops_s",
            Value::Array(untraced_rows.iter().map(|&t| t.into()).collect()),
        ),
        ("traced_best_ops_s", traced_best.into()),
        ("untraced_best_ops_s", untraced_best.into()),
        ("overhead_pct", overhead_pct.into()),
        ("stage_counts_reconcile", recon_ops_ok.into()),
        ("min_stage_sum_ratio", min_ratio.into()),
        (
            "gate_max_overhead_pct",
            if opts.max_overhead_pct.is_finite() {
                opts.max_overhead_pct.into()
            } else {
                Value::Null
            },
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::object;

    #[test]
    fn decode_count_sums_across_kinds_and_wires() {
        let stages = object(vec![(
            "stages",
            Value::Array(vec![
                object(vec![("stage", "decode".into()), ("count", 3.0.into())]),
                object(vec![("stage", "decode".into()), ("count", 4.0.into())]),
                object(vec![("stage", "kernel".into()), ("count", 7.0.into())]),
            ]),
        )]);
        assert_eq!(decode_count(&stages), 7);
        assert_eq!(decode_count(&object(vec![])), 0);
    }

    #[test]
    fn stage_sum_ratio_flags_leaky_entries() {
        let entry = |total: f64, kernel: f64| {
            object(vec![
                ("total_ns", total.into()),
                ("stages", object(vec![("kernel", kernel.into())])),
            ])
        };
        // fully attributed entry: ratio 1
        let good = object(vec![("slow", Value::Array(vec![entry(1000.0, 1000.0)]))]);
        assert!((min_stage_sum_ratio(&good) - 1.0).abs() < 1e-12);
        // an entry whose stages only cover half its wall time
        let leaky = object(vec![(
            "slow",
            Value::Array(vec![entry(1000.0, 1000.0), entry(2000.0, 1000.0)]),
        )]);
        assert!((min_stage_sum_ratio(&leaky) - 0.5).abs() < 1e-12);
        // empty log: nothing to falsify
        assert_eq!(min_stage_sum_ratio(&object(vec![])), 1.0);
    }
}
