//! The `bench-hash` grid: seed-vs-new hot-path throughput, recorded as a
//! JSON trajectory file so later PRs have numbers to regress against.
//!
//! Two comparisons, both against *reimplementations of the seed code*
//! (kept verbatim here, so the baseline cannot silently improve):
//!
//! * **Kernel** — rows/s of the seed scalar f64 row-at-a-time matmul
//!   ([`FoldedHashPath::hash_rows_scalar`]) vs the blocked/threaded f32
//!   kernel ([`HashPath::hash_rows_into`]) across `{N, K, B}`, plus an
//!   A/B of the portable register tile against the AVX2 intrinsics tile
//!   (`set_simd`; the columns coincide without `--features simd`). Each
//!   case also records the narrowest signature storage width the shape
//!   admits under a ‖x‖∞ ≤ 1 input cap (`sig_width`).
//! * **Index** — inserts/s and (multi-probe) queries/s of the seed-era
//!   index model (`Box<[i32]>` keys under SipHash, `HashSet` dedup,
//!   allocating perturbation lists) vs the fingerprint-keyed
//!   [`LshIndex`] with reused [`QueryScratch`].
//!
//! `funclsh bench-hash [--quick] [--out F]` runs the grid and writes the
//! report (default `BENCH_hashpath.json`); `--quick` is the CI smoke
//! grid. Case lines stream to stdout as they finish.

use crate::bench::{Bench, BenchConfig};
use crate::coordinator::{FoldedHashPath, HashPath, Signatures};
use crate::embedding::{Interval, MonteCarloEmbedder};
use crate::hashing::PStableHashBank;
use crate::json::{self, Value};
use crate::lsh::{IndexConfig, LshIndex, QueryScratch};
use crate::util::rng::{Rng64, Xoshiro256pp};
use std::collections::{HashMap, HashSet};
use std::hint::black_box;

/// Options of one `bench-hash` run.
pub struct HashBenchOptions {
    /// the CI smoke grid (fewer shapes/batches); always includes the
    /// acceptance shape `N=256, K=128, B=64`
    pub quick: bool,
}

/// The seed `LshIndex`, reimplemented verbatim as the bench baseline:
/// `Box<[i32]>` bucket keys under the default SipHash, `HashSet`-deduped
/// queries, and clone-heavy multi-probe perturbation lists.
struct SeedIndex {
    k: usize,
    tables: Vec<HashMap<Box<[i32]>, Vec<u64>>>,
}

impl SeedIndex {
    fn new(k: usize, l: usize) -> Self {
        Self {
            k,
            tables: (0..l).map(|_| HashMap::new()).collect(),
        }
    }

    fn insert(&mut self, id: u64, signature: &[i32]) {
        for (table, key) in self.tables.iter_mut().zip(signature.chunks_exact(self.k)) {
            table.entry(key.into()).or_default().push(id);
        }
    }

    fn query(&self, signature: &[i32]) -> Vec<u64> {
        let mut seen = HashSet::new();
        for (table, key) in self.tables.iter().zip(signature.chunks_exact(self.k)) {
            if let Some(ids) = table.get(key) {
                seen.extend(ids.iter().copied());
            }
        }
        seen.into_iter().collect()
    }

    fn query_multiprobe(&self, signature: &[i32], depth: usize) -> Vec<u64> {
        let mut seen = HashSet::new();
        for (table, key) in self.tables.iter().zip(signature.chunks_exact(self.k)) {
            for probe in seed_perturbations(key, depth) {
                if let Some(ids) = table.get(probe.as_slice()) {
                    seen.extend(ids.iter().copied());
                }
            }
        }
        seen.into_iter().collect()
    }
}

/// The seed perturbation enumerator (allocates every probe key).
fn seed_perturbations(key: &[i32], depth: usize) -> Vec<Vec<i32>> {
    let mut out = vec![key.to_vec()];
    if depth == 0 {
        return out;
    }
    let mut frontier: Vec<(Vec<i32>, usize)> = vec![(key.to_vec(), 0)];
    for _ in 1..=depth.min(key.len()) {
        let mut next = Vec::new();
        for (base, start) in &frontier {
            for i in *start..key.len() {
                for delta in [-1i32, 1] {
                    let mut probe = base.clone();
                    probe[i] = probe[i].wrapping_add(delta);
                    out.push(probe.clone());
                    next.push((probe, i + 1));
                }
            }
        }
        frontier = next;
    }
    out
}

/// Seeded uniform sample rows in `[-1, 1]^n` — the shared input
/// generator for the grid and the `hash_throughput` bench target.
pub fn random_rows(n: usize, count: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..count)
        .map(|_| (0..n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect())
        .collect()
}

fn random_sigs(len: usize, count: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..count)
        .map(|_| (0..len).map(|_| rng.uniform_usize(9) as i32 - 4).collect())
        .collect()
}

/// Run the grid with the ambient bench config (honours
/// `FUNCLSH_BENCH_FAST=1`).
pub fn run(opts: &HashBenchOptions) -> Value {
    run_with_config(opts, None)
}

/// Run the grid with an explicit bench config (tests use a tiny one).
pub fn run_with_config(opts: &HashBenchOptions, config: Option<BenchConfig>) -> Value {
    let mut bench = match config {
        Some(c) => Bench::with_config(c),
        None => Bench::new(),
    };
    let kernel_shapes: &[(usize, usize)] = if opts.quick {
        &[(64, 32), (256, 128)]
    } else {
        &[(64, 32), (128, 64), (256, 128)]
    };
    let batches: &[usize] = if opts.quick { &[1, 64] } else { &[1, 16, 64, 256] };

    println!("== bench-hash: seed scalar vs blocked vs SIMD kernel (rows/s) ==");
    let simd_hw = crate::coordinator::simd_kernel_available();
    let mut kernel_cases = Vec::new();
    for &(n, k) in kernel_shapes {
        let mut rng = Xoshiro256pp::seed_from_u64(0xBE + n as u64);
        let emb = MonteCarloEmbedder::new(Interval::unit(), n, 2.0, &mut rng);
        let bank = PStableHashBank::new(n, k, 2.0, 1.0, &mut rng);
        let proj_rows: Vec<&[f64]> = (0..k).map(|j| bank.projection_row(j)).collect();
        let mut folded = FoldedHashPath::new(Box::new(emb), &proj_rows, bank.offsets(), bank.r());
        // the narrowest storage width this shape provably fits under the
        // generator's ‖x‖∞ ≤ 1 input cap (the service derives the same
        // bound from `[hash] norm_cap`)
        let sig_width = folded.sig_width(1.0);
        for &b in batches {
            let rows = random_rows(n, b, (n * 31 + b) as u64);
            let seed_rows = bench
                .throughput_case(&format!("kernel/seed-scalar/n{n}-k{k}-b{b}"), b as f64, || {
                    black_box(folded.hash_rows_scalar(black_box(&rows)).unwrap());
                })
                .throughput()
                .unwrap_or(0.0);
            let mut sigs = Signatures::new(k);
            // A/B the portable register tile against the intrinsics tile
            // on the same instance; without `--features simd` (or off
            // x86_64/AVX2) set_simd(true) is a no-op and the two columns
            // coincide.
            folded.set_simd(false);
            let blocked_rows = bench
                .throughput_case(&format!("kernel/blocked/n{n}-k{k}-b{b}"), b as f64, || {
                    folded
                        .hash_rows_into(black_box(&rows), &mut sigs)
                        .unwrap();
                    black_box(sigs.as_slice());
                })
                .throughput()
                .unwrap_or(0.0);
            folded.set_simd(true);
            let simd_rows = bench
                .throughput_case(&format!("kernel/simd/n{n}-k{k}-b{b}"), b as f64, || {
                    folded
                        .hash_rows_into(black_box(&rows), &mut sigs)
                        .unwrap();
                    black_box(sigs.as_slice());
                })
                .throughput()
                .unwrap_or(0.0);
            let speedup = if seed_rows > 0.0 { blocked_rows / seed_rows } else { 0.0 };
            let simd_speedup = if blocked_rows > 0.0 { simd_rows / blocked_rows } else { 0.0 };
            kernel_cases.push(json::object(vec![
                ("n", n.into()),
                ("k", k.into()),
                ("b", b.into()),
                ("seed_rows_per_s", seed_rows.into()),
                ("blocked_rows_per_s", blocked_rows.into()),
                ("kernel_speedup", speedup.into()),
                ("simd_active", simd_hw.into()),
                ("simd_rows_per_s", simd_rows.into()),
                ("simd_speedup", simd_speedup.into()),
                ("sig_width", sig_width.name().into()),
            ]));
        }
    }

    println!("== bench-hash: seed index vs fingerprint index (ops/s) ==");
    let idx_shapes: &[(usize, usize)] = if opts.quick {
        &[(4, 8)]
    } else {
        &[(2, 16), (4, 8), (8, 4)]
    };
    const ENTRIES: usize = 4096;
    const INSERT_BATCH: usize = 256;
    const QUERIES: usize = 64;
    let mut index_cases = Vec::new();
    for &(ka, l) in idx_shapes {
        let len = ka * l;
        let sigs = random_sigs(len, ENTRIES, 0x1D + len as u64);
        let ins = &sigs[..INSERT_BATCH];
        let seed_ins = bench
            .throughput_case(
                &format!("index/seed-insert/k{ka}-l{l}"),
                INSERT_BATCH as f64,
                || {
                    let mut idx = SeedIndex::new(ka, l);
                    for (i, s) in ins.iter().enumerate() {
                        idx.insert(i as u64, s);
                    }
                    black_box(idx.tables.len());
                },
            )
            .throughput()
            .unwrap_or(0.0);
        let fp_ins = bench
            .throughput_case(
                &format!("index/fp-insert/k{ka}-l{l}"),
                INSERT_BATCH as f64,
                || {
                    let mut idx = LshIndex::new(IndexConfig::new(ka, l));
                    for (i, s) in ins.iter().enumerate() {
                        idx.insert(i as u64, s);
                    }
                    black_box(idx.len());
                },
            )
            .throughput()
            .unwrap_or(0.0);

        let mut seed_idx = SeedIndex::new(ka, l);
        let mut fp_idx = LshIndex::new(IndexConfig::new(ka, l));
        for (i, s) in sigs.iter().enumerate() {
            seed_idx.insert(i as u64, s);
            fp_idx.insert(i as u64, s);
        }
        let qs = &sigs[..QUERIES];
        let seed_q = bench
            .throughput_case(&format!("index/seed-query/k{ka}-l{l}"), QUERIES as f64, || {
                for s in qs {
                    black_box(seed_idx.query(black_box(s)));
                }
            })
            .throughput()
            .unwrap_or(0.0);
        let mut scratch = QueryScratch::default();
        let mut out = Vec::new();
        let fp_q = bench
            .throughput_case(&format!("index/fp-query/k{ka}-l{l}"), QUERIES as f64, || {
                for s in qs {
                    fp_idx.query_into(black_box(s), 0, &mut scratch, &mut out);
                    black_box(out.len());
                }
            })
            .throughput()
            .unwrap_or(0.0);
        let seed_mp = bench
            .throughput_case(
                &format!("index/seed-multiprobe1/k{ka}-l{l}"),
                QUERIES as f64,
                || {
                    for s in qs {
                        black_box(seed_idx.query_multiprobe(black_box(s), 1));
                    }
                },
            )
            .throughput()
            .unwrap_or(0.0);
        let fp_mp = bench
            .throughput_case(
                &format!("index/fp-multiprobe1/k{ka}-l{l}"),
                QUERIES as f64,
                || {
                    for s in qs {
                        fp_idx.query_into(black_box(s), 1, &mut scratch, &mut out);
                        black_box(out.len());
                    }
                },
            )
            .throughput()
            .unwrap_or(0.0);
        index_cases.push(json::object(vec![
            ("k", ka.into()),
            ("l", l.into()),
            ("entries", ENTRIES.into()),
            ("seed_insert_per_s", seed_ins.into()),
            ("fp_insert_per_s", fp_ins.into()),
            ("seed_query_per_s", seed_q.into()),
            ("fp_query_per_s", fp_q.into()),
            ("seed_multiprobe_per_s", seed_mp.into()),
            ("fp_multiprobe_per_s", fp_mp.into()),
        ]));
    }

    json::object(vec![
        ("bench", "hash_throughput".into()),
        ("mode", if opts.quick { "quick" } else { "full" }.into()),
        ("kernel_cases", Value::Array(kernel_cases)),
        ("index_cases", Value::Array(index_cases)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn quick_grid_covers_acceptance_shape_and_serializes() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            max_iters: 10_000,
        };
        let report = run_with_config(&HashBenchOptions { quick: true }, Some(cfg));
        let text = report.to_json();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("mode").and_then(Value::as_str), Some("quick"));
        let kernel = back.get("kernel_cases").and_then(Value::as_array).unwrap();
        assert!(
            kernel.iter().any(|c| {
                c.get("n").and_then(Value::as_usize) == Some(256)
                    && c.get("k").and_then(Value::as_usize) == Some(128)
                    && c.get("b").and_then(Value::as_usize) == Some(64)
            }),
            "acceptance shape N=256 K=128 B=64 missing: {text}"
        );
        for c in kernel {
            assert!(c.get("seed_rows_per_s").and_then(Value::as_f64).unwrap() > 0.0);
            assert!(c.get("blocked_rows_per_s").and_then(Value::as_f64).unwrap() > 0.0);
            assert!(c.get("simd_rows_per_s").and_then(Value::as_f64).unwrap() > 0.0);
            assert!(c.get("simd_active").is_some(), "simd_active column missing");
            let w = c.get("sig_width").and_then(Value::as_str).unwrap();
            assert!(matches!(w, "i8" | "i16" | "i32"), "bad sig_width {w}");
        }
        let index = back.get("index_cases").and_then(Value::as_array).unwrap();
        assert!(!index.is_empty());
    }

    #[test]
    fn seed_index_model_agrees_with_fingerprint_index() {
        // the baseline must measure the same *semantics* it is compared
        // against: identical candidate sets on identical contents
        let sigs = random_sigs(8, 200, 7);
        let mut seed = SeedIndex::new(2, 4);
        let mut fp = LshIndex::new(IndexConfig::new(2, 4));
        for (i, s) in sigs.iter().enumerate() {
            seed.insert(i as u64, s);
            fp.insert(i as u64, s);
        }
        for s in sigs.iter().take(40) {
            let mut a = seed.query(s);
            a.sort_unstable();
            assert_eq!(a, fp.query(s));
            let mut am = seed.query_multiprobe(s, 1);
            am.sort_unstable();
            assert_eq!(am, fp.query_multiprobe(s, 1));
        }
    }
}
