//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Python runs **once** at build time (`make artifacts`); this module is
//! the only thing touching the compiled pipelines afterwards:
//!
//! ```text
//! artifacts/manifest.json          → [`Manifest`]
//! artifacts/<pipeline>.hlo.txt     → HloModuleProto::from_text_file
//!                                  → XlaComputation → client.compile
//!                                  → [`Pipeline::execute`]
//! ```
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod pjrt_path;

use crate::json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Static description of one compiled pipeline, read from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    /// pipeline name (e.g. `mc_l2_hash`)
    pub name: String,
    /// HLO text file, relative to the artifacts dir
    pub file: String,
    /// fixed batch size `B` the pipeline was lowered with
    pub batch: usize,
    /// embedding dimension `N`
    pub dim: usize,
    /// number of hash functions `K`
    pub k: usize,
    /// names of the runtime inputs, in call order
    pub inputs: Vec<String>,
}

/// The artifact manifest (`artifacts/manifest.json`).
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// pipelines by name
    pub pipelines: Vec<PipelineSpec>,
}

impl Manifest {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arr = v
            .get("pipelines")
            .and_then(|p| p.as_array())
            .ok_or_else(|| anyhow!("manifest: missing `pipelines` array"))?;
        let mut pipelines = Vec::new();
        for p in arr {
            let field = |k: &str| {
                p.get(k)
                    .ok_or_else(|| anyhow!("manifest pipeline: missing `{k}`"))
            };
            pipelines.push(PipelineSpec {
                name: field("name")?
                    .as_str()
                    .ok_or_else(|| anyhow!("`name` must be a string"))?
                    .to_string(),
                file: field("file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("`file` must be a string"))?
                    .to_string(),
                batch: field("batch")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("`batch` must be an integer"))?,
                dim: field("dim")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("`dim` must be an integer"))?,
                k: field("k")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("`k` must be an integer"))?,
                inputs: field("inputs")?
                    .as_array()
                    .ok_or_else(|| anyhow!("`inputs` must be an array"))?
                    .iter()
                    .map(|s| s.as_str().unwrap_or_default().to_string())
                    .collect(),
            });
        }
        Ok(Self { pipelines })
    }

    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Find a pipeline spec by name.
    pub fn find(&self, name: &str) -> Option<&PipelineSpec> {
        self.pipelines.iter().find(|p| p.name == name)
    }
}

/// A compiled, executable pipeline.
pub struct Pipeline {
    /// the static spec from the manifest
    pub spec: PipelineSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Pipeline {
    /// Execute with raw literals (advanced use; most callers want
    /// [`Pipeline::hash_batch`]).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("pjrt execute: {e}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("pjrt readback: {e}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))
    }

    /// Run the embed→hash pipeline on a full batch of `B` sample rows.
    ///
    /// `samples` is row-major `[B][N]` f32; `proj` is `[N][K]` (already
    /// folded with embedding scale and `1/r`); `offsets` is `[K]`.
    /// Returns row-major `[B][K]` i32 signatures.
    pub fn hash_batch(
        &self,
        samples: &[f32],
        proj: &xla::Literal,
        offsets: &xla::Literal,
    ) -> Result<Vec<i32>> {
        let b = self.spec.batch;
        let n = self.spec.dim;
        if samples.len() != b * n {
            bail!(
                "batch shape mismatch: got {} values, expected {}x{}",
                samples.len(),
                b,
                n
            );
        }
        let x = xla::Literal::vec1(samples)
            .reshape(&[b as i64, n as i64])
            .map_err(|e| anyhow!("reshape: {e}"))?;
        // manifest input order: samples, proj, offsets
        let out = self.execute(&[x, clone_literal(proj)?, clone_literal(offsets)?])?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))
    }
}

/// The xla crate's `Literal` has no public `Clone`; reshape to the same
/// dims as a cheap copy.
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    l.reshape(&dims).map_err(|e| anyhow!("clone: {e}"))
}

/// The PJRT engine: one CPU client + every compiled pipeline.
pub struct Engine {
    client: xla::PjRtClient,
    pipelines: HashMap<String, Pipeline>,
    dir: PathBuf,
}

impl Engine {
    /// Create a CPU PJRT client and compile every pipeline in the
    /// manifest found at `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut engine = Self {
            client,
            pipelines: HashMap::new(),
            dir: dir.to_path_buf(),
        };
        for spec in manifest.pipelines {
            engine.compile_pipeline(spec)?;
        }
        Ok(engine)
    }

    /// Create an engine with no pipelines (they can be added later) —
    /// used by tests that compile ad-hoc HLO.
    pub fn empty() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self {
            client,
            pipelines: HashMap::new(),
            dir: PathBuf::new(),
        })
    }

    /// An engine rooted at `dir` with no pipelines compiled yet; use
    /// [`Engine::compile_pipeline`] to add the ones you need.
    pub fn with_dir(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self {
            client,
            pipelines: HashMap::new(),
            dir: dir.to_path_buf(),
        })
    }

    /// Compile and register one pipeline (HLO file resolved against the
    /// engine's artifacts dir).
    pub fn compile_pipeline(&mut self, spec: PipelineSpec) -> Result<()> {
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
        self.pipelines
            .insert(spec.name.clone(), Pipeline { spec, exe });
        Ok(())
    }

    /// Look up a compiled pipeline.
    pub fn pipeline(&self, name: &str) -> Option<&Pipeline> {
        self.pipelines.get(name)
    }

    /// Names of all registered pipelines.
    pub fn pipeline_names(&self) -> Vec<&str> {
        self.pipelines.keys().map(String::as_str).collect()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// A batched executor around one pipeline: accepts any number of sample
/// rows, pads to the pipeline's fixed batch `B`, executes, and unpads —
/// the adapter between the dynamic batcher and the static-shape artifact.
pub struct BatchedExecutor<'e> {
    pipeline: &'e Pipeline,
    proj: xla::Literal,
    offsets: xla::Literal,
}

impl<'e> BatchedExecutor<'e> {
    /// Bind a pipeline to a *folded* projection matrix (`[N][K]`, embedding
    /// scale and `1/r` already multiplied in) and offsets (`[K]`).
    pub fn new(pipeline: &'e Pipeline, proj_rm: &[f32], offsets: &[f32]) -> Result<Self> {
        let n = pipeline.spec.dim;
        let k = pipeline.spec.k;
        if proj_rm.len() != n * k {
            bail!("projection must be {n}x{k}");
        }
        if offsets.len() != k {
            bail!("offsets must have length {k}");
        }
        let proj = xla::Literal::vec1(proj_rm)
            .reshape(&[n as i64, k as i64])
            .map_err(|e| anyhow!("proj reshape: {e}"))?;
        let offsets = xla::Literal::vec1(offsets);
        Ok(Self {
            pipeline,
            proj,
            offsets,
        })
    }

    /// The underlying pipeline spec.
    pub fn spec(&self) -> &PipelineSpec {
        &self.pipeline.spec
    }

    /// Hash an arbitrary number of rows (each of length `N`), padding the
    /// final partial batch with zeros. Returns one signature (length `K`)
    /// per input row.
    pub fn hash_rows(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<i32>>> {
        let b = self.pipeline.spec.batch;
        let n = self.pipeline.spec.dim;
        let k = self.pipeline.spec.k;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let mut flat = vec![0f32; b * n];
            for (i, row) in chunk.iter().enumerate() {
                if row.len() != n {
                    bail!("row {} has length {}, expected {n}", i, row.len());
                }
                flat[i * n..(i + 1) * n].copy_from_slice(row);
            }
            let hashes = self.pipeline.hash_batch(&flat, &self.proj, &self.offsets)?;
            for i in 0..chunk.len() {
                out.push(hashes[i * k..(i + 1) * k].to_vec());
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE_MANIFEST: &str = r#"{
      "pipelines": [
        {"name": "mc_l2_hash", "file": "mc_l2_hash.hlo.txt",
         "batch": 128, "dim": 64, "k": 32,
         "inputs": ["samples", "proj", "offsets"]}
      ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE_MANIFEST).unwrap();
        assert_eq!(m.pipelines.len(), 1);
        let p = m.find("mc_l2_hash").unwrap();
        assert_eq!(p.batch, 128);
        assert_eq!(p.dim, 64);
        assert_eq!(p.k, 32);
        assert_eq!(p.inputs, vec!["samples", "proj", "offsets"]);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"pipelines": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
