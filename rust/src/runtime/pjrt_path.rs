//! [`PjrtHashPath`] — the production hash path: the coordinator's batched
//! `samples → signature` transform executed by the AOT-compiled XLA
//! pipeline instead of the pure-Rust fold.
//!
//! Semantics are identical to [`FoldedHashPath`] by construction: both
//! consume the *same* folded matrix/offsets; the PJRT path just runs the
//! matmul+floor on the XLA:CPU executable lowered from the Pallas kernel.
//! (Integration tests assert signature agreement between the two paths.)

use super::{Engine, Manifest};
use crate::coordinator::hashpath::{FoldedHashPath, HashPath, Signatures};
use crate::util::sync;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::Mutex;

/// Engine + bound literals, guarded for shared use.
///
/// SAFETY: the xla crate's handles are raw pointers without `Send`/`Sync`
/// markers, but the PJRT CPU client is thread-safe for compilation and
/// execution (it is exactly what the multi-threaded C API serves). We
/// still serialize all access through a `Mutex`, so the unsafe markers
/// only assert that *moving* the handles across threads is sound — no
/// concurrent aliasing ever happens.
struct Guarded {
    engine: Engine,
    pipeline: String,
    proj: xla::Literal,
    offsets: xla::Literal,
}

// SAFETY: see the type docs above — the PJRT CPU client is thread-safe
// for moves; the Mutex around every `Guarded` rules out aliasing.
unsafe impl Send for Guarded {}

/// PJRT-backed implementation of [`HashPath`].
pub struct PjrtHashPath {
    inner: Mutex<Guarded>,
    /// kept for `embed_row` (re-ranking) and as the fallback reference
    folded: FoldedHashPath,
    batch: usize,
    dim: usize,
    k: usize,
}

impl PjrtHashPath {
    /// Load the artifacts at `dir`, compile pipeline `name`, and bind the
    /// folded matrix/offsets from `folded` (so both backends compute the
    /// same function).
    pub fn from_folded(dir: &Path, name: &str, folded: FoldedHashPath) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let spec = manifest
            .find(name)
            .ok_or_else(|| anyhow!("pipeline `{name}` not in manifest"))?
            .clone();
        anyhow::ensure!(
            spec.dim == folded.dim(),
            "artifact dim {} != service dim {}",
            spec.dim,
            folded.dim()
        );
        anyhow::ensure!(
            spec.k == folded.signature_len(),
            "artifact k {} != service k*l {}",
            spec.k,
            folded.signature_len()
        );
        // only compile the one pipeline the service uses
        let mut engine = Engine::with_dir(dir)?;
        engine.compile_pipeline(spec.clone())?;
        let n = spec.dim;
        let k = spec.k;
        let proj = xla::Literal::vec1(&folded.matrix_f32())
            .reshape(&[n as i64, k as i64])
            .map_err(|e| anyhow!("proj literal: {e}"))?;
        let offsets = xla::Literal::vec1(&folded.offsets_f32());
        Ok(Self {
            inner: Mutex::new(Guarded {
                engine,
                pipeline: name.to_string(),
                proj,
                offsets,
            }),
            batch: spec.batch,
            dim: n,
            k,
            folded,
        })
    }

    /// The fixed batch size of the compiled pipeline.
    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

impl HashPath for PjrtHashPath {
    fn dim(&self) -> usize {
        self.dim
    }

    fn signature_len(&self) -> usize {
        self.k
    }

    fn hash_rows_into(&self, rows: &[Vec<f32>], out: &mut Signatures) -> Result<()> {
        let g = sync::lock(&self.inner);
        let pipeline = g
            .engine
            .pipeline(&g.pipeline)
            .ok_or_else(|| anyhow!("pipeline vanished"))?;
        let b = self.batch;
        let n = self.dim;
        let k = self.k;
        out.reset(k, rows.len());
        let mut done = 0usize;
        for chunk in rows.chunks(b) {
            let mut flat = vec![0f32; b * n];
            for (i, row) in chunk.iter().enumerate() {
                anyhow::ensure!(row.len() == n, "row length {} != {n}", row.len());
                flat[i * n..(i + 1) * n].copy_from_slice(row);
            }
            let hashes = pipeline.hash_batch(&flat, &g.proj, &g.offsets)?;
            for i in 0..chunk.len() {
                out.row_mut(done + i).copy_from_slice(&hashes[i * k..(i + 1) * k]);
            }
            done += chunk.len();
        }
        Ok(())
    }

    fn embed_row_with(&self, row: &[f32], scratch: &mut Vec<f64>) -> Vec<f64> {
        self.folded.embed_row_with(row, scratch)
    }
}
