//! Small statistics helpers shared by experiments, benches, and metrics.

/// Running mean/variance via Welford's algorithm — numerically stable and
/// single-pass, used by the bench harness and service metrics.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 if fewer than 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Quantile of a *sorted* slice with linear interpolation (type-7, the
/// numpy default). `q` in `[0, 1]`.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pearson correlation between two equal-length slices.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx).powi(2);
        dy += (y - my).powi(2);
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Root-mean-square deviation between paired observations — the headline
/// "observed vs theoretical collision rate" agreement metric in
/// EXPERIMENTS.md.
pub fn rmse(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    (xs.iter()
        .zip(ys)
        .map(|(x, y)| (x - y).powi(2))
        .sum::<f64>()
        / n)
        .sqrt()
}

/// Maximum absolute deviation between paired observations.
pub fn max_abs_dev(xs: &[f64], ys: &[f64]) -> f64 {
    xs.iter()
        .zip(ys)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_two_pass() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn quantile_interpolation() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        assert_eq!(quantile_sorted(&xs, 0.0), 0.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 3.0);
        assert!((quantile_sorted(&xs, 0.5) - 1.5).abs() < 1e-12);
        assert!((quantile_sorted(&xs, 0.25) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rmse_and_maxdev() {
        let xs = [0.0, 0.0];
        let ys = [3.0, 4.0];
        assert!((rmse(&xs, &ys) - (12.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(max_abs_dev(&xs, &ys), 4.0);
    }
}
