//! Poison-recovering lock acquisition — the only way library code
//! takes a `Mutex`/`RwLock`/`Condvar`.
//!
//! A panicking thread poisons any lock it holds; the default
//! `.lock().unwrap()` then panics *every later caller*, turning one
//! bad request into a wedged process (this bit the serving stack once
//! already — see the `mutex-poison` rule in [`crate::analysis`]).
//! These helpers recover the guard with
//! `unwrap_or_else(PoisonError::into_inner)`: the protected data is a
//! metrics histogram, an index shard, or a queue — all kept
//! structurally valid at every await-free step — so serving on after a
//! worker panic is strictly better than refusing every request.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
use std::time::Duration;

/// `Mutex::lock`, recovering from poison.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `RwLock::read`, recovering from poison.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// `RwLock::write`, recovering from poison.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait`, recovering from poison.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout`, recovering from poison.
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, timeout)
        .unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        *lock(&m) = 8;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_read_and_write_recover_after_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read(&l).len(), 3);
        write(&l).push(4);
        assert_eq!(read(&l).len(), 4);
    }

    #[test]
    fn wait_timeout_times_out_on_an_unsignalled_condvar() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock(&m);
        let (_g, res) = wait_timeout(&cv, g, Duration::from_millis(5));
        assert!(res.timed_out());
    }
}
