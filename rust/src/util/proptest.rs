//! A miniature property-testing harness (the offline vendor set has no
//! proptest/quickcheck): random case generation with seed reporting and
//! bounded shrinking for `Vec` inputs.
//!
//! Usage:
//! ```no_run
//! use funclsh::util::proptest::{check, Gen};
//! check(100, |g| {
//!     let xs: Vec<f64> = g.vec(1..50, |g| g.f64_range(-10.0, 10.0));
//!     let sum: f64 = xs.iter().sum();
//!     // property: sum is finite for finite inputs
//!     assert!(sum.is_finite(), "xs = {xs:?}");
//! });
//! ```

use super::rng::{Rng64, Xoshiro256pp};

/// Random-input generator handed to properties.
pub struct Gen {
    rng: Xoshiro256pp,
    /// the seed of the current case (printed on failure)
    pub seed: u64,
}

impl Gen {
    /// uniform f64 in `[lo, hi)`
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// standard normal
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// uniform usize in `[range.start, range.end)`
    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        range.start + self.rng.uniform_usize(range.end - range.start)
    }

    /// random u64
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// random bool with probability `p` of `true`
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }

    /// a vector with length drawn from `len` and elements from `item`
    pub fn vec<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut item: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize_in(len);
        (0..n).map(|_| item(self)).collect()
    }

    /// access to the raw RNG (for APIs that take `&mut dyn Rng64`)
    pub fn rng(&mut self) -> &mut dyn Rng64 {
        &mut self.rng
    }
}

/// Run `prop` on `cases` random cases. Panics (with the case seed) on the
/// first failure; re-running with `FUNCLSH_PROPTEST_SEED=<seed>` replays
/// exactly that case. `FUNCLSH_PROPTEST_CASES=<n>` caps the case count
/// (the nightly Miri job sets a small cap — each case runs ~100× slower
/// under the interpreter).
pub fn check(cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let cases = match std::env::var("FUNCLSH_PROPTEST_CASES") {
        Ok(s) => cases.min(s.parse().expect("bad FUNCLSH_PROPTEST_CASES")),
        Err(_) => cases,
    };
    if let Ok(seed_str) = std::env::var("FUNCLSH_PROPTEST_SEED") {
        let seed: u64 = seed_str.parse().expect("bad FUNCLSH_PROPTEST_SEED");
        let mut g = Gen {
            rng: Xoshiro256pp::seed_from_u64(seed),
            seed,
        };
        prop(&mut g);
        return;
    }
    // derive per-case seeds from a master seed that varies per test
    // location but is stable across runs (deterministic CI)
    let master = 0x5EED_2020u64;
    for case in 0..cases {
        let seed = master.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Xoshiro256pp::seed_from_u64(seed),
            seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            crate::util::log::warn(format!(
                "property failed on case {case} (replay with FUNCLSH_PROPTEST_SEED={seed})"
            ));
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check(37, |_| count += 1);
        assert_eq!(count, 37);
    }

    #[test]
    fn generators_in_range() {
        check(50, |g| {
            let x = g.f64_range(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
            let n = g.usize_in(5..10);
            assert!((5..10).contains(&n));
            let v = g.vec(0..4, |g| g.bool(0.5));
            assert!(v.len() < 4);
        });
    }

    #[test]
    #[should_panic(expected = "deliberate")]
    fn failures_propagate() {
        check(10, |g| {
            if g.seed != 0 {
                panic!("deliberate");
            }
        });
    }
}
