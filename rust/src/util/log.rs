//! The library's one stderr choke point.
//!
//! Library code must never print: cluster nodes run headless with
//! stdout redirected into the newline-framed JSON wire, so a stray
//! `println!` corrupts frames, and `eprintln!` scattered through the
//! crate makes diagnostics impossible to silence or redirect
//! coherently. The `print-discipline` rule in [`crate::analysis`]
//! enforces this — only `cli/`, `bench/`, `main.rs`, and this module
//! touch stdio directly.
//!
//! [`warn`] goes to stderr (never stdout), so it can never corrupt a
//! stdout-framed wire, and gives operators a single grep target
//! (`funclsh:`) across every subsystem.

use std::fmt::Display;
use std::io::Write as _;

/// Write one diagnostic line to stderr, prefixed `funclsh: `. Errors
/// writing to stderr are ignored — diagnostics must never take the
/// serving path down.
pub fn warn<M: Display>(msg: M) {
    let stderr = std::io::stderr();
    let mut lock = stderr.lock();
    let _ = writeln!(lock, "funclsh: {msg}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warn_accepts_display_types_without_panicking() {
        warn("plain str");
        warn(format!("formatted {}", 42));
        warn(std::io::Error::other("io error"));
    }
}
