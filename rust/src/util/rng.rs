//! Pseudo-random number generation.
//!
//! * [`SplitMix64`] — Steele, Lea & Flood (2014). Used for seeding and for
//!   cheap stateless streams (the lazy coefficient extension of Algorithm 1
//!   keys a SplitMix64 stream per hash function so coefficient `i` is
//!   reproducible without storing the prefix).
//! * [`Xoshiro256pp`] — Blackman & Vigna (2019), `xoshiro256++`. The default
//!   generator everywhere else.
//!
//! On top of raw bits we provide the samplers the paper needs:
//! uniforms, Gaussians (for the 2-stable hash and SimHash), Cauchy (1-stable,
//! for the `W¹`/earth-mover hash), and general `p`-stable variates via the
//! Chambers–Mallows–Stuck transform (for any `p ∈ (0, 2]`).

/// A 64-bit pseudo-random generator.
///
/// The trait is object-safe so hash banks can hold `Box<dyn Rng64>` when the
/// generator is chosen at run time from config.
pub trait Rng64 {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53 bits of precision.
    fn uniform(&mut self) -> f64 {
        // Take the top 53 bits -> [0, 2^53), scale by 2^-53.
        ((self.next_u64() >> 11) as f64) * (1.0 / 9007199254740992.0)
    }

    /// Uniform in `[lo, hi)`.
    fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    fn uniform_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method.
    ///
    /// Polar (not Box–Muller) avoids trig calls; we deliberately *discard*
    /// the second variate to keep the trait stateless — hash-bank
    /// construction is not on the request path, so the 2x cost is irrelevant
    /// and reproducibility across call sites is simpler.
    fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Standard Cauchy variate (1-stable), via tan of a uniform angle.
    fn cauchy(&mut self) -> f64 {
        // Avoid the exact endpoints where tan blows up to ±inf.
        loop {
            let u = self.uniform();
            if u > 0.0 && u < 1.0 {
                return (std::f64::consts::PI * (u - 0.5)).tan();
            }
        }
    }

    /// Symmetric `alpha`-stable variate (`0 < alpha <= 2`), standard scale,
    /// via the Chambers–Mallows–Stuck (1976) transform.
    ///
    /// `alpha = 2` reduces to `N(0, 2)`; we rescale so that `alpha = 2`
    /// yields a *standard* normal, matching the convention of Datar et al.
    /// (2004) where the 2-stable hash draws `α_i ~ N(0,1)`. `alpha = 1`
    /// is standard Cauchy.
    fn stable(&mut self, alpha: f64) -> f64 {
        assert!(alpha > 0.0 && alpha <= 2.0, "stability index out of range");
        if (alpha - 2.0).abs() < 1e-12 {
            return self.normal();
        }
        if (alpha - 1.0).abs() < 1e-12 {
            return self.cauchy();
        }
        // CMS for symmetric stable (beta = 0):
        //   X = sin(alpha * U) / cos(U)^{1/alpha}
        //       * ( cos(U - alpha*U) / W )^{(1-alpha)/alpha}
        // with U ~ Uniform(-pi/2, pi/2), W ~ Exp(1).
        let u = std::f64::consts::FRAC_PI_2 * (2.0 * self.uniform() - 1.0);
        let w = loop {
            let e = -self.uniform().ln();
            if e.is_finite() && e > 0.0 {
                break e;
            }
        };
        let num = (alpha * u).sin();
        let den = u.cos().powf(1.0 / alpha);
        let tail = ((u - alpha * u).cos() / w).powf((1.0 - alpha) / alpha);
        num / den * tail
    }

    /// Fill `buf` with i.i.d. standard normals.
    fn fill_normal(&mut self, buf: &mut [f64]) {
        for x in buf.iter_mut() {
            *x = self.normal();
        }
    }

    /// Fisher–Yates shuffle. (`Self: Sized` keeps the trait dyn-safe.)
    fn shuffle<T>(&mut self, xs: &mut [T])
    where
        Self: Sized,
    {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — a tiny, high-quality 64-bit generator. Passes BigCrush when
/// used as designed; primarily used here for seeding and keyed streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// The `i`-th output of the stream seeded by `seed`, without mutation.
    /// Used for lazy/virtual infinite coefficient vectors (Algorithm 1).
    pub fn nth(seed: u64, i: u64) -> u64 {
        let mut s = Self::new(seed.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15)));
        s.next_u64()
    }
}

impl Rng64 for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna, 2019). The workhorse generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed the full 256-bit state from a 64-bit seed through SplitMix64,
    /// as recommended by the authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for si in s.iter_mut() {
            *si = sm.next_u64();
        }
        // All-zero state is invalid (fixed point); SplitMix64 cannot emit
        // four zeros in a row for any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Jump ahead 2^128 steps: used to carve independent substreams for
    /// worker threads from a single master seed.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180ec6d33cfd0aba,
            0xd5a61266f0c9392c,
            0xa9582618e03fc9aa,
            0x39abdc4529b1661c,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    for (acc, cur) in s.iter_mut().zip(self.s.iter()) {
                        *acc ^= cur;
                    }
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// A fresh generator 2^128 steps ahead; advances `self` past the jump.
    pub fn split(&mut self) -> Self {
        let child = *self;
        self.jump();
        child
    }
}

impl Rng64 for Xoshiro256pp {
    fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 from the public-domain
        // splitmix64.c by Sebastiano Vigna.
        let mut g = SplitMix64::new(1234567);
        let got: Vec<u64> = (0..3).map(|_| g.next_u64()).collect();
        assert_eq!(got[0], 6457827717110365317);
        assert_eq!(got[1], 3203168211198807973);
        assert_eq!(got[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_nonzero_and_distinct() {
        let mut g = Xoshiro256pp::seed_from_u64(42);
        let a = g.next_u64();
        let b = g.next_u64();
        assert_ne!(a, b);
        assert_ne!(a, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut g = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = g.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut g = Xoshiro256pp::seed_from_u64(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| g.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256pp::seed_from_u64(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn cauchy_median_and_quartiles() {
        // The Cauchy has no moments; check median ~ 0 and quartiles ~ ±1.
        let mut g = Xoshiro256pp::seed_from_u64(17);
        let n = 100_000;
        let mut xs: Vec<f64> = (0..n).map(|_| g.cauchy()).collect();
        xs.sort_by(f64::total_cmp);
        let med = xs[n / 2];
        let q1 = xs[n / 4];
        let q3 = xs[3 * n / 4];
        assert!(med.abs() < 0.03, "median {med}");
        assert!((q1 + 1.0).abs() < 0.05, "q1 {q1}");
        assert!((q3 - 1.0).abs() < 0.05, "q3 {q3}");
    }

    #[test]
    fn stable_matches_special_cases() {
        // alpha = 2 must be standard normal; alpha = 1 standard Cauchy.
        let mut g = Xoshiro256pp::seed_from_u64(19);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| g.stable(2.0)).collect();
        let var = xs.iter().map(|x| x * x).sum::<f64>() / n as f64;
        assert!((var - 1.0).abs() < 0.03, "alpha=2 var {var}");

        let mut ys: Vec<f64> = (0..n).map(|_| g.stable(1.0)).collect();
        ys.sort_by(f64::total_cmp);
        assert!((ys[3 * n / 4] - 1.0).abs() < 0.06, "alpha=1 q3 {}", ys[3 * n / 4]);
    }

    #[test]
    fn stable_generic_alpha_symmetric() {
        // For alpha = 1.5, the distribution is symmetric: median ~ 0 and
        // P(X > 0) ~ 1/2.
        let mut g = Xoshiro256pp::seed_from_u64(23);
        let n = 100_000;
        let pos = (0..n).filter(|_| g.stable(1.5) > 0.0).count();
        let frac = pos as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "P(X>0) = {frac}");
    }

    #[test]
    fn uniform_usize_unbiased_small_n() {
        let mut g = Xoshiro256pp::seed_from_u64(29);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[g.uniform_usize(5)] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "bin fraction {frac}");
        }
    }

    #[test]
    fn jump_produces_disjoint_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(99);
        let mut b = a;
        b.jump();
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn splitmix_nth_is_stateless_random_access() {
        let a = SplitMix64::nth(5, 10);
        let b = SplitMix64::nth(5, 10);
        let c = SplitMix64::nth(5, 11);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut g = Xoshiro256pp::seed_from_u64(31);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
