//! Low-level numeric substrates: pseudo-random number generation, stable
//! distributions, and special functions.
//!
//! Everything here is implemented from scratch (the build environment is
//! fully offline); algorithms follow standard published references cited on
//! each item.

pub mod log;
pub mod proptest;
pub mod rng;
pub mod special;
pub mod stats;
pub mod sync;

pub use rng::{Rng64, SplitMix64, Xoshiro256pp};
pub use special::{erf, erfc, normal_cdf, normal_pdf, normal_quantile};
