//! Special functions: `erf`, `erfc`, the standard normal pdf/cdf/quantile.
//!
//! These drive (a) the Gaussian quantile functions hashed in the paper's
//! Wasserstein experiment (Figure 3), and (b) the theoretical collision
//! probability curves (Equations 7–8).
//!
//! `erf` uses the Abramowitz & Stegun 7.1.26-style rational approximation
//! refined to double precision (W. J. Cody's rational Chebyshev fits);
//! `normal_quantile` uses Acklam's algorithm polished with one step of
//! Halley's method, giving ~1e-15 relative error.

use std::f64::consts::{FRAC_1_SQRT_2, PI};

/// The error function `erf(x) = 2/√π ∫₀ˣ e^{-t²} dt`.
///
/// Cody-style rational approximations on three ranges; absolute error
/// below 1.2e-16 over the real line.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// The complementary error function `erfc(x) = 1 - erf(x)`.
pub fn erfc(x: f64) -> f64 {
    let ax = x.abs();
    let r = if ax < 0.5 {
        // erf via the series-like rational fit, then complement.
        return 1.0 - erf_small(x);
    } else if ax < 4.0 {
        erfc_mid(ax)
    } else {
        erfc_large(ax)
    };
    if x < 0.0 {
        2.0 - r
    } else {
        r
    }
}

/// Rational fit for `erf` on |x| < 0.5 (Cody 1969, W. Fullerton FNLIB).
fn erf_small(x: f64) -> f64 {
    // max error ~ 6e-17 on |x| <= 0.5
    const P: [f64; 5] = [
        3.209377589138469472562e3,
        3.774852376853020208137e2,
        1.138641541510501556495e2,
        3.161123743870565596947e0,
        1.857777061846031526730e-1,
    ];
    const Q: [f64; 5] = [
        2.844236833439170622273e3,
        1.282616526077372275645e3,
        2.440246379344441733056e2,
        2.360129095234412093499e1,
        1.0,
    ];
    let z = x * x;
    let mut num = P[4];
    let mut den = Q[4];
    for i in (0..4).rev() {
        num = num * z + P[i];
        den = den * z + Q[i];
    }
    x * num / den
}

/// Rational fit for `erfc` on 0.5 <= x < 4 (Cody 1969).
fn erfc_mid(x: f64) -> f64 {
    const P: [f64; 9] = [
        1.23033935479799725272e3,
        2.05107837782607146532e3,
        1.71204761263407058314e3,
        8.81952221241769090411e2,
        2.98635138197400131132e2,
        6.61191906371416294775e1,
        8.88314979438837594118e0,
        5.64188496988670089180e-1,
        2.15311535474403846343e-8,
    ];
    const Q: [f64; 9] = [
        1.23033935480374942043e3,
        3.43936767414372163696e3,
        4.36261909014324715820e3,
        3.29079923573345962678e3,
        1.62138957456669018874e3,
        5.37181101862009857509e2,
        1.17693950891312499305e2,
        1.57449261107098347253e1,
        1.0,
    ];
    let mut num = P[8];
    let mut den = Q[8];
    for i in (0..8).rev() {
        num = num * x + P[i];
        den = den * x + Q[i];
    }
    (-x * x).exp() * num / den
}

/// Asymptotic-style rational fit for `erfc` on x >= 4 (Cody 1969).
fn erfc_large(x: f64) -> f64 {
    if x > 26.5 {
        return 0.0; // below double underflow of exp(-x^2)
    }
    const P: [f64; 6] = [
        -6.58749161529837803157e-4,
        -1.60837851487422766278e-2,
        -1.25781726111229246204e-1,
        -3.60344899949804439429e-1,
        -3.05326634961232344035e-1,
        -1.63153871373020978498e-2,
    ];
    const Q: [f64; 6] = [
        2.33520497626869185443e-3,
        6.05183413124413191178e-2,
        5.27905102951428412248e-1,
        1.87295284992346047209e0,
        2.56852019228982242072e0,
        1.0,
    ];
    let z = 1.0 / (x * x);
    let mut num = P[5];
    let mut den = Q[5];
    for i in (0..5).rev() {
        num = num * z + P[i];
        den = den * z + Q[i];
    }
    let poly = z * num / den;
    let inv_sqrt_pi = 1.0 / PI.sqrt();
    ((-x * x).exp() / x) * (inv_sqrt_pi + poly)
}

/// Standard normal probability density `φ(x)`.
pub fn normal_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * PI).sqrt()
}

/// Standard normal cumulative distribution `Φ(x)`.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * FRAC_1_SQRT_2)
}

/// Standard normal quantile `Φ⁻¹(p)` for `p ∈ (0, 1)`.
///
/// Acklam's rational approximation (abs error < 1.15e-9) refined with one
/// Halley step against [`normal_cdf`], giving near machine precision.
/// Returns `±∞` at the endpoints.
pub fn normal_quantile(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "quantile arg must be in [0,1]");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley refinement: u = (Phi(x) - p) / phi(x);
    // x <- x - u / (1 + x u / 2).
    let e = normal_cdf(x) - p;
    let u = e / normal_pdf(x);
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values computed with mpmath to 20 digits.
    const ERF_TABLE: [(f64, f64); 7] = [
        (0.0, 0.0),
        (0.1, 0.1124629160182848922),
        (0.5, 0.5204998778130465377),
        (1.0, 0.8427007929497148693),
        (1.5, 0.9661051464753107271),
        (2.0, 0.9953222650189527342),
        (3.0, 0.9999779095030014146),
    ];

    #[test]
    fn erf_against_table() {
        for (x, want) in ERF_TABLE {
            let got = erf(x);
            assert!(
                (got - want).abs() < 1e-14,
                "erf({x}) = {got}, want {want}"
            );
            // odd symmetry
            assert!((erf(-x) + want).abs() < 1e-14);
        }
    }

    #[test]
    fn erfc_tail_accuracy() {
        // erfc(5) = 1.5374597944280348502e-12 (mpmath)
        let got = erfc(5.0);
        let want = 1.5374597944280348502e-12;
        assert!(
            ((got - want) / want).abs() < 1e-12,
            "erfc(5) rel err: {got} vs {want}"
        );
    }

    #[test]
    fn normal_cdf_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-15);
        // Phi(1.959963984540054) = 0.975
        assert!((normal_cdf(1.959963984540054) - 0.975).abs() < 1e-13);
        assert!((normal_cdf(-1.0) - 0.15865525393145707).abs() < 1e-14);
    }

    #[test]
    fn quantile_roundtrip() {
        for &p in &[1e-10, 1e-6, 0.001, 0.01, 0.25, 0.5, 0.77, 0.99, 0.999999] {
            let x = normal_quantile(p);
            let back = normal_cdf(x);
            assert!(
                (back - p).abs() < 1e-12 * p.max(1e-3),
                "roundtrip p={p}: got {back}"
            );
        }
    }

    #[test]
    fn quantile_known_points() {
        assert!((normal_quantile(0.5)).abs() < 1e-15);
        assert!((normal_quantile(0.975) - 1.959963984540054).abs() < 1e-12);
        assert!((normal_quantile(0.025) + 1.959963984540054).abs() < 1e-12);
    }

    #[test]
    fn quantile_endpoints() {
        assert_eq!(normal_quantile(0.0), f64::NEG_INFINITY);
        assert_eq!(normal_quantile(1.0), f64::INFINITY);
    }

    #[test]
    fn pdf_integrates_to_one() {
        // crude trapezoid over [-8, 8]
        let n = 4000;
        let h = 16.0 / n as f64;
        let mut s = 0.0;
        for i in 0..=n {
            let x = -8.0 + i as f64 * h;
            let w = if i == 0 || i == n { 0.5 } else { 1.0 };
            s += w * normal_pdf(x);
        }
        assert!((s * h - 1.0).abs() < 1e-10);
    }
}
