//! Embeddings `T : L^p_μ(Ω) → ℓ^p_N` — the heart of the paper (§3).
//!
//! Both methods approximately preserve `‖f − g‖_{L^p_μ}` (and, for `p = 2`,
//! `⟨f, g⟩`), so any LSH family on `ℝ^N` applied to `T(f)` becomes an LSH
//! family on the function space:
//!
//! * [`MonteCarloEmbedder`] (§3.2) — sample `f` at `N` i.i.d. points of `Ω`
//!   drawn from `μ/V` and scale by `(V/N)^{1/p}`; error `O(N^{-1/2})`.
//! * [`QmcEmbedder`] (§3.2) — same, but the points come from a
//!   low-discrepancy (Sobol/Halton) sequence; error `O(N^{-1} log N)` in 1-D.
//! * [`ChebyshevEmbedder`] (§3.1) — coefficients in an orthonormal basis of
//!   `L²([a,b])`. We use the cosine-transformed Chebyshev system: under
//!   `x = a + (b-a)(1 - cos θ)/2` the weighted samples
//!   `h(θ) = f(x(θ)) · √((b-a) sin θ / 2)` live in `L²([0, π])`, where
//!   `{1/√π, √(2/π) cos jθ}` is orthonormal — this is exactly the paper's
//!   "Chebyshev basis made a basis for `L²([a,b])` with a change of
//!   variables". Coefficients are a scaled DCT-II of the weighted samples,
//!   computed in `O(N log N)`.

pub mod bases;
pub mod multidim;

pub use bases::{FourierEmbedder, LegendreEmbedder};
pub use multidim::{Function2D, MonteCarloEmbedder2D, Rectangle};

use crate::chebyshev::dct2;
use crate::functions::Function1D;
use crate::sequences::{Halton, Sobol};
use crate::util::rng::Rng64;
use std::f64::consts::PI;

/// A closed interval `[a, b]` — the domain `Ω` of all 1-D experiments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    /// left endpoint
    pub a: f64,
    /// right endpoint
    pub b: f64,
}

impl Interval {
    /// `[a, b]`, requiring `a < b`.
    pub fn new(a: f64, b: f64) -> Self {
        assert!(a < b, "interval must be nondegenerate");
        Self { a, b }
    }

    /// The unit interval `[0, 1]` used throughout the paper's experiments.
    pub fn unit() -> Self {
        Self::new(0.0, 1.0)
    }

    /// Volume `V = ∫_Ω dμ` under Lebesgue measure.
    pub fn volume(&self) -> f64 {
        self.b - self.a
    }
}

/// An embedding of a function space into `ℝ^N`.
///
/// Implementations also expose their *sample points*: the coordinator
/// publishes these so clients can ship raw sample vectors `f(x_1..x_N)`
/// instead of function objects, and [`Embedder::embed_samples`] finishes
/// the job (this is the request-path split: sampling happens client-side,
/// the linear transform happens in the AOT pipeline or here).
pub trait Embedder: Send + Sync {
    /// Output dimension `N`.
    fn dim(&self) -> usize;

    /// The exponent `p` of the `L^p` space being embedded.
    fn p(&self) -> f64;

    /// The points at which input functions must be sampled.
    fn sample_points(&self) -> &[f64];

    /// Embed a vector of raw samples `f(x_i)` (in `sample_points` order).
    fn embed_samples(&self, samples: &[f64]) -> Vec<f64>;

    /// Embed a function by sampling it, then calling
    /// [`Embedder::embed_samples`].
    fn embed_fn(&self, f: &dyn Function1D) -> Vec<f64> {
        let samples: Vec<f64> = self
            .sample_points()
            .iter()
            .map(|&x| f.eval(x))
            .collect();
        self.embed_samples(&samples)
    }
}

/// §3.2 with i.i.d. sampling: `T(f) = (V/N)^{1/p} (f(x_1), …, f(x_N))`,
/// `x_i ~ μ/V` (uniform on the interval for Lebesgue `μ`).
#[derive(Debug, Clone)]
pub struct MonteCarloEmbedder {
    points: Vec<f64>,
    scale: f64,
    p: f64,
}

impl MonteCarloEmbedder {
    /// Draw `n` i.i.d. uniform sample points on `omega`.
    pub fn new(omega: Interval, n: usize, p: f64, rng: &mut dyn Rng64) -> Self {
        assert!(n > 0 && p > 0.0);
        let points = (0..n).map(|_| rng.uniform_in(omega.a, omega.b)).collect();
        Self::from_points(points, omega.volume(), p)
    }

    /// Build from externally chosen points (e.g. shared across a cluster so
    /// every node embeds identically). `volume` is `V = ∫_Ω dμ`.
    pub fn from_points(points: Vec<f64>, volume: f64, p: f64) -> Self {
        assert!(!points.is_empty());
        let n = points.len();
        let scale = (volume / n as f64).powf(1.0 / p);
        Self { points, scale, p }
    }

    /// The `(V/N)^{1/p}` prefactor.
    pub fn scale(&self) -> f64 {
        self.scale
    }
}

impl Embedder for MonteCarloEmbedder {
    fn dim(&self) -> usize {
        self.points.len()
    }

    fn p(&self) -> f64 {
        self.p
    }

    fn sample_points(&self) -> &[f64] {
        &self.points
    }

    fn embed_samples(&self, samples: &[f64]) -> Vec<f64> {
        assert_eq!(samples.len(), self.points.len());
        samples.iter().map(|&s| s * self.scale).collect()
    }
}

/// The low-discrepancy sequence behind a [`QmcEmbedder`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QmcSequence {
    /// Sobol' sequence (Joe–Kuo direction numbers).
    Sobol,
    /// Halton sequence (base 2 in one dimension).
    Halton,
}

/// §3.2 with quasi-Monte Carlo sampling: identical transform to
/// [`MonteCarloEmbedder`] but the points form a low-discrepancy sequence,
/// improving the embedding error to `O(N^{-1} log N)` in one dimension.
#[derive(Debug, Clone)]
pub struct QmcEmbedder {
    inner: MonteCarloEmbedder,
    sequence: QmcSequence,
}

impl QmcEmbedder {
    /// `n` points of the chosen sequence mapped onto `omega`.
    pub fn new(omega: Interval, n: usize, p: f64, sequence: QmcSequence) -> Self {
        let unit: Vec<f64> = match sequence {
            QmcSequence::Sobol => Sobol::new(1).take_1d(n),
            QmcSequence::Halton => {
                let mut h = Halton::new(1);
                (0..n).map(|_| h.next_point()[0]).collect()
            }
        };
        let points = unit
            .into_iter()
            .map(|u| omega.a + omega.volume() * u)
            .collect();
        Self {
            inner: MonteCarloEmbedder::from_points(points, omega.volume(), p),
            sequence,
        }
    }

    /// Which sequence generated the sample points.
    pub fn sequence(&self) -> QmcSequence {
        self.sequence
    }
}

impl Embedder for QmcEmbedder {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn p(&self) -> f64 {
        self.inner.p()
    }

    fn sample_points(&self) -> &[f64] {
        self.inner.sample_points()
    }

    fn embed_samples(&self, samples: &[f64]) -> Vec<f64> {
        self.inner.embed_samples(samples)
    }
}

/// §3.1: orthonormal-basis embedding of `L²([a, b])` (Lebesgue) via the
/// cosine-transformed Chebyshev system.
///
/// `T(f)_j = ⟨e_j, h⟩_{L²([0,π])}` approximated by the midpoint rule at
/// `θ_k = π(k+½)/N`, which is a scaled DCT-II of the weighted samples
/// `h_k = f(x(θ_k)) √((b-a) sin θ_k / 2)`:
///
/// * `T(f)_0 = (√π / N) Σ_k h_k`
/// * `T(f)_j = (√(2π) / N) Σ_k h_k cos(π j (k+½)/N)`, `j ≥ 1`.
///
/// As `N → ∞`, `‖T(f) − T(g)‖_{ℓ²} → ‖f − g‖_{L²([a,b])}` and inner
/// products converge likewise (Hilbert-space isometry, truncated).
#[derive(Debug, Clone)]
pub struct ChebyshevEmbedder {
    omega: Interval,
    /// x(θ_k) — where the input function is sampled
    points: Vec<f64>,
    /// √((b-a) sin θ_k / 2) — the change-of-variables weight
    weights: Vec<f64>,
}

impl ChebyshevEmbedder {
    /// An `n`-coefficient embedding of `L²(omega)`.
    pub fn new(omega: Interval, n: usize) -> Self {
        assert!(n > 0);
        let mut points = Vec::with_capacity(n);
        let mut weights = Vec::with_capacity(n);
        let v = omega.volume();
        for k in 0..n {
            let theta = PI * (k as f64 + 0.5) / n as f64;
            points.push(omega.a + v * (1.0 - theta.cos()) / 2.0);
            weights.push((v * theta.sin() / 2.0).sqrt());
        }
        Self {
            omega,
            points,
            weights,
        }
    }

    /// The domain being embedded.
    pub fn omega(&self) -> Interval {
        self.omega
    }

    /// The DCT weights (exposed for the AOT pipeline, which folds them into
    /// the kernel's input scaling).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

impl Embedder for ChebyshevEmbedder {
    fn dim(&self) -> usize {
        self.points.len()
    }

    fn p(&self) -> f64 {
        2.0
    }

    fn sample_points(&self) -> &[f64] {
        &self.points
    }

    fn embed_samples(&self, samples: &[f64]) -> Vec<f64> {
        let n = samples.len();
        assert_eq!(n, self.points.len());
        let weighted: Vec<f64> = samples
            .iter()
            .zip(&self.weights)
            .map(|(&s, &w)| s * w)
            .collect();
        let d = dct2(&weighted);
        let s0 = PI.sqrt() / n as f64;
        let sj = (2.0 * PI).sqrt() / n as f64;
        d.into_iter()
            .enumerate()
            .map(|(j, dj)| if j == 0 { s0 * dj } else { sj * dj })
            .collect()
    }
}

/// ℓ² distance between two embedded vectors — convenience used everywhere
/// in experiments.
pub fn l2_dist(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
}

/// ℓ^p distance between two embedded vectors.
pub fn lp_dist(x: &[f64], y: &[f64], p: f64) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| (a - b).abs().powf(p))
        .sum::<f64>()
        .powf(1.0 / p)
}

/// Cosine similarity between two embedded vectors.
pub fn cosine_sim(x: &[f64], y: &[f64]) -> f64 {
    let ip: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    let nx: f64 = x.iter().map(|a| a * a).sum::<f64>().sqrt();
    let ny: f64 = y.iter().map(|a| a * a).sum::<f64>().sqrt();
    (ip / (nx * ny)).clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::Sine;
    use crate::quadrature::{cosine_similarity_l2, inner_product_l2, lp_distance};
    use crate::util::rng::Xoshiro256pp;

    fn sine_pair() -> (Sine, Sine) {
        (Sine::paper(0.4), Sine::paper(2.1))
    }

    #[test]
    fn mc_embedding_preserves_l2_distance() {
        let (f, g) = sine_pair();
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        // Average over several point sets: MC is unbiased in the squared
        // distance, so the mean over seeds should land near the truth.
        let mut acc = 0.0;
        let reps = 32;
        for _ in 0..reps {
            let emb = MonteCarloEmbedder::new(Interval::unit(), 256, 2.0, &mut rng);
            acc += l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
        }
        let mean = acc / reps as f64;
        assert!(
            (mean - truth).abs() < 0.02 * truth.max(0.1),
            "{mean} vs {truth}"
        );
    }

    #[test]
    fn qmc_embedding_tighter_than_mc() {
        let (f, g) = sine_pair();
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let emb = QmcEmbedder::new(Interval::unit(), 256, 2.0, QmcSequence::Sobol);
        let d = l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
        assert!((d - truth).abs() < 5e-3 * truth.max(0.1), "{d} vs {truth}");
    }

    #[test]
    fn halton_variant_works() {
        let (f, g) = sine_pair();
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let emb = QmcEmbedder::new(Interval::unit(), 512, 2.0, QmcSequence::Halton);
        let d = l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
        assert!((d - truth).abs() < 5e-3 * truth.max(0.1));
    }

    #[test]
    fn chebyshev_embedding_preserves_l2_distance() {
        let (f, g) = sine_pair();
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let emb = ChebyshevEmbedder::new(Interval::unit(), 64);
        let d = l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
        // endpoint √sin weight limits convergence to ~N^{-3/2}
        assert!((d - truth).abs() < 5e-3, "{d} vs {truth}");
    }

    #[test]
    fn chebyshev_embedding_preserves_inner_product() {
        let (f, g) = sine_pair();
        let truth = inner_product_l2(&f, &g, 0.0, 1.0);
        let emb = ChebyshevEmbedder::new(Interval::unit(), 64);
        let tf = emb.embed_fn(&f);
        let tg = emb.embed_fn(&g);
        let ip: f64 = tf.iter().zip(&tg).map(|(a, b)| a * b).sum();
        assert!((ip - truth).abs() < 5e-3, "{ip} vs {truth}");
    }

    #[test]
    fn chebyshev_embedding_preserves_cosine_similarity() {
        let (f, g) = sine_pair();
        let truth = cosine_similarity_l2(&f, &g, 0.0, 1.0);
        let emb = ChebyshevEmbedder::new(Interval::unit(), 64);
        let got = cosine_sim(&emb.embed_fn(&f), &emb.embed_fn(&g));
        assert!((got - truth).abs() < 1e-2, "{got} vs {truth}");
    }

    #[test]
    fn chebyshev_error_decreases_with_n() {
        let (f, g) = sine_pair();
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let errs: Vec<f64> = [16usize, 64, 256]
            .iter()
            .map(|&n| {
                let emb = ChebyshevEmbedder::new(Interval::unit(), n);
                (l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g)) - truth).abs()
            })
            .collect();
        assert!(errs[2] < errs[0], "errors {errs:?}");
    }

    #[test]
    fn nonunit_domain_volume_scaling() {
        // f = 1, g = 0 on [0, 4]: ‖f−g‖_{L²} = 2.
        let f = |_x: f64| 1.0;
        let g = |_x: f64| 0.0;
        let omega = Interval::new(0.0, 4.0);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let mc = MonteCarloEmbedder::new(omega, 128, 2.0, &mut rng);
        let d = l2_dist(&mc.embed_fn(&f), &mc.embed_fn(&g));
        assert!((d - 2.0).abs() < 1e-12, "{d}");
        let ch = ChebyshevEmbedder::new(omega, 64);
        let dc = l2_dist(&ch.embed_fn(&f), &ch.embed_fn(&g));
        assert!((dc - 2.0).abs() < 5e-3, "{dc}");
    }

    #[test]
    fn l1_embedding_scaling() {
        // p = 1: ‖f−g‖_{L¹[0,1]} of |sin| pair via MC matches quadrature.
        let (f, g) = sine_pair();
        let truth = lp_distance(&f, &g, 0.0, 1.0, 1.0);
        let emb = QmcEmbedder::new(Interval::unit(), 512, 1.0, QmcSequence::Sobol);
        let d = lp_dist(&emb.embed_fn(&f), &emb.embed_fn(&g), 1.0);
        assert!((d - truth).abs() < 0.01, "{d} vs {truth}");
    }

    #[test]
    fn embed_samples_matches_embed_fn() {
        let (f, _) = sine_pair();
        let emb = ChebyshevEmbedder::new(Interval::unit(), 32);
        let samples: Vec<f64> = emb.sample_points().iter().map(|&x| f.eval(x)).collect();
        assert_eq!(emb.embed_samples(&samples), emb.embed_fn(&f));
    }
}
