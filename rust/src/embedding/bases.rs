//! Additional orthonormal bases for the §3.1 embedding — the paper's
//! method is stated for *any* orthonormal basis of `L²_μ(Ω)`; these two
//! exercise that generality and fix the Chebyshev variant's weak spots:
//!
//! * [`LegendreEmbedder`] — normalized Legendre polynomials
//!   `ê_j = √((2j+1)/V) P_j(t)`, orthonormal w.r.t. **Lebesgue** measure
//!   directly, so the embedding is exactly isometric (no √sin weighting)
//!   and spectrally accurate for smooth `f`. Coefficients are computed
//!   with Gauss–Legendre quadrature, which is exact for the polynomial
//!   integrands involved.
//! * [`FourierEmbedder`] — the real trigonometric basis
//!   `{1/√V, √(2/V) cos(2πjt/V), √(2/V) sin(2πjt/V)}`, the natural choice
//!   for periodic workloads (the paper's own sine experiments!), computed
//!   by direct projection at equispaced points (a real DFT).

use super::{Embedder, Interval};
use crate::quadrature::gauss_legendre;
use std::f64::consts::PI;

/// §3.1 embedding in the normalized Legendre basis.
#[derive(Debug, Clone)]
pub struct LegendreEmbedder {
    omega: Interval,
    /// Gauss–Legendre nodes mapped to `omega` (the sample points)
    points: Vec<f64>,
    /// projection matrix `P[m][j] = w_m ê_j(x_m)` (row-major `[n][n]`),
    /// so `T(f)_j = Σ_m P[m][j] f(x_m)`
    proj: Vec<f64>,
    n: usize,
}

impl LegendreEmbedder {
    /// An `n`-coefficient Legendre embedding of `L²(omega)` using an
    /// `n`-point Gauss–Legendre rule (exact for the degree ≤ 2n−1
    /// integrands `P_j · P_j`).
    pub fn new(omega: Interval, n: usize) -> Self {
        assert!(n > 0);
        let (nodes, weights) = gauss_legendre(n);
        let v = omega.volume();
        let mid = 0.5 * (omega.a + omega.b);
        let half = 0.5 * v;
        let points: Vec<f64> = nodes.iter().map(|&t| mid + half * t).collect();
        // Legendre values P_j(t_m) by the three-term recurrence.
        let mut proj = vec![0.0; n * n];
        for (m, &t) in nodes.iter().enumerate() {
            let mut p0 = 1.0; // P_0
            let mut p1 = t; // P_1
            for j in 0..n {
                let pj = if j == 0 {
                    1.0
                } else if j == 1 {
                    t
                } else {
                    let p2 = ((2 * j - 1) as f64 * t * p1 - (j - 1) as f64 * p0) / j as f64;
                    p0 = p1;
                    p1 = p2;
                    p2
                };
                // ê_j(x) = √((2j+1)/V) P_j(t(x)); quadrature weight on
                // [a,b] is w_m · V/2.
                let norm = ((2 * j + 1) as f64 / v).sqrt();
                proj[m * n + j] = weights[m] * half * norm * pj;
            }
        }
        Self {
            omega,
            points,
            proj,
            n,
        }
    }

    /// The domain being embedded.
    pub fn omega(&self) -> Interval {
        self.omega
    }
}

impl Embedder for LegendreEmbedder {
    fn dim(&self) -> usize {
        self.n
    }

    fn p(&self) -> f64 {
        2.0
    }

    fn sample_points(&self) -> &[f64] {
        &self.points
    }

    fn embed_samples(&self, samples: &[f64]) -> Vec<f64> {
        assert_eq!(samples.len(), self.n);
        let mut out = vec![0.0; self.n];
        for (m, &s) in samples.iter().enumerate() {
            let row = &self.proj[m * self.n..(m + 1) * self.n];
            for (o, &p) in out.iter_mut().zip(row) {
                *o += p * s;
            }
        }
        out
    }
}

/// §3.1 embedding in the real Fourier basis (periodic `L²(omega)`).
///
/// Output layout: `[a_0, a_1, b_1, a_2, b_2, …]` (cosine/sine pairs),
/// total dimension `n` (must be odd so pairs complete: `n = 2m + 1`).
#[derive(Debug, Clone)]
pub struct FourierEmbedder {
    omega: Interval,
    points: Vec<f64>,
    n: usize,
}

impl FourierEmbedder {
    /// An `n`-coefficient Fourier embedding (`n` odd), sampling at `n`
    /// equispaced points (midpoint grid), for which the discrete
    /// projection is exactly the trapezoid/DFT rule.
    pub fn new(omega: Interval, n: usize) -> Self {
        assert!(n > 0 && n % 2 == 1, "fourier dim must be odd (1 + 2m)");
        let v = omega.volume();
        let points = (0..n)
            .map(|k| omega.a + v * (k as f64 + 0.5) / n as f64)
            .collect();
        Self { omega, points, n }
    }

    /// The domain being embedded.
    pub fn omega(&self) -> Interval {
        self.omega
    }
}

impl Embedder for FourierEmbedder {
    fn dim(&self) -> usize {
        self.n
    }

    fn p(&self) -> f64 {
        2.0
    }

    fn sample_points(&self) -> &[f64] {
        &self.points
    }

    fn embed_samples(&self, samples: &[f64]) -> Vec<f64> {
        assert_eq!(samples.len(), self.n);
        let n = self.n;
        let v = self.omega.volume();
        let m = (n - 1) / 2;
        // midpoint quadrature: ∫ f e dx ≈ (V/n) Σ f(x_k) e(x_k)
        let h = v / n as f64;
        let mut out = Vec::with_capacity(n);
        // a_0
        let a0: f64 = samples.iter().sum::<f64>() * h / v.sqrt();
        out.push(a0);
        for j in 1..=m {
            let mut aj = 0.0;
            let mut bj = 0.0;
            for (k, &s) in samples.iter().enumerate() {
                let t = 2.0 * PI * j as f64 * (k as f64 + 0.5) / n as f64;
                aj += s * t.cos();
                bj += s * t.sin();
            }
            let norm = (2.0 / v).sqrt() * h;
            out.push(aj * norm);
            out.push(bj * norm);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::l2_dist;
    use crate::functions::{Function1D, Sine};
    use crate::quadrature::{inner_product_l2, lp_distance};

    fn embed(e: &dyn Embedder, f: &dyn Function1D) -> Vec<f64> {
        e.embed_fn(f)
    }

    #[test]
    fn legendre_is_exact_isometry_for_polynomials() {
        // f, g polynomials of degree < n: distances must be exact to
        // machine precision (quadrature exactness).
        let f = crate::functions::Polynomial::new(vec![1.0, -2.0, 0.5, 3.0]);
        let g = crate::functions::Polynomial::new(vec![0.0, 1.0, 1.0]);
        let emb = LegendreEmbedder::new(Interval::new(-1.0, 2.0), 16);
        let d = l2_dist(&embed(&emb, &f), &embed(&emb, &g));
        let truth = lp_distance(&f, &g, -1.0, 2.0, 2.0);
        assert!((d - truth).abs() < 1e-12, "{d} vs {truth}");
    }

    #[test]
    fn legendre_spectral_accuracy_on_smooth_functions() {
        let f = Sine::paper(0.3);
        let g = Sine::paper(1.7);
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let emb = LegendreEmbedder::new(Interval::unit(), 32);
        let d = l2_dist(&embed(&emb, &f), &embed(&emb, &g));
        assert!((d - truth).abs() < 1e-10, "{d} vs {truth}");
    }

    #[test]
    fn legendre_beats_chebyshev_weighting_at_same_n() {
        let f = Sine::paper(0.3);
        let g = Sine::paper(1.7);
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let leg = LegendreEmbedder::new(Interval::unit(), 32);
        let cheb = super::super::ChebyshevEmbedder::new(Interval::unit(), 32);
        let e_leg = (l2_dist(&embed(&leg, &f), &embed(&leg, &g)) - truth).abs();
        let e_cheb = (l2_dist(&embed(&cheb, &f), &embed(&cheb, &g)) - truth).abs();
        assert!(e_leg < e_cheb, "legendre {e_leg} vs chebyshev {e_cheb}");
    }

    #[test]
    fn legendre_inner_products() {
        let f = Sine::paper(0.2);
        let g = Sine::paper(2.5);
        let emb = LegendreEmbedder::new(Interval::unit(), 32);
        let tf = embed(&emb, &f);
        let tg = embed(&emb, &g);
        let ip: f64 = tf.iter().zip(&tg).map(|(a, b)| a * b).sum();
        let truth = inner_product_l2(&f, &g, 0.0, 1.0);
        assert!((ip - truth).abs() < 1e-10, "{ip} vs {truth}");
    }

    #[test]
    fn fourier_exact_for_periodic_workload() {
        // the paper's own workload is 1-periodic on [0,1]: the Fourier
        // embedding captures sin(2πx + δ) with 3 coefficients.
        let f = Sine::paper(0.9);
        let g = Sine::paper(2.2);
        let truth = lp_distance(&f, &g, 0.0, 1.0, 2.0);
        let emb = FourierEmbedder::new(Interval::unit(), 9);
        let d = l2_dist(&embed(&emb, &f), &embed(&emb, &g));
        assert!((d - truth).abs() < 1e-10, "{d} vs {truth}");
    }

    #[test]
    fn fourier_norm_of_constant() {
        let one = |_x: f64| 1.0;
        let emb = FourierEmbedder::new(Interval::new(0.0, 4.0), 17);
        let t = emb.embed_fn(&one);
        let norm: f64 = t.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((norm - 2.0).abs() < 1e-12, "‖1‖ on [0,4] is 2, got {norm}");
    }

    #[test]
    #[should_panic]
    fn fourier_requires_odd_dim() {
        let _ = FourierEmbedder::new(Interval::unit(), 8);
    }

    #[test]
    fn all_bases_linear() {
        let emb = LegendreEmbedder::new(Interval::unit(), 12);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let y: Vec<f64> = (0..12).map(|i| (i as f64 * 0.3).cos()).collect();
        let combo: Vec<f64> = x.iter().zip(&y).map(|(a, b)| 2.0 * a - b).collect();
        let t = emb.embed_samples(&combo);
        let tx = emb.embed_samples(&x);
        let ty = emb.embed_samples(&y);
        for i in 0..12 {
            assert!((t[i] - (2.0 * tx[i] - ty[i])).abs() < 1e-12);
        }
    }
}
