//! Multi-dimensional domains: the paper's §3.2 is stated for `Ω ⊆ ℝⁿ`
//! (and, in the conclusion, arbitrary finite-volume measure spaces); the
//! Monte Carlo embedding carries over verbatim. This module provides the
//! 2-D instantiation — enough to demonstrate the `(log N)^d / N` QMC rate
//! degradation the paper cites from Lemieux (2009) (experiment E11).

use crate::sequences::{Halton, Sobol};
use crate::util::rng::Rng64;

/// A real function on a subset of `ℝ²`.
pub trait Function2D: Send + Sync {
    /// Evaluate at `(x, y)`.
    fn eval2(&self, x: f64, y: f64) -> f64;
}

impl<F: Fn(f64, f64) -> f64 + Send + Sync> Function2D for F {
    fn eval2(&self, x: f64, y: f64) -> f64 {
        self(x, y)
    }
}

/// An axis-aligned rectangle `[a₁,b₁] × [a₂,b₂]` — the 2-D domain `Ω`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rectangle {
    /// x-range start
    pub a1: f64,
    /// x-range end
    pub b1: f64,
    /// y-range start
    pub a2: f64,
    /// y-range end
    pub b2: f64,
}

impl Rectangle {
    /// A rectangle; both ranges must be nondegenerate.
    pub fn new(a1: f64, b1: f64, a2: f64, b2: f64) -> Self {
        assert!(a1 < b1 && a2 < b2);
        Self { a1, b1, a2, b2 }
    }

    /// The unit square `[0,1]²`.
    pub fn unit() -> Self {
        Self::new(0.0, 1.0, 0.0, 1.0)
    }

    /// Volume (area) of the rectangle.
    pub fn volume(&self) -> f64 {
        (self.b1 - self.a1) * (self.b2 - self.a2)
    }
}

/// Which point set drives the 2-D embedding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling2D {
    /// i.i.d. uniform (plain Monte Carlo, `O(N^{-1/2})`)
    Iid,
    /// 2-D Sobol (`O(N^{-1} (log N)²)`)
    Sobol,
    /// 2-D Halton
    Halton,
}

/// §3.2 over `Ω ⊆ ℝ²`: `T(f) = (V/N)^{1/p} (f(z_1), …, f(z_N))`.
#[derive(Debug, Clone)]
pub struct MonteCarloEmbedder2D {
    points: Vec<(f64, f64)>,
    scale: f64,
    p: f64,
}

impl MonteCarloEmbedder2D {
    /// Build with `n` sample points from the chosen scheme.
    pub fn new(
        omega: Rectangle,
        n: usize,
        p: f64,
        sampling: Sampling2D,
        rng: &mut dyn Rng64,
    ) -> Self {
        assert!(n > 0 && p > 0.0);
        let unit: Vec<(f64, f64)> = match sampling {
            Sampling2D::Iid => (0..n).map(|_| (rng.uniform(), rng.uniform())).collect(),
            Sampling2D::Sobol => {
                let mut s = Sobol::new(2);
                s.take_points(n).into_iter().map(|p| (p[0], p[1])).collect()
            }
            Sampling2D::Halton => {
                let mut h = Halton::new(2);
                h.take_points(n).into_iter().map(|p| (p[0], p[1])).collect()
            }
        };
        let points = unit
            .into_iter()
            .map(|(u, v)| {
                (
                    omega.a1 + (omega.b1 - omega.a1) * u,
                    omega.a2 + (omega.b2 - omega.a2) * v,
                )
            })
            .collect();
        let scale = (omega.volume() / n as f64).powf(1.0 / p);
        Self { points, scale, p }
    }

    /// Embedding dimension `N`.
    pub fn dim(&self) -> usize {
        self.points.len()
    }

    /// The `L^p` exponent.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// The 2-D sample points.
    pub fn sample_points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// Embed a function by sampling it at the point set.
    pub fn embed_fn(&self, f: &dyn Function2D) -> Vec<f64> {
        self.points
            .iter()
            .map(|&(x, y)| f.eval2(x, y) * self.scale)
            .collect()
    }

    /// Embed raw sample values (in `sample_points` order).
    pub fn embed_samples(&self, samples: &[f64]) -> Vec<f64> {
        assert_eq!(samples.len(), self.points.len());
        samples.iter().map(|&s| s * self.scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::l2_dist;
    use crate::util::rng::Xoshiro256pp;
    use std::f64::consts::PI;

    /// ‖f − g‖_{L²([0,1]²)} for f = sin(2π(x+y)+δ₁), g with δ₂ —
    /// closed form √(1 − cos Δδ) (same algebra as the 1-D case).
    fn truth(d1: f64, d2: f64) -> f64 {
        (1.0 - (d1 - d2 as f64).cos()).max(0.0).sqrt()
    }

    fn wave(delta: f64) -> impl Fn(f64, f64) -> f64 {
        move |x: f64, y: f64| (2.0 * PI * (x + y) + delta).sin()
    }

    #[test]
    fn iid_2d_preserves_distance_on_average() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let f = wave(0.4);
        let g = wave(1.9);
        let want = truth(0.4, 1.9);
        let mut acc = 0.0;
        let reps = 32;
        for _ in 0..reps {
            let emb =
                MonteCarloEmbedder2D::new(Rectangle::unit(), 256, 2.0, Sampling2D::Iid, &mut rng);
            acc += l2_dist(&emb.embed_fn(&f), &emb.embed_fn(&g));
        }
        let mean = acc / reps as f64;
        assert!((mean - want).abs() < 0.03, "{mean} vs {want}");
    }

    #[test]
    fn sobol_2d_much_tighter_than_iid() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let f = wave(0.4);
        let g = wave(1.9);
        let want = truth(0.4, 1.9);
        let emb_q =
            MonteCarloEmbedder2D::new(Rectangle::unit(), 1024, 2.0, Sampling2D::Sobol, &mut rng);
        let err_q = (l2_dist(&emb_q.embed_fn(&f), &emb_q.embed_fn(&g)) - want).abs();
        let emb_m =
            MonteCarloEmbedder2D::new(Rectangle::unit(), 1024, 2.0, Sampling2D::Iid, &mut rng);
        let err_m = (l2_dist(&emb_m.embed_fn(&f), &emb_m.embed_fn(&g)) - want).abs();
        assert!(err_q < err_m, "sobol {err_q} vs iid {err_m}");
        assert!(err_q < 5e-3, "sobol error {err_q}");
    }

    #[test]
    fn volume_scaling_2d() {
        // constant 1 on a 2x3 rectangle: ‖1‖ = √6
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let emb = MonteCarloEmbedder2D::new(
            Rectangle::new(0.0, 2.0, 0.0, 3.0),
            128,
            2.0,
            Sampling2D::Halton,
            &mut rng,
        );
        let t = emb.embed_fn(&|_x: f64, _y: f64| 1.0);
        let norm: f64 = t.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!((norm - 6.0f64.sqrt()).abs() < 1e-12, "{norm}");
    }

    #[test]
    fn embed_samples_matches_embed_fn_2d() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let emb =
            MonteCarloEmbedder2D::new(Rectangle::unit(), 64, 1.0, Sampling2D::Sobol, &mut rng);
        let f = wave(0.1);
        let samples: Vec<f64> = emb
            .sample_points()
            .iter()
            .map(|&(x, y)| f(x, y))
            .collect();
        assert_eq!(emb.embed_samples(&samples), emb.embed_fn(&f));
    }
}
